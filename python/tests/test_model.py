"""L2 fused solver steps: one fused iteration must match a plain-numpy
iteration of the textbook algorithm, and repeated steps must converge on
an SPD system."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model


def spd_ell(rng, n, k_pad=16):
    """Random diagonally-dominant symmetric matrix in ELL arrays + dense."""
    dense = np.zeros((n, n))
    for i in range(n):
        for _ in range(2):
            j = int(rng.integers(0, n))
            v = rng.uniform(-0.3, 0.3)
            dense[i, j] += v
            dense[j, i] += v
    dense[np.diag_indices(n)] = 0.0
    dense += np.diag(np.abs(dense).sum(axis=1) + 1.0)
    vals = np.zeros((k_pad, n))
    cols = np.zeros((k_pad, n), dtype=np.int32)
    for i in range(n):
        nz = np.nonzero(dense[i])[0]
        assert len(nz) <= k_pad, "increase k_pad"
        for j, c in enumerate(nz):
            vals[j, i] = dense[i, c]
            cols[j, i] = c
    return vals, cols, dense


def test_cg_step_matches_numpy(rng):
    n = 256
    vals, cols, dense = spd_ell(rng, n)
    b = rng.uniform(-1, 1, n)
    x = np.zeros(n)
    r = b.copy()
    p = r.copy()
    rr = float(r @ r)

    # fused step
    x1, r1, p1, rr1 = (np.asarray(v) for v in model.cg_step(vals, cols, x, r, p, np.float64(rr)))

    # textbook step
    q = dense @ p
    alpha = rr / (p @ q)
    xe = x + alpha * p
    re = r - alpha * q
    rre = re @ re
    beta = rre / rr
    pe = re + beta * p

    assert_allclose(x1, xe, rtol=1e-12)
    assert_allclose(r1, re, rtol=1e-12)
    assert_allclose(p1, pe, rtol=1e-12)
    assert_allclose(rr1[0], rre, rtol=1e-12)


def test_cg_steps_converge(rng):
    n = 256
    vals, cols, dense = spd_ell(rng, n)
    xs = np.linalg.solve(dense, np.ones(n))
    x = np.zeros(n)
    r = np.ones(n)
    p = r.copy()
    rr = np.float64(r @ r)
    for _ in range(60):
        x, r, p, rr_arr = model.cg_step(vals, cols, x, r, p, rr)
        rr = np.asarray(rr_arr)[0]
        if np.sqrt(rr) < 1e-10:
            break
    assert_allclose(np.asarray(x), xs, rtol=1e-6, atol=1e-8)


def test_bicgstab_steps_converge(rng):
    n = 256
    vals, cols, dense = spd_ell(rng, n)
    # make it nonsymmetric but still dominant
    dense2 = dense.copy()
    b = rng.uniform(-1, 1, n)
    x = np.zeros(n)
    r = b.copy()
    rhat = r.copy()
    p = np.zeros(n)
    v = np.zeros(n)
    rho = np.float64(1.0)
    alpha = np.float64(1.0)
    omega = np.float64(1.0)
    for _ in range(80):
        x, r, p, v, rho_a, alpha_a, omega_a = model.bicgstab_step(
            vals, cols, x, r, rhat, p, v, rho, alpha, omega
        )
        rho = np.asarray(rho_a)[0]
        alpha = np.asarray(alpha_a)[0]
        omega = np.asarray(omega_a)[0]
        if np.linalg.norm(np.asarray(r)) < 1e-10:
            break
    assert np.linalg.norm(dense2 @ np.asarray(x) - b) < 1e-7


def test_cgs_steps_converge(rng):
    n = 256
    vals, cols, dense = spd_ell(rng, n)
    b = rng.uniform(-1, 1, n)
    x = np.zeros(n)
    r = b.copy()
    rhat = r.copy()
    p = np.zeros(n)
    q = np.zeros(n)
    rho = np.float64(1.0)
    for _ in range(80):
        x, r, p, q, rho_a = model.cgs_step(vals, cols, x, r, rhat, p, q, rho)
        rho = np.asarray(rho_a)[0]
        if np.linalg.norm(np.asarray(r)) < 1e-10:
            break
    assert np.linalg.norm(dense @ np.asarray(x) - b) < 1e-7
