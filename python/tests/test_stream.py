"""Pallas BabelStream kernels vs the oracle + the BabelStream self-check."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import ref, stream

DTYPES = [np.float32, np.float64]


@pytest.mark.parametrize("dt", DTYPES)
@pytest.mark.parametrize("n", [256, 1024])
def test_each_kernel_matches_ref(rng, dt, n):
    a = rng.uniform(-1, 1, n).astype(dt)
    b = rng.uniform(-1, 1, n).astype(dt)
    c = rng.uniform(-1, 1, n).astype(dt)
    s = dt(ref.STREAM_SCALAR)
    tol = dict(rtol=1e-6, atol=1e-6) if dt == np.float32 else dict(rtol=1e-13, atol=1e-14)
    assert_allclose(np.asarray(stream.stream_copy(a)), a)
    assert_allclose(np.asarray(stream.stream_mul(s, c)), np.asarray(ref.stream_mul(s, c)), **tol)
    assert_allclose(np.asarray(stream.stream_add(a, b)), a + b, **tol)
    assert_allclose(
        np.asarray(stream.stream_triad(s, b, c)), np.asarray(ref.stream_triad(s, b, c)), **tol
    )
    got = np.asarray(stream.stream_dot(a, b))
    assert got.shape == (1,)
    assert_allclose(got[0], np.dot(a.astype(np.float64), b.astype(np.float64)), rtol=1e-5)


def test_babelstream_cycle_self_check():
    """Run the BabelStream Copy->Mul->Add->Triad cycle and verify against
    the closed-form gold values (the benchmark's own validation)."""
    n = 512
    a = np.full(n, 0.1)
    b = np.full(n, 0.2)
    c = np.zeros(n)
    s = np.float64(ref.STREAM_SCALAR)
    ga, gb, gc = 0.1, 0.2, 0.0
    for _ in range(4):
        c = np.asarray(stream.stream_copy(a))
        b = np.asarray(stream.stream_mul(s, c))
        c = np.asarray(stream.stream_add(a, b))
        a = np.asarray(stream.stream_triad(s, b, c))
        gc = ga
        gb = ref.STREAM_SCALAR * gc
        gc = ga + gb
        ga = gb + ref.STREAM_SCALAR * gc
    assert_allclose(a, np.full(n, ga), rtol=1e-13)
    assert_allclose(b, np.full(n, gb), rtol=1e-13)
    assert_allclose(c, np.full(n, gc), rtol=1e-13)


def test_mixbench_flops_chain():
    from compile.kernels import mixbench

    x = np.linspace(-1, 1, 256)
    for flops in [1, 4, 16]:
        got = np.asarray(mixbench.mixbench(x, flops))
        want = np.asarray(ref.mixbench(x, flops))
        assert_allclose(got, want, rtol=1e-12)
