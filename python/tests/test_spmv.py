"""Pallas ELL SpMV + jnp COO SpMV vs dense ground truth."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref, spmv

DTYPES = [np.float32, np.float64]


def random_ell(rng, n, k_fill, k_pad, dt):
    """Random ELL matrix: each row gets up to k_fill entries, stored in
    (k_pad, n) column-major arrays with val-0/col-0 padding. Returns the
    (vals, cols) arrays and the equivalent dense matrix."""
    vals = np.zeros((k_pad, n), dtype=dt)
    cols = np.zeros((k_pad, n), dtype=np.int32)
    dense = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        nnz_i = rng.integers(0, k_fill + 1)
        cs = rng.choice(n, size=nnz_i, replace=False)
        for j, c in enumerate(cs):
            v = rng.uniform(-1, 1)
            vals[j, i] = v
            cols[j, i] = c
            dense[i, c] += v
    return vals, cols, dense


@pytest.mark.parametrize("n", [256, 512])
@pytest.mark.parametrize("dt", DTYPES)
def test_ell_spmv_matches_dense(rng, n, dt):
    vals, cols, dense = random_ell(rng, n, 6, 8, dt)
    x = rng.uniform(-1, 1, n).astype(dt)
    got = np.asarray(spmv.ell_spmv(vals, cols, x))
    want = dense @ x.astype(np.float64)
    tol = 1e-4 if dt == np.float32 else 1e-12
    assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("dt", DTYPES)
def test_ell_matches_ref_oracle(rng, dt):
    vals, cols, _ = random_ell(rng, 256, 4, 8, dt)
    x = rng.uniform(-1, 1, 256).astype(dt)
    got = np.asarray(spmv.ell_spmv(vals, cols, x))
    want = np.asarray(ref.ell_spmv(vals, cols, x))
    assert_allclose(got, want, rtol=1e-6 if dt == np.float32 else 1e-14, atol=1e-6 if dt == np.float32 else 1e-14)


def test_ell_padding_is_neutral(rng):
    """The runtime invariant: padding rows/width with val-0/col-0 entries
    must not change the result."""
    n = 256
    vals, cols, dense = random_ell(rng, n, 4, 8, np.float64)
    x = rng.uniform(-1, 1, n)
    base = np.asarray(spmv.ell_spmv(vals, cols, x))
    # pad width 8 -> 32
    vals_w = np.zeros((32, n)); vals_w[:8] = vals
    cols_w = np.zeros((32, n), dtype=np.int32); cols_w[:8] = cols
    padded_w = np.asarray(spmv.ell_spmv(vals_w, cols_w, x))
    assert_allclose(padded_w, base, rtol=1e-14)
    # pad rows n -> 2n (extra rows all padding, x padded with garbage-free 0)
    vals_n = np.zeros((8, 2 * n)); vals_n[:, :n] = vals
    cols_n = np.zeros((8, 2 * n), dtype=np.int32); cols_n[:, :n] = cols
    x_n = np.concatenate([x, np.zeros(n)])
    padded_n = np.asarray(spmv.ell_spmv(vals_n, cols_n, x_n))
    assert_allclose(padded_n[:n], base, rtol=1e-14)
    assert_allclose(padded_n[n:], np.zeros(n))


@pytest.mark.parametrize("dt", DTYPES)
def test_ell_advanced_alpha_beta(rng, dt):
    vals, cols, dense = random_ell(rng, 256, 4, 8, dt)
    b = rng.uniform(-1, 1, 256).astype(dt)
    y = rng.uniform(-1, 1, 256).astype(dt)
    got = np.asarray(spmv.ell_spmv_advanced(dt(2.0), vals, cols, b, dt(-0.5), y))
    want = 2.0 * (dense @ b.astype(np.float64)) - 0.5 * y.astype(np.float64)
    tol = 1e-4 if dt == np.float32 else 1e-12
    assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("dt", DTYPES)
def test_coo_spmv_matches_dense(rng, dt):
    n, nnz = 200, 1500
    rows = np.sort(rng.integers(0, n, nnz)).astype(np.int32)
    cols = rng.integers(0, n, nnz).astype(np.int32)
    vals = rng.uniform(-1, 1, nnz).astype(dt)
    dense = np.zeros((n, n))
    np.add.at(dense, (rows, cols), vals.astype(np.float64))
    x = rng.uniform(-1, 1, n).astype(dt)
    got = np.asarray(ref.coo_spmv(vals, rows, cols, x, n))
    tol = 1e-4 if dt == np.float32 else 1e-12
    assert_allclose(got, dense @ x.astype(np.float64), rtol=tol, atol=tol)


def test_coo_padding_is_neutral(rng):
    """Padding entries (row 0, col 0, val 0) must contribute nothing."""
    n, nnz = 100, 400
    rows = np.sort(rng.integers(0, n, nnz)).astype(np.int32)
    cols = rng.integers(0, n, nnz).astype(np.int32)
    vals = rng.uniform(-1, 1, nnz)
    x = rng.uniform(-1, 1, n)
    base = np.asarray(ref.coo_spmv(vals, rows, cols, x, n))
    rows_p = np.concatenate([rows, np.zeros(50, np.int32)])
    cols_p = np.concatenate([cols, np.zeros(50, np.int32)])
    vals_p = np.concatenate([vals, np.zeros(50)])
    padded = np.asarray(ref.coo_spmv(vals_p, rows_p, cols_p, x, n))
    assert_allclose(padded, base, rtol=1e-14)


@settings(max_examples=15, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=4),
    k=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_ell_property_sweep(blocks, k, seed):
    """hypothesis: any row-block count, any stored width, any seed."""
    n = 256 * blocks
    r = np.random.default_rng(seed)
    vals = r.uniform(-1, 1, (k, n))
    cols = r.integers(0, n, (k, n)).astype(np.int32)
    x = r.uniform(-1, 1, n)
    got = np.asarray(spmv.ell_spmv(vals, cols, x))
    want = np.asarray(ref.ell_spmv(vals, cols, x))
    assert_allclose(got, want, rtol=1e-11, atol=1e-11)
