"""Block-policy coverage: the lowering-time tile-size knob must preserve
numerics under both the CPU policy (large blocks, few grid steps) and
the TPU policy (VMEM-sized tiles, many grid steps)."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import blas1, ref, spmv


def test_block_divides_n():
    for n in [256, 1024, 4096, 65536, 262144, 1048576]:
        b = blas1._block(n)
        assert n % b == 0, f"block {b} does not divide {n}"
        assert b <= blas1.MAX_BLOCK or b == n


def test_block_respects_max(monkeypatch):
    monkeypatch.setattr(blas1, "MAX_BLOCK", 1024)
    assert blas1._block(65536) == 1024
    assert blas1._block(256) == 256
    # non-power-of-two max still yields a divisor
    monkeypatch.setattr(blas1, "MAX_BLOCK", 1000)
    b = blas1._block(4096)
    assert 4096 % b == 0 and b <= 1000


@pytest.mark.parametrize("max_block", [256, 1024, 65536])
def test_axpy_correct_under_any_policy(rng, monkeypatch, max_block):
    monkeypatch.setattr(blas1, "MAX_BLOCK", max_block)
    n = 4096
    x = rng.uniform(-1, 1, n)
    y = rng.uniform(-1, 1, n)
    got = np.asarray(blas1.axpy(np.float64(0.7), x, y))
    assert_allclose(got, 0.7 * x + y, rtol=1e-13)


@pytest.mark.parametrize("max_block", [256, 4096])
def test_dot_accumulates_across_policies(rng, monkeypatch, max_block):
    """The sequential-grid accumulator must agree for 1 step and for
    n/max_block steps."""
    monkeypatch.setattr(blas1, "MAX_BLOCK", max_block)
    n = 4096
    x = rng.standard_normal(n)
    y = rng.standard_normal(n)
    got = np.asarray(blas1.dot(x, y))[0]
    assert np.isclose(got, np.dot(x, y), rtol=1e-12)


@pytest.mark.parametrize("max_block", [256, 65536])
def test_ell_spmv_correct_under_any_policy(rng, monkeypatch, max_block):
    monkeypatch.setattr(spmv, "MAX_ROW_BLOCK", max_block)
    n, k = 1024, 6
    vals = rng.uniform(-1, 1, (k, n))
    cols = rng.integers(0, n, (k, n)).astype(np.int32)
    x = rng.uniform(-1, 1, n)
    got = np.asarray(spmv.ell_spmv(vals, cols, x))
    want = np.asarray(ref.ell_spmv(vals, cols, x))
    assert_allclose(got, want, rtol=1e-12, atol=1e-12)
