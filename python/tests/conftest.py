"""Shared fixtures: x64 mode on, deterministic numpy RNG per test."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture
def rng():
    return np.random.default_rng(20210319)  # the paper's date
