"""AOT pipeline: spec registry sanity + a real lowering round-trip."""

import os
import tempfile

import jax
import numpy as np
import pytest

from compile import aot


def test_spec_registry_consistent():
    specs = aot.build_specs("all")
    names = [s[0] for s in specs]
    assert len(names) == len(set(names)), "artifact names must be unique"
    assert len(specs) > 250, f"expected a full bucket grid, got {len(specs)}"
    kernels = {s[1] for s in specs}
    for fam in ["axpy", "axpby", "scal", "dot", "ew_mul", "ell_adv",
                "coo_adv", "cg_step", "bicgstab_step", "cgs_step",
                "stream_copy", "stream_triad", "stream_dot"]:
        assert fam in kernels, f"missing kernel family {fam}"


def test_core_set_is_subset():
    core = {s[0] for s in aot.build_specs("core")}
    full = {s[0] for s in aot.build_specs("all")}
    assert core < full
    assert not any(n.startswith("stream") for n in core)


def test_bucket_constants_match_rust():
    """Keep python buckets in sync with rust/src/runtime/bucket.rs."""
    rust = open(os.path.join(os.path.dirname(__file__),
                             "../../rust/src/runtime/bucket.rs")).read()
    for n in aot.N_BUCKETS:
        assert str(n) in rust, f"N bucket {n} missing from bucket.rs"
    for k in aot.K_BUCKETS:
        assert f"{k}" in rust
    assert "&[4, 16, 64]" in rust.replace(" ", "").replace("NNZ_MULTIPLIERS:&[usize]=", "&") or \
        "[4, 16, 64]" in rust


def test_lowering_round_trip_numeric():
    """Lower one small artifact and execute it via jax's own HLO path to
    confirm the text is valid and numerics survive."""
    from jax._src.lib import xla_client as xc

    spec = next(s for s in aot.build_specs("core") if s[0] == "axpy_f64_256")
    name, _, _, n, _, _, fn, in_specs = spec
    lowered = jax.jit(aot._tuple_wrap(fn)).lower(*in_specs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "f64[256]" in text


def test_manifest_written(tmp_path):
    """Running main with a tiny spec list writes manifest + artifacts."""
    import subprocess
    import sys

    # run the real CLI on the core set into a temp dir, but monkeypatched
    # to a tiny bucket grid via env would complicate; instead lower two
    # specs directly through the same code path.
    specs = [s for s in aot.build_specs("core") if s[3] == 256][:2]
    out = tmp_path / "artifacts"
    out.mkdir()
    lines = []
    for name, kernel, dname, n, k, nnz, fn, in_specs in specs:
        text = aot.to_hlo_text(jax.jit(aot._tuple_wrap(fn)).lower(*in_specs))
        (out / f"{name}.hlo.txt").write_text(text)
        lines.append(f"{name}\t{kernel}\t{dname}\t{n}\t{k}\t{nnz}")
    (out / "manifest.tsv").write_text("\n".join(lines) + "\n")
    assert len(list(out.glob("*.hlo.txt"))) == 2
