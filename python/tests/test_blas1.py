"""Pallas BLAS-1 kernels vs the pure-jnp oracle (hypothesis sweeps)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import blas1, ref

SIZES = [256, 512, 1024, 4096]
DTYPES = [np.float32, np.float64]


def _tol(dt):
    return dict(rtol=1e-5, atol=1e-6) if dt == np.float32 else dict(rtol=1e-12, atol=1e-13)


def _vec(rng, n, dt):
    return rng.uniform(-1, 1, n).astype(dt)


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dt", DTYPES)
def test_axpy_matches_ref(rng, n, dt):
    alpha = dt(0.7)
    x, y = _vec(rng, n, dt), _vec(rng, n, dt)
    got = blas1.axpy(alpha, x, y)
    assert_allclose(np.asarray(got), ref.axpy(alpha, x, y), **_tol(dt))


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dt", DTYPES)
def test_axpby_matches_ref(rng, n, dt):
    a, b = dt(-0.3), dt(1.7)
    x, y = _vec(rng, n, dt), _vec(rng, n, dt)
    got = blas1.axpby(a, b, x, y)
    assert_allclose(np.asarray(got), ref.axpby(a, b, x, y), **_tol(dt))


@pytest.mark.parametrize("dt", DTYPES)
def test_scal_and_zero(rng, dt):
    x = _vec(rng, 512, dt)
    assert_allclose(np.asarray(blas1.scal(dt(2.5), x)), 2.5 * x, **_tol(dt))
    assert_allclose(np.asarray(blas1.scal(dt(0.0), x)), np.zeros_like(x))


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dt", DTYPES)
def test_dot_matches_ref(rng, n, dt):
    x, y = _vec(rng, n, dt), _vec(rng, n, dt)
    got = blas1.dot(x, y)
    assert got.shape == (1,)
    assert_allclose(np.asarray(got), ref.dot(x, y), **_tol(dt))


@pytest.mark.parametrize("dt", DTYPES)
def test_ew_mul_matches_ref(rng, dt):
    x, y = _vec(rng, 1024, dt), _vec(rng, 1024, dt)
    assert_allclose(np.asarray(blas1.ew_mul(x, y)), x * y, **_tol(dt))


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31),
    alpha=st.floats(min_value=-10, max_value=10, allow_nan=False),
)
def test_axpy_property_sweep(blocks, seed, alpha):
    """hypothesis: any block count, any seed, any finite alpha."""
    n = 256 * blocks
    r = np.random.default_rng(seed)
    x = r.standard_normal(n)
    y = r.standard_normal(n)
    got = blas1.axpy(np.float64(alpha), x, y)
    assert_allclose(np.asarray(got), alpha * x + y, rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_dot_property_sweep(blocks, seed):
    """hypothesis: dot accumulation across any grid length."""
    n = 256 * blocks
    r = np.random.default_rng(seed)
    x = r.standard_normal(n)
    y = r.standard_normal(n)
    got = np.asarray(blas1.dot(x, y))[0]
    assert np.isclose(got, np.dot(x, y), rtol=1e-11, atol=1e-11)
