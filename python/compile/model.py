"""L2: fused Krylov solver iteration graphs (the paper's §5 solvers).

Each `*_step` function is one full iteration of a short-recurrence Krylov
solver operating on an ELL-stored operator, calling the L1 Pallas SpMV
and reduction kernels. `aot.py` lowers one artifact per (solver, dtype,
n-bucket, k-bucket); the Rust solver drivers then run whole iterations in
a single PJRT dispatch (the fused-vs-composed tradeoff is measured by the
`ablation_fused_step` bench).

GMRES is deliberately *not* fused: its orthogonalization works against a
growing Krylov basis, so the Rust driver composes it from BLAS-1/SpMV
dispatches — mirroring the paper's observation (§6.4) that GMRES maps
worst onto the ported backend and runs through workaround paths.

Scalars cross the artifact boundary as rank-0 inputs and (1,)-shaped
outputs (the Rust side reads `out[i][0]`).
"""

import jax.numpy as jnp

from compile.kernels import blas1, spmv


def _dot(x, y):
    """Pallas dot -> rank-0 scalar."""
    return blas1.dot(x, y)[0]


def cg_step(vals, cols, x, r, p, rr):
    """One Conjugate Gradient iteration.

    Inputs: ELL operator (vals, cols), iterate x, residual r, search
    direction p, and rr = <r, r> carried from the previous step.
    Returns (x', r', p', rr' as (1,)).
    """
    q = spmv.ell_spmv(vals, cols, p)
    pq = _dot(p, q)
    alpha = rr / pq
    x1 = blas1.axpy(alpha, p, x)
    r1 = blas1.axpy(-alpha, q, r)
    rr1 = _dot(r1, r1)
    beta = rr1 / rr
    p1 = blas1.axpby(jnp.ones_like(beta), beta, r1, p)
    return x1, r1, p1, rr1.reshape((1,))


def bicgstab_step(vals, cols, x, r, rhat, p, v, rho_old, alpha, omega):
    """One BiCGSTAB iteration (two SpMVs).

    Returns (x', r', p', v', rho' (1,), alpha' (1,), omega' (1,)).
    """
    rho = _dot(rhat, r)
    beta = (rho / rho_old) * (alpha / omega)
    # p = r + beta * (p - omega * v)
    pmov = blas1.axpy(-omega, v, p)
    p1 = blas1.axpby(jnp.ones_like(beta), beta, r, pmov)
    v1 = spmv.ell_spmv(vals, cols, p1)
    alpha1 = rho / _dot(rhat, v1)
    s = blas1.axpy(-alpha1, v1, r)
    t = spmv.ell_spmv(vals, cols, s)
    omega1 = _dot(t, s) / _dot(t, t)
    # x = x + alpha * p + omega * s
    x1 = blas1.axpy(alpha1, p1, x)
    x1 = blas1.axpy(omega1, s, x1)
    r1 = blas1.axpy(-omega1, t, s)
    return (
        x1,
        r1,
        p1,
        v1,
        rho.reshape((1,)),
        alpha1.reshape((1,)),
        omega1.reshape((1,)),
    )


def cgs_step(vals, cols, x, r, rhat, p, q, rho_old):
    """One CGS iteration (two SpMVs).

    Returns (x', r', p', q', rho' (1,)).
    """
    rho = _dot(rhat, r)
    beta = rho / rho_old
    u = blas1.axpy(beta, q, r)
    # p = u + beta * (q + beta * p)
    qbp = blas1.axpby(jnp.ones_like(beta), beta, q, p)
    p1 = blas1.axpby(jnp.ones_like(beta), beta, u, qbp)
    vhat = spmv.ell_spmv(vals, cols, p1)
    sigma = _dot(rhat, vhat)
    alpha = rho / sigma
    q1 = blas1.axpy(-alpha, vhat, u)
    uq = blas1.axpy(jnp.ones_like(alpha), q1, u)
    x1 = blas1.axpy(alpha, uq, x)
    auq = spmv.ell_spmv(vals, cols, uq)
    r1 = blas1.axpy(-alpha, auq, r)
    return x1, r1, p1, q1, rho.reshape((1,))
