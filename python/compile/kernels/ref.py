"""Pure-jnp reference oracles for every L1 kernel.

These are the correctness ground truth the Pallas kernels are tested
against (pytest + hypothesis in python/tests), mirroring Ginkgo's
`reference` backend role. No pallas, no tricks — just the textbook
definition of each operation.
"""

import jax.numpy as jnp


# ----------------------------------------------------------------- BLAS-1

def axpy(alpha, x, y):
    """y' = alpha * x + y."""
    return alpha * x + y


def axpby(alpha, beta, x, y):
    """y' = alpha * x + beta * y."""
    return alpha * x + beta * y


def scal(beta, x):
    """x' = beta * x."""
    return beta * x


def dot(x, y):
    """<x, y> as a (1,) array (matches the Pallas accumulator shape)."""
    return jnp.sum(x * y).reshape((1,))


def ew_mul(x, y):
    """Element-wise product."""
    return x * y


# ----------------------------------------------------------------- stream

STREAM_SCALAR = 0.4


def stream_copy(a):
    return a


def stream_mul(s, c):
    return s * c


def stream_add(a, b):
    return a + b


def stream_triad(s, b, c):
    return b + s * c


def stream_dot(a, b):
    return jnp.sum(a * b).reshape((1,))


# ------------------------------------------------------------------- SpMV

def ell_spmv(vals, cols, x):
    """ELL SpMV. vals/cols are (k, n) column-major ELL storage; padding
    entries have val 0 / col 0, which contribute nothing."""
    return jnp.sum(vals * x[cols], axis=0)


def ell_spmv_advanced(alpha, vals, cols, b, beta, y):
    """y' = alpha * A b + beta * y for ELL A."""
    return alpha * ell_spmv(vals, cols, b) + beta * y


def coo_spmv(vals, rows, cols, x, n):
    """COO SpMV via segment-sum (the TPU substitution for the atomic
    scatter the CUDA/DPC++ kernels use — see DESIGN.md
    §Hardware-Adaptation)."""
    import jax

    prod = vals * x[cols]
    return jax.ops.segment_sum(prod, rows, num_segments=n)


def coo_spmv_advanced(alpha, vals, rows, cols, b, beta, y):
    """y' = alpha * A b + beta * y for COO A (n taken from y)."""
    return alpha * coo_spmv(vals, rows, cols, b, y.shape[0]) + beta * y


def mixbench(x, flops_per_elem):
    """mixbench-style arithmetic intensity kernel: `flops_per_elem / 2`
    fused multiply-adds per element (2 flops each)."""
    s = jnp.asarray(0.999, dtype=x.dtype)
    t = jnp.asarray(0.001, dtype=x.dtype)
    y = x
    for _ in range(max(1, flops_per_elem // 2)):
        y = y * s + t
    return y
