"""L1 Pallas kernels: BLAS-1 vector operations.

Each kernel tiles its vectors into `BLOCK`-element VMEM blocks and maps a
1-D grid over them; scalars ride along as (1,)-shaped blocks broadcast to
every grid step (the TPU analog of a kernel argument living in SMEM).
`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels lower to plain HLO (see aot_recipe / DESIGN.md).

The reduction kernel (`dot`) accumulates into a (1,)-element output block
across sequential grid steps — the standard TPU pattern replacing the
subgroup-reduction + atomic finale a CUDA/DPC++ dot uses (the paper §4.2
emulates missing subgroup votes the same way, one level down).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import os

# Block policy (the per-backend kernel-configuration knob, §4 of the
# paper: the same kernel source is launched with backend-tuned tiles).
#
# Interpret-mode grid steps carry a large fixed overhead on the CPU PJRT
# backend (~0.4 ms/step measured — see EXPERIMENTS.md §Perf), so the CPU
# default uses blocks up to 64 Ki elements (≤ 16 grid steps at the
# largest bucket). For a real-TPU lowering set SPARKLE_MAX_BLOCK=1024 (or
# smaller) so every operand tile fits VMEM with double buffering.
MAX_BLOCK = int(os.environ.get("SPARKLE_MAX_BLOCK", 65536))
# Kept for backward-compat in tests that import BLOCK: the minimum tile.
BLOCK = 256


def _block(n):
    """Largest power-of-two block ≤ MAX_BLOCK that divides n."""
    b = min(n, MAX_BLOCK)
    while n % b != 0:
        b //= 2
    return max(b, 1)


def _grid(n):
    return (n // _block(n),)


def _vec_spec_n(n):
    b = _block(n)
    return pl.BlockSpec((b,), lambda i: (i,))


def _scalar_spec():
    # one (1,) block broadcast to every grid step
    return pl.BlockSpec((1,), lambda i: (0,))


def _ew_call(kernel, n, dtype, num_scalars, num_vecs):
    """Build a pallas_call for an element-wise kernel."""
    in_specs = [_scalar_spec()] * num_scalars + [_vec_spec_n(n)] * num_vecs
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), dtype),
        grid=_grid(n),
        in_specs=in_specs,
        out_specs=_vec_spec_n(n),
        interpret=True,
    )


def axpy(alpha, x, y):
    """y' = alpha * x + y. `alpha` is rank-0 (matches the Rust caller)."""

    def kernel(a_ref, x_ref, y_ref, o_ref):
        o_ref[...] = a_ref[0] * x_ref[...] + y_ref[...]

    n = x.shape[0]
    return _ew_call(kernel, n, x.dtype, 1, 2)(alpha.reshape((1,)), x, y)


def axpby(alpha, beta, x, y):
    """y' = alpha * x + beta * y."""

    def kernel(a_ref, b_ref, x_ref, y_ref, o_ref):
        o_ref[...] = a_ref[0] * x_ref[...] + b_ref[0] * y_ref[...]

    n = x.shape[0]
    return _ew_call(kernel, n, x.dtype, 2, 2)(
        alpha.reshape((1,)), beta.reshape((1,)), x, y
    )


def scal(beta, x):
    """x' = beta * x."""

    def kernel(b_ref, x_ref, o_ref):
        o_ref[...] = b_ref[0] * x_ref[...]

    n = x.shape[0]
    return _ew_call(kernel, n, x.dtype, 1, 1)(beta.reshape((1,)), x)


def ew_mul(x, y):
    """z = x ⊙ y."""

    def kernel(x_ref, y_ref, o_ref):
        o_ref[...] = x_ref[...] * y_ref[...]

    n = x.shape[0]
    return _ew_call(kernel, n, x.dtype, 0, 2)(x, y)


def dot(x, y):
    """<x, y> accumulated across grid steps into a (1,) output."""

    def kernel(x_ref, y_ref, o_ref):
        @pl.when(pl.program_id(0) == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.sum(x_ref[...] * y_ref[...]).reshape((1,))

    n = x.shape[0]
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        grid=_grid(n),
        in_specs=[_vec_spec_n(n), _vec_spec_n(n)],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        interpret=True,
    )(x, y)
