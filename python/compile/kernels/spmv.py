"""L1 Pallas kernel: ELL SpMV — the paper's compute hot-spot, re-thought
for the TPU memory model.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the DPC++ kernel of
the paper assigns a subgroup to a batch of rows, stages x through L1/SLM
and reduces partial products with subgroup shuffles. The Pallas version
expresses the same schedule with BlockSpecs:

* the (k, n) column-major ELL arrays are tiled into (k, ROW_BLOCK) VMEM
  blocks — one grid step per row block (the "subgroup batch");
* the dense vector x stays resident as a whole-VMEM operand — its reuse
  across rows is what DPC++ gets from SLM staging;
* the per-row reduction over the k stored entries is a vectorized axis-0
  sum — the subgroup-shuffle reduction becomes a VPU reduction.

COO SpMV stays at the JAX level (`ref.coo_spmv`: gather + segment_sum).
A scatter-add has no efficient Pallas expression on TPU (no device
atomics); sorted-COO segment-sum is the standard substitution and lowers
to an HLO scatter the runtime executes unchanged.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import os

# Rows per grid step. Lowering-time policy (see blas1.MAX_BLOCK): the CPU
# PJRT backend pays ~0.4 ms per interpret-mode grid step, so the default
# uses up to 64 Ki-row blocks (≤ 16 steps at the largest bucket). For a
# real-TPU lowering set SPARKLE_MAX_BLOCK so that k × ROW_BLOCK × 8 B
# fits VMEM with double buffering (e.g. 1024 rows at k ≤ 128 = 1 MiB
# value tiles; EXPERIMENTS.md §Perf carries the full VMEM table).
MAX_ROW_BLOCK = int(os.environ.get("SPARKLE_MAX_BLOCK", 65536))


def _row_block(n):
    b = min(n, MAX_ROW_BLOCK)
    while n % b != 0:
        b //= 2
    return max(b, 1)


def ell_spmv(vals, cols, x):
    """y = A x with A in (k, n) column-major ELL storage.

    Padding entries carry val 0 / col 0 and therefore contribute nothing;
    that makes the same arrays safe to pad further up to bucket shapes
    (the Rust runtime relies on this invariant).
    """
    k, n = vals.shape

    def kernel(v_ref, c_ref, x_ref, o_ref):
        v = v_ref[...]          # (k, row_block) VMEM block
        c = c_ref[...]
        xv = x_ref[...]         # full x resident (SLM-staging analog)
        o_ref[...] = jnp.sum(v * xv[c], axis=0)

    rb = _row_block(n)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), vals.dtype),
        grid=(n // rb,),
        in_specs=[
            pl.BlockSpec((k, rb), lambda i: (0, i)),
            pl.BlockSpec((k, rb), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb,), lambda i: (i,)),
        interpret=True,
    )(vals, cols, x)


def ell_spmv_advanced(alpha, vals, cols, b, beta, y):
    """y' = alpha * A b + beta * y (scaling fused by XLA around the
    Pallas SpMV core)."""
    return alpha * ell_spmv(vals, cols, b) + beta * y
