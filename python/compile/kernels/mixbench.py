"""L1 Pallas kernel: mixbench-style arithmetic-intensity sweep (Fig. 7).

One kernel per flops-per-element value F: each element receives F/2
fused multiply-adds. Sweeping F moves the kernel along the roofline from
bandwidth-bound to compute-bound — exactly what mixbench does to trace
the experimental roofline of the paper's GPUs.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.blas1 import _grid, _vec_spec_n


def mixbench(x, flops_per_elem):
    """y[i] = fma-chain(x[i]) with `flops_per_elem` flops per element."""
    iters = max(1, flops_per_elem // 2)

    def kernel(x_ref, o_ref):
        s = jnp.asarray(0.999, dtype=x_ref.dtype)
        t = jnp.asarray(0.001, dtype=x_ref.dtype)

        def body(_, y):
            return y * s + t

        o_ref[...] = jax.lax.fori_loop(0, iters, body, x_ref[...])

    n = x.shape[0]
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        grid=_grid(n),
        in_specs=[_vec_spec_n(n)],
        out_specs=_vec_spec_n(n),
        interpret=True,
    )(x)
