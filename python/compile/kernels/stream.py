"""L1 Pallas kernels: the five BabelStream kernels (paper Fig. 6).

Same tiling scheme as blas1.py; `dot` uses the sequential-grid
accumulator. These exist so the ported backend runs the *same* bandwidth
benchmark the paper runs on its GPUs (the fig6 bench also runs them on
the host executors for measured numbers).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.blas1 import _grid, _scalar_spec, _vec_spec_n


def _call(kernel, n, dtype, num_scalars, num_vecs):
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), dtype),
        grid=_grid(n),
        in_specs=[_scalar_spec()] * num_scalars + [_vec_spec_n(n)] * num_vecs,
        out_specs=_vec_spec_n(n),
        interpret=True,
    )


def stream_copy(a):
    """c = a."""

    def kernel(a_ref, o_ref):
        o_ref[...] = a_ref[...]

    return _call(kernel, a.shape[0], a.dtype, 0, 1)(a)


def stream_mul(s, c):
    """b = s * c."""

    def kernel(s_ref, c_ref, o_ref):
        o_ref[...] = s_ref[0] * c_ref[...]

    return _call(kernel, c.shape[0], c.dtype, 1, 1)(s.reshape((1,)), c)


def stream_add(a, b):
    """c = a + b."""

    def kernel(a_ref, b_ref, o_ref):
        o_ref[...] = a_ref[...] + b_ref[...]

    return _call(kernel, a.shape[0], a.dtype, 0, 2)(a, b)


def stream_triad(s, b, c):
    """a = b + s * c."""

    def kernel(s_ref, b_ref, c_ref, o_ref):
        o_ref[...] = b_ref[...] + s_ref[0] * c_ref[...]

    return _call(kernel, b.shape[0], b.dtype, 1, 2)(s.reshape((1,)), b, c)


def stream_dot(a, b):
    """sum(a * b) — the one kernel with a global reduction (the paper
    observes its bandwidth dip on both Intel GPUs)."""

    def kernel(a_ref, b_ref, o_ref):
        @pl.when(pl.program_id(0) == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.sum(a_ref[...] * b_ref[...]).reshape((1,))

    n = a.shape[0]
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((1,), a.dtype),
        grid=_grid(n),
        in_specs=[_vec_spec_n(n), _vec_spec_n(n)],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        interpret=True,
    )(a, b)
