"""AOT lowering: JAX/Pallas kernels + L2 solver steps -> HLO text
artifacts + manifest.tsv.

Emits HLO *text* (NOT .serialize()): jax >= 0.5 serializes HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` 0.1.6 Rust crate links) rejects; the HLO text parser
reassigns ids, so text round-trips cleanly.

Shape buckets must stay in sync with rust/src/runtime/bucket.rs:
  N_BUCKETS       = powers of 4 from 2^8 to 2^20
  K_BUCKETS       = {8, 32, 128}           (ELL widths)
  NNZ_MULTIPLIERS = {4, 16, 64}            (COO nnz = m * n)

Manifest line format: name<TAB>kernel<TAB>dtype<TAB>n<TAB>k<TAB>nnz.

Usage:
    python -m compile.aot --out-dir ../artifacts [--set core|all]
`--set core` skips the stream/mixbench artifacts (faster CI runs).
"""

import argparse
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)  # f64 artifacts need x64

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402
from compile.kernels import blas1, mixbench, ref, spmv, stream  # noqa: E402

N_BUCKETS = [256, 1024, 4096, 16384, 65536, 262144, 1048576]
K_BUCKETS = [8, 32, 128]
NNZ_MULTIPLIERS = [4, 16, 64]
DTYPES = [("f32", jnp.float32), ("f64", jnp.float64)]
MIXBENCH_FLOPS = [1, 4, 16, 64, 256]
MIXBENCH_N = 65536

# The largest ELL buckets are lowered but trade padding for coverage;
# (n, k) pairs above this element count are skipped to bound artifact
# build time and on-disk size (n * k values + indices).
MAX_ELL_ELEMS = 32 * 1024 * 1024


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _tuple_wrap(fn):
    """Ensure the lowered function returns a tuple (uniform unpacking in
    Rust: every artifact's result is a tuple literal)."""

    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


def S(shape, dt):
    return jax.ShapeDtypeStruct(shape, dt)


def build_specs(which="all"):
    """Yield (name, kernel_family, dtype_name, n, k, nnz, fn, input_specs)."""
    specs = []
    for dname, dt in DTYPES:
        sc = S((), dt)
        for n in N_BUCKETS:
            v = S((n,), dt)
            # BLAS-1 — argument order must match rust/src/kernels/xla.rs
            specs.append((f"axpy_{dname}_{n}", "axpy", dname, n, 0, 0,
                          blas1.axpy, [sc, v, v]))
            specs.append((f"axpby_{dname}_{n}", "axpby", dname, n, 0, 0,
                          blas1.axpby, [sc, sc, v, v]))
            specs.append((f"scal_{dname}_{n}", "scal", dname, n, 0, 0,
                          blas1.scal, [sc, v]))
            specs.append((f"dot_{dname}_{n}", "dot", dname, n, 0, 0,
                          blas1.dot, [v, v]))
            specs.append((f"ew_mul_{dname}_{n}", "ew_mul", dname, n, 0, 0,
                          blas1.ew_mul, [v, v]))
            if which == "all":
                specs.append((f"stream_copy_{dname}_{n}", "stream_copy",
                              dname, n, 0, 0, stream.stream_copy, [v]))
                specs.append((f"stream_mul_{dname}_{n}", "stream_mul",
                              dname, n, 0, 0, stream.stream_mul, [sc, v]))
                specs.append((f"stream_add_{dname}_{n}", "stream_add",
                              dname, n, 0, 0, stream.stream_add, [v, v]))
                specs.append((f"stream_triad_{dname}_{n}", "stream_triad",
                              dname, n, 0, 0, stream.stream_triad, [sc, v, v]))
                specs.append((f"stream_dot_{dname}_{n}", "stream_dot",
                              dname, n, 0, 0, stream.stream_dot, [v, v]))
            # ELL SpMV + fused solver steps
            for k in K_BUCKETS:
                if n * k > MAX_ELL_ELEMS:
                    continue
                vals = S((k, n), dt)
                cols = S((k, n), jnp.int32)
                specs.append((f"ell_adv_{dname}_{n}_{k}", "ell_adv",
                              dname, n, k, 0, spmv.ell_spmv_advanced,
                              [sc, vals, cols, v, sc, v]))
                specs.append((f"cg_step_{dname}_{n}_{k}", "cg_step",
                              dname, n, k, 0, model.cg_step,
                              [vals, cols, v, v, v, sc]))
                specs.append((f"bicgstab_step_{dname}_{n}_{k}",
                              "bicgstab_step", dname, n, k, 0,
                              model.bicgstab_step,
                              [vals, cols, v, v, v, v, v, sc, sc, sc]))
                specs.append((f"cgs_step_{dname}_{n}_{k}", "cgs_step",
                              dname, n, k, 0, model.cgs_step,
                              [vals, cols, v, v, v, v, v, sc]))
            # COO SpMV
            for m in NNZ_MULTIPLIERS:
                nnz = m * n
                if nnz > MAX_ELL_ELEMS:
                    continue
                cv = S((nnz,), dt)
                ci = S((nnz,), jnp.int32)
                specs.append((f"coo_adv_{dname}_{n}_{nnz}", "coo_adv",
                              dname, n, 0, nnz, ref.coo_spmv_advanced,
                              [sc, cv, ci, ci, v, sc, v]))
        if which == "all":
            for f in MIXBENCH_FLOPS:
                v = S((MIXBENCH_N,), dt)
                specs.append((
                    f"mixbench{f}_{dname}_{MIXBENCH_N}", f"mixbench{f}",
                    dname, MIXBENCH_N, 0, 0,
                    lambda x, _f=f: mixbench.mixbench(x, _f), [v]))
    return specs


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--set", default="all", choices=["core", "all"])
    parser.add_argument("--force", action="store_true",
                        help="re-lower even if the artifact file exists")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    specs = build_specs(args.set)
    manifest_lines = []
    lowered_count = 0
    for name, kernel, dname, n, k, nnz, fn, in_specs in specs:
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        manifest_lines.append(f"{name}\t{kernel}\t{dname}\t{n}\t{k}\t{nnz}")
        if os.path.exists(path) and not args.force:
            continue
        lowered = jax.jit(_tuple_wrap(fn)).lower(*in_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        lowered_count += 1
        if lowered_count % 25 == 0:
            print(f"  ... {lowered_count} lowered", flush=True)

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"{len(specs)} artifacts registered, {lowered_count} newly lowered "
          f"-> {args.out_dir}/manifest.tsv", flush=True)


if __name__ == "__main__":
    sys.exit(main())
