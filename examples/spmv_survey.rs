//! SpMV survey: one matrix, every format × every executor, with the
//! device-model projection next to the host measurement — a miniature
//! of the paper's §6.3 study runnable in seconds.
//!
//!     cargo run --release --example spmv_survey [suitesparse-name]
//!
//! The optional argument picks a Table-1 matrix (default: thermal2).

use std::sync::Arc;

use sparkle::autotune::AutoMatrix;
use sparkle::bench_util::{f2, Table, Timer};
use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::matgen::{suite, MatrixStats};
use sparkle::matrix::{Coo, Csr, Dense, Ell, Hybrid, SellP};
use sparkle::observe::{Profile, Record};
use sparkle::perfmodel::project::Implementation;
use sparkle::perfmodel::{project_spmv, Device, SpmvKernelKind};
use sparkle::solver::SolverBuilder;
use sparkle::stop::Criterion;
use sparkle::vendor_mkl::VendorCsr;
use sparkle::Dim2;

fn main() -> sparkle::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "thermal2".into());
    let entry = suite::table1_entry(&name).unwrap_or_else(|| {
        eprintln!("unknown matrix `{name}`; available:");
        for e in suite::table1() {
            eprintln!("  {}", e.name);
        }
        std::process::exit(1);
    });
    let scale = 128;
    let data = entry.generate::<f64>(scale);
    let stats = MatrixStats::from_data(&data);
    let full = stats.scaled_to(entry.n_full, entry.nnz_full);
    println!(
        "== SpMV survey: {} ({}; scaled 1/{scale}: n={}, nnz={}) ==\n",
        entry.name, entry.origin, stats.n, stats.nnz
    );

    let mut execs = vec![Executor::reference(), Executor::par()];
    if std::path::Path::new("artifacts/manifest.tsv").exists() {
        execs.push(Executor::xla("artifacts")?);
    }

    let timer = Timer::default();
    let flops = 2.0 * stats.nnz as f64;
    let mut t = Table::new(&["executor", "format", "host GF/s", "||Ax||"]);
    for exec in &execs {
        let b = Dense::filled(exec.clone(), Dim2::new(stats.n, 1), 1.0);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(stats.n, 1));
        let mut run = |fmt: &str, op: &dyn LinOp<f64>| {
            let st = timer.run(|| op.apply(&b, &mut x).unwrap());
            t.row(&[
                exec.name().to_string(),
                fmt.into(),
                f2(st.rate_giga(flops)),
                format!("{:.6}", x.norm2_host()),
            ]);
        };
        run("csr", &Csr::from_data(exec.clone(), &data)?);
        run("coo", &Coo::from_data(exec.clone(), &data)?);
        if stats.max_row < 512 {
            run("ell", &Ell::from_data(exec.clone(), &data)?);
        }
        if !matches!(&**exec, sparkle::Executor::Xla(_)) {
            run("sellp", &SellP::from_data(exec.clone(), &data)?);
            run("hybrid", &Hybrid::from_data(exec.clone(), &data)?);
            run("vendor", &VendorCsr::new(Csr::from_data(exec.clone(), &data)?));
        }
    }
    t.print();

    println!("\n-- automatic format selection (autotune) --");
    for exec in &execs {
        if matches!(&**exec, sparkle::Executor::Xla(_)) {
            continue; // tuning wants host-timed applies
        }
        let auto = AutoMatrix::from_data(exec.clone(), &data)?;
        let b = Dense::filled(exec.clone(), Dim2::new(stats.n, 1), 1.0);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(stats.n, 1));
        let st = timer.run(|| auto.apply(&b, &mut x).unwrap());
        println!(
            "{:>9}: chose {} ({:?}, {} tuning applies) -> {} GF/s",
            exec.name(),
            auto.chosen_format(),
            auto.report().source,
            auto.report().measure_applies,
            f2(st.rate_giga(flops)),
        );
    }

    println!("\n-- device-model projection at published size (n={}, nnz={}) --", full.n, full.nnz);
    let mut t2 = Table::new(&["device", "precision", "csr GF/s", "coo GF/s", "vendor GF/s"]);
    for dev in Device::INTEL {
        let p = if dev == Device::Gen12 {
            sparkle::Precision::Single
        } else {
            sparkle::Precision::Double
        };
        t2.row(&[
            dev.spec().name.to_string(),
            p.to_string(),
            f2(project_spmv(dev, Implementation::Sparkle, SpmvKernelKind::Csr, &full, p).gflops),
            f2(project_spmv(dev, Implementation::Sparkle, SpmvKernelKind::Coo, &full, p).gflops),
            f2(project_spmv(dev, Implementation::Vendor, SpmvKernelKind::Csr, &full, p).gflops),
        ]);
    }
    t2.print();

    // Profiled solve walkthrough: the survey above times SpMV in
    // isolation; here a whole solve runs under an event logger, and
    // the same roofline machinery scores every kernel it dispatched —
    // measured efficiency next to the projections just printed.
    println!("\n-- profiled solve (observe): BiCGSTAB on the par executor --");
    let exec = Executor::par();
    let b = Dense::filled(exec.clone(), Dim2::new(stats.n, 1), 1.0);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(stats.n, 1));
    let rec = Arc::new(Record::new());
    let result = SolverBuilder::bicgstab()
        .with_criterion(Criterion::residual(1e-6, 2000))
        .with_logger(rec.clone())
        .solve_data(&exec, &data, &b, &mut x)?;
    let profile = Profile::from_events(&rec.events(), Device::Gen12, sparkle::Precision::Double);
    profile.summary_table().print();
    println!(
        "converged={} in {} iterations ({} events); best measured SpMV efficiency vs {}: {}",
        result.converged,
        result.iterations,
        rec.len(),
        Device::Gen12.spec().name,
        profile
            .best_spmv_efficiency()
            .map_or("n/a".to_string(), |e| format!("{e:.3}")),
    );

    println!("\nspmv_survey OK");
    Ok(())
}
