//! End-to-end driver: solve a 3-D Poisson problem through the FULL
//! three-layer stack and prove all layers compose.
//!
//! Pipeline exercised:
//!   L1  Pallas ELL SpMV + dot/axpy kernels (AOT artifacts)
//!   L2  fused `cg_step` iteration graph (one HLO per CG iteration)
//!   L3  Rust coordinator: matrix generation, format conversion, solver
//!       drivers, stopping criteria, verification
//!
//! Three solve paths are compared on the same system:
//!   1. composed CG on the `par` executor (pure Rust),
//!   2. composed CG on the `xla` executor (every BLAS-1/SpMV a PJRT
//!      dispatch into an AOT artifact),
//!   3. fused CG on the `xla` executor (one `cg_step` artifact per
//!      iteration — the L2 fusion optimization).
//!
//! The run (convergence + timings + launch counts) is recorded in
//! EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::matgen::stencil;
use sparkle::matrix::{Csr, Dense, Ell};
use sparkle::solver::fused::FusedCg;
use sparkle::solver::{Cg, Solver, SolverConfig};
use sparkle::stop::Criterion;
use sparkle::Dim2;

fn main() -> sparkle::Result<()> {
    let side = 14; // 14^3 = 2744 unknowns
    let data = stencil::stencil_3d::<f64>(side, side, side, 0.0);
    let n = data.dim.rows;
    println!(
        "== end-to-end: 3-D Poisson {side}^3 ({n} unknowns, {} nnz) ==\n",
        data.nnz()
    );
    let crit = Criterion::residual(1e-8, 400);

    // path 1: composed CG, par executor
    let exec = Executor::par();
    let a = Csr::from_data(exec.clone(), &data)?;
    let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
    let mut x1 = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let t0 = Instant::now();
    let r1 = Cg::new(SolverConfig::with_criterion(crit.clone())).solve(&a, &b, &mut x1)?;
    let t1 = t0.elapsed();
    println!(
        "par/composed : {} iters, residual {:.2e}, {:.1} ms",
        r1.iterations,
        r1.resnorm,
        t1.as_secs_f64() * 1e3
    );

    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        println!("artifacts/ missing -> run `make artifacts` for the XLA paths");
        return Ok(());
    }

    // path 2: composed CG, xla executor (every op is a PJRT dispatch)
    let xexec = Executor::xla("artifacts")?;
    let rt = xexec.xla_runtime().unwrap().clone();
    let ax = Csr::from_data(xexec.clone(), &data)?;
    let bx = Dense::filled(xexec.clone(), Dim2::new(n, 1), 1.0);
    let mut x2 = Dense::zeros(xexec.clone(), Dim2::new(n, 1));
    let launches0 = rt.launch_count();
    let t0 = Instant::now();
    let r2 = Cg::new(SolverConfig::with_criterion(crit.clone())).solve(&ax, &bx, &mut x2)?;
    let t2 = t0.elapsed();
    let l2 = rt.launch_count() - launches0;
    println!(
        "xla/composed : {} iters, residual {:.2e}, {:.1} ms, {} PJRT launches ({:.1}/iter)",
        r2.iterations,
        r2.resnorm,
        t2.as_secs_f64() * 1e3,
        l2,
        l2 as f64 / r2.iterations.max(1) as f64
    );

    // path 3: fused cg_step artifact (the L2 fusion)
    let ell = Ell::from_data(xexec.clone(), &data)?;
    let mut x3 = Dense::zeros(xexec.clone(), Dim2::new(n, 1));
    let launches0 = rt.launch_count();
    let t0 = Instant::now();
    let r3 = FusedCg::new(SolverConfig::with_criterion(crit)).solve(&ell, &bx, &mut x3)?;
    let t3 = t0.elapsed();
    let l3 = rt.launch_count() - launches0;
    println!(
        "xla/fused    : {} iters, residual {:.2e}, {:.1} ms, {} PJRT launches ({:.1}/iter)",
        r3.iterations,
        r3.resnorm,
        t3.as_secs_f64() * 1e3,
        l3,
        l3 as f64 / r3.iterations.max(1) as f64
    );

    // all three must agree with each other and actually solve the system
    for (name, x, r) in [("par", &x1, &r1), ("xla", &x2, &r2), ("fused", &x3, &r3)] {
        assert!(r.converged, "{name} did not converge");
        let mut resid = b.to_executor(exec.clone());
        let a_check = Csr::from_data(exec.clone(), &data)?;
        let x_host = x.to_executor(exec.clone());
        a_check.apply_advanced(-1.0, &x_host, 1.0, &mut resid)?;
        let rel = resid.norm2_host() / b.norm2_host();
        println!("{name:>5}: true relative residual {rel:.2e}");
        assert!(rel < 1e-7, "{name} residual too large: {rel}");
    }
    println!("\nall three paths converge to the same solution — L1/L2/L3 compose. OK");
    Ok(())
}
