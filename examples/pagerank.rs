//! PageRank on a power-law web graph — the §5 motivation that SpMV
//! "identifies all immediate neighbors of a node" and powers the
//! PageRank power iteration.
//!
//! Builds a circuit-generator-style power-law digraph, column-normalizes
//! it into a stochastic operator, and runs the damped power iteration
//! `r' = d·Aᵀr + (1-d)/n` using the library's COO SpMV on the chosen
//! executor (xla if artifacts exist, else par).

use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::core::matrix_data::MatrixData;
use sparkle::kernels::blas;
use sparkle::matrix::{Coo, Dense};
use sparkle::testing::prng::Prng;
use sparkle::Dim2;

const DAMPING: f64 = 0.85;

/// Power-law digraph, column-stochastic (transposed link matrix).
fn web_graph(n: usize, avg_degree: usize, seed: u64) -> MatrixData<f64> {
    let mut rng = Prng::new(seed);
    let mut outlinks: Vec<Vec<i32>> = vec![Vec::new(); n];
    for (page, links) in outlinks.iter_mut().enumerate() {
        // preferential-attachment-flavored targets: low indices are hubs
        let deg = 1 + (rng.pareto(avg_degree as f64 / 2.0, 1.3) as usize).min(n / 4);
        for _ in 0..deg {
            let target = if rng.unit() < 0.3 {
                rng.below((n / 20).max(1)) // hub
            } else {
                rng.below(n)
            };
            if target != page {
                links.push(target as i32);
            }
        }
        links.sort_unstable();
        links.dedup();
    }
    // transposed + column-normalized: entry (target, source) = 1/outdeg
    let mut data = MatrixData::new(Dim2::square(n));
    for (page, links) in outlinks.iter().enumerate() {
        let w = 1.0 / links.len().max(1) as f64;
        for &t in links {
            data.push(t, page as i32, w);
        }
    }
    data.normalize();
    data
}

fn main() -> sparkle::Result<()> {
    let n = 20_000;
    let data = web_graph(n, 8, 2021);
    println!(
        "== PageRank: {n} pages, {} links, damping {DAMPING} ==",
        data.nnz()
    );

    let exec = if std::path::Path::new("artifacts/manifest.tsv").exists() {
        println!("running on the xla (ported) executor");
        Executor::xla("artifacts")?
    } else {
        println!("artifacts/ missing -> running on the par executor");
        Executor::par()
    };

    let a = Coo::from_data(exec.clone(), &data)?;
    let mut rank = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0 / n as f64);
    let mut next = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let teleport = Dense::filled(exec.clone(), Dim2::new(n, 1), (1.0 - DAMPING) / n as f64);

    let t0 = std::time::Instant::now();
    let mut iters = 0;
    loop {
        // next = d * A rank + teleport
        next.copy_from(&teleport)?;
        a.apply_advanced(DAMPING, &rank, 1.0, &mut next)?;
        // re-normalize the dangling-page mass (columns with no outlinks)
        let mass = blas::dot(&exec, &next, &Dense::filled(exec.clone(), next.shape(), 1.0))?;
        blas::scal(&exec, 1.0 / mass, &mut next)?;
        // L1-ish convergence via norm of the update
        let mut delta = next.clone();
        blas::axpy(&exec, -1.0, &rank, &mut delta)?;
        let change = blas::norm2(&exec, &delta)?;
        rank.copy_from(&next)?;
        iters += 1;
        if change < 1e-10 || iters >= 200 {
            break;
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!("converged in {iters} iterations, {:.1} ms", secs * 1e3);

    // report the top pages — hubs (low indices) must dominate
    let mut ranked: Vec<(usize, f64)> = rank
        .as_slice()
        .iter()
        .enumerate()
        .map(|(i, &v)| (i, v))
        .collect();
    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top 5 pages:");
    for (page, score) in ranked.iter().take(5) {
        println!("  page {page:>6}: {score:.6}");
    }
    let hub_in_top = ranked.iter().take(10).filter(|(i, _)| *i < n / 20).count();
    assert!(
        hub_in_top >= 5,
        "power-law hubs should dominate the top ranks ({hub_in_top}/10)"
    );
    let sum: f64 = rank.as_slice().iter().sum();
    assert!((sum - 1.0).abs() < 1e-6, "ranks must stay a distribution");
    println!("pagerank OK");
    Ok(())
}
