//! Quickstart: assemble a sparse matrix, convert formats, run SpMV on
//! every executor, and solve a small system with CG.
//!
//!     cargo run --release --example quickstart
//!
//! (The XLA executor needs `make artifacts` once; the example skips it
//! gracefully when artifacts are missing.)

use std::sync::Arc;

use sparkle::autotune::AutoMatrix;
use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::matgen::stencil;
use sparkle::matrix::{Coo, Csr, Dense, Ell};
use sparkle::observe::{Profile, Record};
use sparkle::perfmodel::Device;
use sparkle::resilience::{FaultSpec, FaultyOp, ResilientSolver};
use sparkle::solver::{Cg, Solver, SolverBuilder, SolverConfig};
use sparkle::stop::Criterion;
use sparkle::{Dim2, Precision};

fn main() -> sparkle::Result<()> {
    // 1. assemble: a 2-D Poisson problem on a 32x32 grid
    let data = stencil::laplace_2d::<f64>(32, 32);
    let n = data.dim.rows;
    println!("matrix: {} rows, {} nonzeros", n, data.nnz());

    // 2. executors: reference (oracle), par (host threads), xla (the
    //    AOT JAX/Pallas "ported" backend via PJRT)
    let mut executors = vec![Executor::reference(), Executor::par()];
    if std::path::Path::new("artifacts/manifest.tsv").exists() {
        executors.push(Executor::xla("artifacts")?);
    } else {
        println!("(artifacts/ missing -> skipping the xla executor; run `make artifacts`)");
    }

    // 3. one SpMV per executor and format — identical numerics everywhere
    for exec in &executors {
        let csr = Csr::from_data(exec.clone(), &data)?;
        let coo = Coo::from_data(exec.clone(), &data)?;
        let ell = Ell::from_data(exec.clone(), &data)?;
        let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        csr.apply(&b, &mut x)?;
        let csr_norm = x.norm2_host();
        coo.apply(&b, &mut x)?;
        let coo_norm = x.norm2_host();
        ell.apply(&b, &mut x)?;
        let ell_norm = x.norm2_host();
        println!(
            "executor {:>9}: ||A·1|| = {csr_norm:.6} (csr) {coo_norm:.6} (coo) {ell_norm:.6} (ell)",
            exec.name()
        );
        assert!((csr_norm - coo_norm).abs() < 1e-9 && (csr_norm - ell_norm).abs() < 1e-9);
    }

    // 4. solve A x = b with CG on the parallel executor
    let exec = Executor::par();
    let a = Csr::from_data(exec.clone(), &data)?;
    let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let result = Cg::new(SolverConfig::with_criterion(Criterion::residual(1e-10, 1000)))
        .solve(&a, &b, &mut x)?;
    println!(
        "CG: converged={} in {} iterations, residual {:.3e}",
        result.converged, result.iterations, result.resnorm
    );
    assert!(result.converged);

    // 5. automatic format selection: let the autotuner pick the storage
    //    format (features -> roofline prior -> top-k measurement), then
    //    use it like any other operator — or skip the ceremony entirely
    //    with `solve_data`
    let auto = AutoMatrix::from_data(exec.clone(), &data)?;
    println!(
        "autotune chose {} (source {:?}, {} measurement applies)",
        auto.chosen_format(),
        auto.report().source,
        auto.report().measure_applies
    );
    let mut xa = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let auto_result = Cg::new(SolverConfig::with_criterion(Criterion::residual(1e-10, 1000)))
        .solve_data(&exec, &data, &b, &mut xa)?;
    assert!(auto_result.converged);
    println!(
        "CG via solve_data: converged={} in {} iterations",
        auto_result.converged, auto_result.iterations
    );

    // 6. resilient solving: wrap the operator in a seeded fault injector
    //    (NaN payloads + transient failures), then let ResilientSolver
    //    checkpoint, verify the true residual, roll back and retry. The
    //    reported residual is the *verified* ||b - A x||, never the
    //    recurrence's claim.
    let faulty = FaultyOp::new(
        Csr::from_data(exec.clone(), &data)?,
        FaultSpec {
            seed: 42,
            nan_prob: 0.02,
            transient_prob: 0.02,
            max_faults: 3,
            armed_after: 5,
            ..FaultSpec::default()
        },
    );
    let mut xr = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let resilient = ResilientSolver::new(Criterion::residual(1e-10, 5000));
    let outcome = resilient.solve_outcome(&faulty, &b, &mut xr)?;
    println!(
        "resilient {}: converged={} (recovered={}) in {} iterations, \
         {} restarts / {} fallbacks, verified residual {:.3e}",
        outcome.solver,
        outcome.result.converged,
        outcome.recovered(),
        outcome.result.iterations,
        outcome.restarts,
        outcome.fallbacks,
        outcome.true_resnorm
    );
    for event in &outcome.events {
        println!("  recovery event: {event:?}");
    }
    assert!(outcome.result.converged);

    // 7. observability: SolverBuilder is the unified entry point (it
    //    subsumes steps 4-6: plain solve, solve_data, resilient), and
    //    with_logger scopes an event logger to the solve. Aggregating
    //    the recorded events against a device roofline yields a
    //    per-kernel profile — the paper's VTune tables, in-library.
    let rec = Arc::new(Record::new());
    let mut xo = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let observed = SolverBuilder::cg()
        .with_criterion(Criterion::residual(1e-10, 1000))
        .with_logger(rec.clone())
        .solve(&a, &b, &mut xo)?;
    assert!(observed.converged);
    let profile = Profile::from_events(&rec.events(), Device::Gen12, Precision::Double);
    println!(
        "profiled CG: {} events recorded, {} distinct kernels",
        rec.len(),
        profile.kernels.len()
    );
    profile.summary_table().print();
    if let Some(eff) = profile.best_spmv_efficiency() {
        println!(
            "best SpMV roofline efficiency vs {}: {eff:.3}",
            profile.device.spec().name
        );
    }

    println!("quickstart OK");
    Ok(())
}
