//! The linear operator abstraction (Ginkgo's `LinOp`).
//!
//! Everything that can be applied to a vector — sparse matrices in any
//! format, preconditioners, and generated solvers — implements [`LinOp`].
//! This is the "generic algorithm skeletons in core, kernels in backends"
//! design of the paper's Figure 1.

use std::sync::Arc;

use crate::core::dim::Dim2;
use crate::core::error::{Result, SparkleError};
use crate::core::executor::Executor;
use crate::core::types::Value;
use crate::matrix::dense::Dense;

/// A linear operator `A : R^cols -> R^rows`.
///
/// Not `Send`/`Sync`: the XLA executor wraps the PJRT client which is
/// reference-counted non-atomically inside the `xla` crate. Parallelism
/// lives *inside* kernels (scoped threads over data slices), never by
/// sharing operators across threads.
pub trait LinOp<T: Value> {
    /// Operator dimensions.
    fn shape(&self) -> Dim2;

    /// Executor the operator's kernels run on.
    fn executor(&self) -> &Arc<Executor>;

    /// x = A · b
    fn apply(&self, b: &Dense<T>, x: &mut Dense<T>) -> Result<()>;

    /// x = alpha · A · b + beta · x  (Ginkgo's `apply(alpha, b, beta, x)`).
    ///
    /// Default implementation composes `apply` with BLAS-1; formats
    /// override it with a fused kernel.
    fn apply_advanced(&self, alpha: T, b: &Dense<T>, beta: T, x: &mut Dense<T>) -> Result<()> {
        let exec = self.executor().clone();
        let mut tmp = Dense::zeros(exec, x.shape());
        self.apply(b, &mut tmp)?;
        crate::kernels::blas::scal(self.executor(), beta, x)?;
        crate::kernels::blas::axpy(self.executor(), alpha, &tmp, x)?;
        Ok(())
    }

    /// x = A · b, returning `(w·x, x·x)` — the dominant Krylov pattern
    /// (`q = A p` with `p·q`, or `t = A s` with `t·s` and `t·t`).
    ///
    /// Default implementation composes `apply` with `dot_norm2`; the
    /// sparse formats override it with a fused SpMV+reduction kernel
    /// that reads `x` once instead of twice.
    fn apply_dot(&self, b: &Dense<T>, x: &mut Dense<T>, w: &Dense<T>) -> Result<(T, T)> {
        self.apply(b, x)?;
        crate::kernels::blas::dot_norm2(self.executor(), w, x)
    }

    /// Human-readable operator name for logs and benches.
    fn op_name(&self) -> &'static str {
        "linop"
    }

    /// Validate that `b`, `x` conform with this operator.
    fn check_conformant(&self, b: &Dense<T>, x: &Dense<T>) -> Result<()> {
        let dim = self.shape();
        if b.shape().rows != dim.cols || x.shape().rows != dim.rows {
            return Err(SparkleError::dim(
                "apply",
                format!(
                    "A is {}, b is {}, x is {}",
                    dim,
                    b.shape(),
                    x.shape()
                ),
            ));
        }
        if b.shape().cols != x.shape().cols {
            return Err(SparkleError::dim(
                "apply",
                format!("b has {} rhs, x has {}", b.shape().cols, x.shape().cols),
            ));
        }
        Ok(())
    }
}
