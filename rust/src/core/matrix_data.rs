//! Device-agnostic sparse assembly container (Ginkgo's `matrix_data`).
//!
//! All matrix generators and the MatrixMarket reader produce a
//! [`MatrixData`]; every concrete format (`Coo`, `Csr`, `Ell`, ...) is
//! constructed *from* it. This is the single point where structure is
//! validated, sorted and deduplicated.

use crate::core::dim::Dim2;
use crate::core::error::{Result, SparkleError};
use crate::core::types::{IndexType, Value};

/// One nonzero entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry<T> {
    pub row: IndexType,
    pub col: IndexType,
    pub val: T,
}

/// Sparse matrix in assembly (triplet) form.
#[derive(Debug, Clone, Default)]
pub struct MatrixData<T> {
    pub dim: Dim2,
    /// Entries; use [`MatrixData::normalize`] to sort + combine duplicates.
    pub entries: Vec<Entry<T>>,
}

impl<T: Value> MatrixData<T> {
    /// Empty container of the given dimension.
    pub fn new(dim: Dim2) -> Self {
        Self {
            dim,
            entries: Vec::new(),
        }
    }

    /// Build from parallel triplet slices.
    pub fn from_triplets(
        dim: Dim2,
        rows: &[IndexType],
        cols: &[IndexType],
        vals: &[T],
    ) -> Result<Self> {
        if rows.len() != cols.len() || rows.len() != vals.len() {
            return Err(SparkleError::InvalidStructure(format!(
                "triplet arrays disagree: rows={} cols={} vals={}",
                rows.len(),
                cols.len(),
                vals.len()
            )));
        }
        let mut data = Self::new(dim);
        data.entries.reserve(rows.len());
        for i in 0..rows.len() {
            data.push(rows[i], cols[i], vals[i]);
        }
        data.validate()?;
        Ok(data)
    }

    /// Append one entry (no validation until [`MatrixData::validate`]).
    pub fn push(&mut self, row: IndexType, col: IndexType, val: T) {
        self.entries.push(Entry { row, col, val });
    }

    /// Number of stored entries (before dedup this may over-count).
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Check all indices are in-bounds.
    pub fn validate(&self) -> Result<()> {
        for e in &self.entries {
            if e.row < 0
                || e.col < 0
                || e.row as usize >= self.dim.rows
                || e.col as usize >= self.dim.cols
            {
                return Err(SparkleError::InvalidStructure(format!(
                    "entry ({}, {}) out of bounds for {}",
                    e.row, e.col, self.dim
                )));
            }
        }
        Ok(())
    }

    /// Sort row-major and sum duplicate coordinates. Zero entries produced
    /// by cancellation are kept (Ginkgo keeps explicit zeros too).
    pub fn normalize(&mut self) {
        self.entries
            .sort_unstable_by_key(|e| (e.row, e.col));
        let mut out: Vec<Entry<T>> = Vec::with_capacity(self.entries.len());
        for e in self.entries.drain(..) {
            match out.last_mut() {
                Some(last) if last.row == e.row && last.col == e.col => {
                    last.val += e.val;
                }
                _ => out.push(e),
            }
        }
        self.entries = out;
    }

    /// True if sorted row-major with unique coordinates.
    pub fn is_normalized(&self) -> bool {
        self.entries
            .windows(2)
            .all(|w| (w[0].row, w[0].col) < (w[1].row, w[1].col))
    }

    /// Number of nonzeros per row (requires in-bounds entries).
    pub fn row_lengths(&self) -> Vec<usize> {
        let mut lens = vec![0usize; self.dim.rows];
        for e in &self.entries {
            lens[e.row as usize] += 1;
        }
        lens
    }

    /// Longest row.
    pub fn max_row_length(&self) -> usize {
        self.row_lengths().into_iter().max().unwrap_or(0)
    }

    /// Make structurally symmetric by inserting the transposed pattern
    /// (values averaged). Used by generators for FEM-like matrices.
    pub fn symmetrize(&mut self) {
        let mut extra = Vec::with_capacity(self.entries.len());
        for e in &self.entries {
            if e.row != e.col {
                extra.push(Entry {
                    row: e.col,
                    col: e.row,
                    val: e.val,
                });
            }
        }
        self.entries.extend(extra);
        self.normalize();
        // average the summed off-diagonal pairs
        for e in &mut self.entries {
            if e.row != e.col {
                e.val = e.val * T::from_f64(0.5);
            }
        }
    }

    /// Add `shift` to every diagonal entry, inserting missing diagonals.
    /// Generators use this to force diagonal dominance (solver-friendly).
    pub fn shift_diagonal(&mut self, shift: T) {
        let n = self.dim.rows.min(self.dim.cols);
        let mut present = vec![false; n];
        for e in &mut self.entries {
            if e.row == e.col {
                e.val += shift;
                present[e.row as usize] = true;
            }
        }
        for (i, has) in present.iter().enumerate() {
            if !has {
                self.push(i as IndexType, i as IndexType, shift);
            }
        }
        self.normalize();
    }

    /// Transposed copy (rows and columns swapped, re-normalized).
    pub fn transpose(&self) -> MatrixData<T> {
        let mut out = MatrixData::new(self.dim.transposed());
        out.entries.reserve(self.entries.len());
        for e in &self.entries {
            out.push(e.col, e.row, e.val);
        }
        out.normalize();
        out
    }

    /// Convert values to another precision.
    pub fn convert<U: Value>(&self) -> MatrixData<U> {
        MatrixData {
            dim: self.dim,
            entries: self
                .entries
                .iter()
                .map(|e| Entry {
                    row: e.row,
                    col: e.col,
                    val: U::from_f64(e.val.as_f64()),
                })
                .collect(),
        }
    }

    /// Dense row-major materialization — only for tests / tiny matrices.
    pub fn to_dense_vec(&self) -> Vec<T> {
        let mut out = vec![T::zero(); self.dim.count()];
        for e in &self.entries {
            out[e.row as usize * self.dim.cols + e.col as usize] += e.val;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MatrixData<f64> {
        // [[2, 1, 0],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        MatrixData::from_triplets(
            Dim2::square(3),
            &[0, 0, 1, 2, 2],
            &[0, 1, 1, 0, 2],
            &[2.0, 1.0, 3.0, 4.0, 5.0],
        )
        .unwrap()
    }

    #[test]
    fn from_triplets_and_dense() {
        let d = sample();
        assert_eq!(d.nnz(), 5);
        assert_eq!(
            d.to_dense_vec(),
            vec![2.0, 1.0, 0.0, 0.0, 3.0, 0.0, 4.0, 0.0, 5.0]
        );
    }

    #[test]
    fn mismatched_triplets_rejected() {
        let r = MatrixData::<f64>::from_triplets(Dim2::square(2), &[0], &[0, 1], &[1.0]);
        assert!(r.is_err());
    }

    #[test]
    fn out_of_bounds_rejected() {
        let r = MatrixData::from_triplets(Dim2::square(2), &[0, 5], &[0, 0], &[1.0, 1.0]);
        assert!(r.is_err());
        let r = MatrixData::from_triplets(Dim2::square(2), &[0, -1], &[0, 0], &[1.0, 1.0]);
        assert!(r.is_err());
    }

    #[test]
    fn normalize_sorts_and_sums_duplicates() {
        let mut d = MatrixData::new(Dim2::square(2));
        d.push(1, 1, 5.0);
        d.push(0, 0, 1.0);
        d.push(1, 1, 2.0);
        assert!(!d.is_normalized());
        d.normalize();
        assert!(d.is_normalized());
        assert_eq!(d.nnz(), 2);
        assert_eq!(d.entries[1].val, 7.0);
    }

    #[test]
    fn row_lengths_and_max() {
        let d = sample();
        assert_eq!(d.row_lengths(), vec![2, 1, 2]);
        assert_eq!(d.max_row_length(), 2);
    }

    #[test]
    fn symmetrize_makes_pattern_symmetric() {
        let mut d = sample();
        d.symmetrize();
        let dense = d.to_dense_vec();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(dense[i * 3 + j], dense[j * 3 + i], "({i},{j})");
            }
        }
        // (0,1) had 1.0, (1,0) had 0 -> both become 0.5
        assert_eq!(dense[1], 0.5);
    }

    #[test]
    fn shift_diagonal_inserts_missing() {
        let mut d = MatrixData::<f64>::new(Dim2::square(2));
        d.push(0, 1, 1.0);
        d.shift_diagonal(10.0);
        let dense = d.to_dense_vec();
        assert_eq!(dense, vec![10.0, 1.0, 0.0, 10.0]);
    }

    #[test]
    fn transpose_swaps_image() {
        let d = sample();
        let t = d.transpose();
        assert_eq!(t.dim, d.dim.transposed());
        let dd = d.to_dense_vec();
        let td = t.to_dense_vec();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(dd[i * 3 + j], td[j * 3 + i]);
            }
        }
        // double transpose is identity
        assert_eq!(t.transpose().to_dense_vec(), dd);
    }

    #[test]
    fn transpose_rectangular() {
        let mut d = MatrixData::<f64>::new(Dim2::new(2, 4));
        d.push(0, 3, 5.0);
        d.push(1, 0, -1.0);
        let t = d.transpose();
        assert_eq!(t.dim, Dim2::new(4, 2));
        let td = t.to_dense_vec();
        assert_eq!(td[3 * 2], 5.0); // (3,0)
        assert_eq!(td[1], -1.0); // (0,1)
    }

    #[test]
    fn precision_conversion() {
        let d = sample();
        let s: MatrixData<f32> = d.convert();
        assert_eq!(s.entries[0].val, 2.0f32);
        assert_eq!(s.dim, d.dim);
    }
}
