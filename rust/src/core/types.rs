//! Scalar value types supported by the library.
//!
//! The paper evaluates IEEE 754 double precision (GEN9) and single
//! precision (GEN12, which lacks native fp64). `Value` abstracts the two
//! so every format / kernel / solver is generic over precision, mirroring
//! Ginkgo's `ValueType` template parameter.

use std::fmt::{Debug, Display};

/// Index type used in all sparse structures (Ginkgo's `IndexType=int32`).
///
/// 32-bit indices match what both Ginkgo and oneMKL use on GPUs and what
/// the AOT kernel artifacts expect (`int32` columns/rows).
pub type IndexType = i32;

/// Precision tag, used by the performance model and artifact naming.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// IEEE 754 binary64.
    Double,
    /// IEEE 754 binary32.
    Single,
    /// IEEE 754 binary16 — only used by the roofline model (Fig. 7);
    /// no kernels are instantiated at half precision.
    Half,
}

impl Precision {
    /// Size of one scalar in bytes.
    pub fn bytes(self) -> usize {
        match self {
            Precision::Double => 8,
            Precision::Single => 4,
            Precision::Half => 2,
        }
    }

    /// Short name used in artifact files and benchmark tables.
    pub fn name(self) -> &'static str {
        match self {
            Precision::Double => "f64",
            Precision::Single => "f32",
            Precision::Half => "f16",
        }
    }
}

impl Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Scalar type every format/kernel/solver is generic over.
///
/// `xla::ArrayElement` lets the runtime move values into device-resident
/// PJRT buffers directly (the zero-re-marshalling SpMV path).
pub trait Value:
    num_traits::Float
    + num_traits::NumAssign
    + xla::ArrayElement
    + Debug
    + Display
    + Default
    + Copy
    + Send
    + Sync
    + 'static
{
    /// Precision tag for this type.
    const PRECISION: Precision;

    /// Lossless widen to f64 (named `as_f64` to avoid colliding with num_traits::ToPrimitive) (for residual norms, statistics, projections).
    fn as_f64(self) -> f64;
    /// Narrowing conversion from f64.
    fn from_f64(v: f64) -> Self;

    /// Build an XLA literal from a slice of this type.
    fn literal_vec(v: &[Self]) -> xla::Literal;
    /// Read an XLA literal back into a vec of this type.
    fn literal_to_vec(l: &xla::Literal) -> std::result::Result<Vec<Self>, xla::Error>;

    /// Relative tolerance appropriate for comparisons at this precision.
    fn cmp_tol() -> f64 {
        match Self::PRECISION {
            Precision::Double => 1e-12,
            Precision::Single => 1e-5,
            Precision::Half => 1e-2,
        }
    }
}

impl Value for f64 {
    const PRECISION: Precision = Precision::Double;

    fn as_f64(self) -> f64 {
        self
    }
    fn from_f64(v: f64) -> Self {
        v
    }
    fn literal_vec(v: &[Self]) -> xla::Literal {
        xla::Literal::vec1(v)
    }
    fn literal_to_vec(l: &xla::Literal) -> std::result::Result<Vec<Self>, xla::Error> {
        l.to_vec::<f64>()
    }
}

impl Value for f32 {
    const PRECISION: Precision = Precision::Single;

    fn as_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    fn literal_vec(v: &[Self]) -> xla::Literal {
        xla::Literal::vec1(v)
    }
    fn literal_to_vec(l: &xla::Literal) -> std::result::Result<Vec<Self>, xla::Error> {
        l.to_vec::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_bytes() {
        assert_eq!(Precision::Double.bytes(), 8);
        assert_eq!(Precision::Single.bytes(), 4);
        assert_eq!(Precision::Half.bytes(), 2);
    }

    #[test]
    fn precision_names() {
        assert_eq!(Precision::Double.name(), "f64");
        assert_eq!(f32::PRECISION.name(), "f32");
        assert_eq!(f64::PRECISION, Precision::Double);
    }

    #[test]
    fn round_trip_f64() {
        assert_eq!(f64::from_f64(2.5).as_f64(), 2.5);
        assert_eq!(f32::from_f64(2.5).as_f64(), 2.5);
    }

    #[test]
    fn generic_sum() {
        fn sum<T: Value>(v: &[T]) -> T {
            v.iter().fold(T::zero(), |a, &b| a + b)
        }
        assert_eq!(sum(&[1.0f32, 2.0, 3.0]), 6.0);
        assert_eq!(sum(&[1.0f64, 2.0, 3.0]), 6.0);
    }

    #[test]
    fn tolerances_ordered() {
        assert!(f64::cmp_tol() < f32::cmp_tol());
    }
}
