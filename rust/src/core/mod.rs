//! Core abstractions: types, executors, dimensions, assembly data, and
//! the `LinOp` interface (the "core" library of the paper's Figure 1).

pub mod dim;
pub mod error;
pub mod executor;
pub mod linop;
pub mod matrix_data;
pub mod types;
