//! Two-dimensional size descriptor (Ginkgo's `dim<2>`).

use std::fmt;

/// Rows × columns of a linear operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Dim2 {
    pub rows: usize,
    pub cols: usize,
}

impl Dim2 {
    /// Construct a rows × cols dimension.
    pub fn new(rows: usize, cols: usize) -> Self {
        Self { rows, cols }
    }

    /// Square dimension n × n.
    pub fn square(n: usize) -> Self {
        Self { rows: n, cols: n }
    }

    /// Total number of entries a dense operator of this dim would hold.
    pub fn count(&self) -> usize {
        self.rows * self.cols
    }

    /// True if rows == cols.
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Transposed dimension.
    pub fn transposed(&self) -> Self {
        Self {
            rows: self.cols,
            cols: self.rows,
        }
    }
}

impl fmt::Display for Dim2 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}", self.rows, self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction() {
        let d = Dim2::new(3, 4);
        assert_eq!(d.rows, 3);
        assert_eq!(d.cols, 4);
        assert_eq!(d.count(), 12);
        assert!(!d.is_square());
        assert!(Dim2::square(5).is_square());
    }

    #[test]
    fn transpose_and_display() {
        let d = Dim2::new(3, 4);
        assert_eq!(d.transposed(), Dim2::new(4, 3));
        assert_eq!(d.to_string(), "3x4");
    }
}
