//! Executors: where kernels run.
//!
//! Mirrors Ginkgo's executor model (§2 of the paper): the executor is the
//! "handle" controlling kernel execution and memory, and switching the
//! executor switches the backend implementation of every operation at
//! runtime. The sparkle analogs are:
//!
//! | Ginkgo        | sparkle            | implementation                         |
//! |---------------|--------------------|----------------------------------------|
//! | `reference`   | [`Executor::Reference`] | sequential Rust kernels (oracle)  |
//! | `omp`         | [`Executor::Par`]  | multithreaded Rust (std scoped threads) |
//! | `dpcpp` (new) | [`Executor::Xla`]  | AOT JAX/Pallas HLO via PJRT — the "ported backend" this paper is about |
//!
//! The CUDA/HIP backends of the paper exist only inside the performance
//! model (`perfmodel`), since no NVIDIA/AMD hardware is attached.

use std::sync::Arc;

use crate::core::error::Result;
use crate::runtime::XlaRuntime;

/// Configuration of the parallel (OpenMP-analog) executor.
#[derive(Debug, Clone)]
pub struct ParConfig {
    /// Number of worker threads; `0` = one per available core.
    pub threads: usize,
    /// Rows below this size run sequentially (parallel overhead guard).
    pub seq_threshold: usize,
}

impl Default for ParConfig {
    fn default() -> Self {
        Self {
            threads: 0,
            seq_threshold: 4096,
        }
    }
}

impl ParConfig {
    /// Effective number of threads.
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }
}

/// An execution backend. Every matrix/vector object and every solver holds
/// an `Arc<Executor>`; kernels dispatch on the variant.
pub enum Executor {
    /// Sequential reference kernels — correctness oracle for everything.
    Reference,
    /// Multithreaded host kernels (the `omp` analog).
    Par(ParConfig),
    /// The ported accelerator backend: AOT-compiled JAX/Pallas artifacts
    /// executed through the PJRT C API (the `dpcpp` analog).
    Xla(XlaExec),
}

/// State of the XLA executor.
pub struct XlaExec {
    /// Shared PJRT runtime + compile cache.
    pub runtime: Arc<XlaRuntime>,
}

impl Executor {
    /// Sequential reference executor.
    pub fn reference() -> Arc<Self> {
        Arc::new(Executor::Reference)
    }

    /// Parallel host executor with default configuration.
    pub fn par() -> Arc<Self> {
        Arc::new(Executor::Par(ParConfig::default()))
    }

    /// Parallel host executor with an explicit thread count.
    pub fn par_with_threads(threads: usize) -> Arc<Self> {
        Arc::new(Executor::Par(ParConfig {
            threads,
            ..ParConfig::default()
        }))
    }

    /// XLA executor reading artifacts from `artifact_dir`.
    pub fn xla(artifact_dir: impl AsRef<std::path::Path>) -> Result<Arc<Self>> {
        let runtime = Arc::new(XlaRuntime::new(artifact_dir)?);
        Ok(Arc::new(Executor::Xla(XlaExec { runtime })))
    }

    /// XLA executor sharing an existing runtime.
    pub fn xla_with_runtime(runtime: Arc<XlaRuntime>) -> Arc<Self> {
        Arc::new(Executor::Xla(XlaExec { runtime }))
    }

    /// Short name used in logs and benchmark tables.
    pub fn name(&self) -> &'static str {
        match self {
            Executor::Reference => "reference",
            Executor::Par(_) => "par",
            Executor::Xla(_) => "xla",
        }
    }

    /// Access the XLA runtime if this is an XLA executor.
    pub fn xla_runtime(&self) -> Option<&Arc<XlaRuntime>> {
        match self {
            Executor::Xla(x) => Some(&x.runtime),
            _ => None,
        }
    }
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Executor::{}", self.name())
    }
}

/// Split `len` items into per-thread chunks and run `body(thread_id,
/// start, end)` on scoped threads. The workhorse of every `par` kernel.
///
/// `body` must be safe to run concurrently on disjoint `[start, end)`
/// ranges; kernels achieve this by splitting output rows.
pub fn par_for<F>(cfg: &ParConfig, len: usize, body: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let threads = cfg.effective_threads().max(1);
    if len == 0 {
        return;
    }
    if threads == 1 || len <= cfg.seq_threshold {
        body(0, 0, len);
        return;
    }
    let chunk = len.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(len);
            if start >= end {
                break;
            }
            let body = &body;
            s.spawn(move || body(t, start, end));
        }
    });
}

/// Per-thread partial reduction: runs `body(start, end) -> acc` on scoped
/// threads and combines the partials with `combine`.
pub fn par_reduce<A, F, C>(cfg: &ParConfig, len: usize, identity: A, body: F, combine: C) -> A
where
    A: Send,
    F: Fn(usize, usize) -> A + Sync,
    C: Fn(A, A) -> A,
{
    let threads = cfg.effective_threads().max(1);
    if len == 0 {
        return identity;
    }
    if threads == 1 || len <= cfg.seq_threshold {
        return combine(identity, body(0, len));
    }
    let chunk = len.div_ceil(threads);
    let partials = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .filter_map(|t| {
                let start = t * chunk;
                let end = ((t + 1) * chunk).min(len);
                if start >= end {
                    return None;
                }
                let body = &body;
                Some(s.spawn(move || body(start, end)))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par_reduce worker panicked"))
            .collect::<Vec<_>>()
    });
    partials.into_iter().fold(identity, combine)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names() {
        assert_eq!(Executor::reference().name(), "reference");
        assert_eq!(Executor::par().name(), "par");
    }

    #[test]
    fn par_config_threads() {
        assert_eq!(
            ParConfig {
                threads: 3,
                ..Default::default()
            }
            .effective_threads(),
            3
        );
        assert!(ParConfig::default().effective_threads() >= 1);
    }

    #[test]
    fn par_for_covers_range_exactly_once() {
        let cfg = ParConfig {
            threads: 4,
            seq_threshold: 0,
        };
        let n = 1000;
        let hits: Vec<std::sync::atomic::AtomicU32> =
            (0..n).map(|_| std::sync::atomic::AtomicU32::new(0)).collect();
        par_for(&cfg, n, |_, start, end| {
            for i in start..end {
                hits[i].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
        });
        assert!(hits
            .iter()
            .all(|h| h.load(std::sync::atomic::Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_for_empty_and_small() {
        let cfg = ParConfig::default();
        par_for(&cfg, 0, |_, _, _| panic!("must not be called"));
        let seen = std::sync::atomic::AtomicBool::new(false);
        par_for(
            &ParConfig {
                threads: 1,
                seq_threshold: 10,
            },
            5,
            |_, s, e| {
                assert_eq!((s, e), (0, 5));
                seen.store(true, std::sync::atomic::Ordering::Relaxed);
            },
        );
        assert!(seen.load(std::sync::atomic::Ordering::Relaxed));
    }

    #[test]
    fn par_reduce_sums() {
        let cfg = ParConfig {
            threads: 8,
            seq_threshold: 0,
        };
        let n = 12345usize;
        let total = par_reduce(
            &cfg,
            n,
            0u64,
            |s, e| (s..e).map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn par_reduce_empty() {
        let cfg = ParConfig::default();
        let r = par_reduce(&cfg, 0, 7i64, |_, _| panic!(), |a, b| a + b);
        assert_eq!(r, 7);
    }
}
