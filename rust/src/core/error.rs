//! Error type shared across the library.

/// Library-wide error type.
///
/// Mirrors Ginkgo's exception hierarchy (`DimensionMismatch`,
/// `NotSupported`, `KernelNotFound`, ...) flattened into one enum.
/// `Display`/`Error`/`From` are hand-implemented below — the offline
/// vendor set carries no proc-macro crates.
#[derive(Debug)]
pub enum SparkleError {
    /// Operand dimensions do not conform (e.g. SpMV with wrong vector size).
    DimensionMismatch { op: &'static str, detail: String },

    /// The requested kernel/operation is not implemented for this executor.
    NotSupported { op: &'static str, exec: &'static str },

    /// Malformed sparse structure (unsorted, out-of-bounds index, ...).
    InvalidStructure(String),

    /// Artifact missing / shape outside every bucket / PJRT failure.
    Runtime(String),

    /// I/O and parse failures (MatrixMarket, manifests).
    Io(std::io::Error),

    /// Parse failure with location context.
    Parse(String),

    /// Solver failed to meet its stopping criterion budget.
    NotConverged {
        solver: &'static str,
        iters: usize,
        resnorm: f64,
    },

    /// Solver broke down numerically (NaN/Inf residual, collapsed
    /// recurrence denominator, stagnation) and recovery — if attempted —
    /// was exhausted.
    Breakdown {
        solver: &'static str,
        iters: usize,
        resnorm: f64,
        reason: crate::stop::Breakdown,
    },
}

impl std::fmt::Display for SparkleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SparkleError::DimensionMismatch { op, detail } => {
                write!(f, "dimension mismatch in {op}: {detail}")
            }
            SparkleError::NotSupported { op, exec } => {
                write!(f, "operation `{op}` is not supported on executor `{exec}`")
            }
            SparkleError::InvalidStructure(msg) => {
                write!(f, "invalid matrix structure: {msg}")
            }
            SparkleError::Runtime(msg) => write!(f, "xla runtime: {msg}"),
            SparkleError::Io(e) => write!(f, "io: {e}"),
            SparkleError::Parse(msg) => write!(f, "parse error: {msg}"),
            SparkleError::NotConverged {
                solver,
                iters,
                resnorm,
            } => write!(
                f,
                "solver `{solver}` did not converge in {iters} iterations (residual {resnorm:.3e})"
            ),
            SparkleError::Breakdown {
                solver,
                iters,
                resnorm,
                reason,
            } => write!(
                f,
                "solver `{solver}` broke down after {iters} iterations: {reason} (residual {resnorm:.3e})"
            ),
        }
    }
}

impl std::error::Error for SparkleError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SparkleError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SparkleError {
    fn from(e: std::io::Error) -> Self {
        SparkleError::Io(e)
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, SparkleError>;

impl SparkleError {
    /// Helper for dimension mismatch errors.
    pub fn dim(op: &'static str, detail: impl Into<String>) -> Self {
        SparkleError::DimensionMismatch {
            op,
            detail: detail.into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = SparkleError::dim("spmv", "A is 4x4, b is 3");
        assert!(e.to_string().contains("spmv"));
        assert!(e.to_string().contains("4x4"));
        let e = SparkleError::NotSupported {
            op: "half_precision",
            exec: "reference",
        };
        assert!(e.to_string().contains("half_precision"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: SparkleError = io.into();
        assert!(matches!(e, SparkleError::Io(_)));
    }
}
