//! Error type shared across the library.

/// Library-wide error type.
///
/// Mirrors Ginkgo's exception hierarchy (`DimensionMismatch`,
/// `NotSupported`, `KernelNotFound`, ...) flattened into one enum.
#[derive(Debug, thiserror::Error)]
pub enum SparkleError {
    /// Operand dimensions do not conform (e.g. SpMV with wrong vector size).
    #[error("dimension mismatch in {op}: {detail}")]
    DimensionMismatch { op: &'static str, detail: String },

    /// The requested kernel/operation is not implemented for this executor.
    #[error("operation `{op}` is not supported on executor `{exec}`")]
    NotSupported { op: &'static str, exec: &'static str },

    /// Malformed sparse structure (unsorted, out-of-bounds index, ...).
    #[error("invalid matrix structure: {0}")]
    InvalidStructure(String),

    /// Artifact missing / shape outside every bucket / PJRT failure.
    #[error("xla runtime: {0}")]
    Runtime(String),

    /// I/O and parse failures (MatrixMarket, manifests).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Parse failure with location context.
    #[error("parse error: {0}")]
    Parse(String),

    /// Solver failed to meet its stopping criterion budget.
    #[error("solver `{solver}` did not converge in {iters} iterations (residual {resnorm:.3e})")]
    NotConverged {
        solver: &'static str,
        iters: usize,
        resnorm: f64,
    },
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, SparkleError>;

impl SparkleError {
    /// Helper for dimension mismatch errors.
    pub fn dim(op: &'static str, detail: impl Into<String>) -> Self {
        SparkleError::DimensionMismatch {
            op,
            detail: detail.into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = SparkleError::dim("spmv", "A is 4x4, b is 3");
        assert!(e.to_string().contains("spmv"));
        assert!(e.to_string().contains("4x4"));
        let e = SparkleError::NotSupported {
            op: "half_precision",
            exec: "reference",
        };
        assert!(e.to_string().contains("half_precision"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: SparkleError = io.into();
        assert!(matches!(e, SparkleError::Io(_)));
    }
}
