//! Wallclock timing with warmup, mirroring the paper's methodology
//! (§6.3: average of 10 repetitions after 2 warmup launches).

use std::time::Instant;

use crate::bench_util::stats::Stats;

/// Time `f` once, in seconds.
pub fn time_secs(mut f: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

/// Repetition timer.
pub struct Timer {
    pub warmup: usize,
    pub reps: usize,
}

impl Default for Timer {
    fn default() -> Self {
        Self {
            warmup: crate::bench_util::WARMUP,
            reps: crate::bench_util::REPS,
        }
    }
}

impl Timer {
    /// Explicit warmup/reps.
    pub fn new(warmup: usize, reps: usize) -> Self {
        Self { warmup, reps }
    }

    /// Run `f` warmup+reps times; return timing stats over the reps.
    pub fn run(&self, mut f: impl FnMut()) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let samples: Vec<f64> = (0..self.reps.max(1)).map(|_| time_secs(&mut f)).collect();
        Stats::from_samples(&samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_counts_calls() {
        let mut calls = 0usize;
        let t = Timer::new(2, 5);
        let stats = t.run(|| calls += 1);
        assert_eq!(calls, 7);
        assert!(stats.mean >= 0.0);
        assert!(stats.min <= stats.median && stats.median <= stats.max);
    }

    #[test]
    fn time_secs_positive() {
        let s = time_secs(|| std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(s >= 0.002);
    }

    #[test]
    fn zero_rep_config_still_yields_finite_stats() {
        let mut calls = 0usize;
        let stats = Timer::new(0, 0).run(|| calls += 1);
        assert_eq!(calls, 1, "reps clamp to at least one timed run");
        assert!(stats.median.is_finite());
        assert!(stats.mean.is_finite());
        assert!(stats.rate_giga(1e9).is_finite());
    }
}
