//! The SpMV benchmark suite for Fig. 8 / Fig. 10.
//!
//! The paper benchmarks "the test matrices of the SuiteSparse Matrix
//! Collection" — hundreds of points per plot. The substitute suite spans
//! the same structural axes: all ten Table-1 analogs plus sweeps over
//! size, density, and irregularity per generator class, ~30 matrices.

use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;
use crate::matgen::{circuit, fem, kkt, porous, stencil, suite, MatrixStats};

/// One suite matrix: name + assembly data + structure stats.
pub struct SuiteMatrix<T> {
    pub name: String,
    pub data: MatrixData<T>,
    /// Stats of the generated (scaled) matrix — what host runs measure.
    pub stats: MatrixStats,
    /// Stats rescaled to paper-size dimensions — what the device model
    /// projects (the paper benchmarks full-size matrices).
    pub stats_full: MatrixStats,
}

fn push_scaled<T: Value>(
    out: &mut Vec<SuiteMatrix<T>>,
    name: impl Into<String>,
    data: MatrixData<T>,
    scale: usize,
) {
    let stats = MatrixStats::from_data(&data);
    let stats_full = stats.scaled_to(stats.n * scale, stats.nnz * scale);
    out.push(SuiteMatrix {
        name: name.into(),
        data,
        stats,
        stats_full,
    });
}

/// Build the suite at `1/scale` of paper-size dimensions.
pub fn spmv_suite<T: Value>(scale: usize) -> Vec<SuiteMatrix<T>> {
    let scale = scale.max(1);
    let mut out = Vec::new();
    // the ten Table-1 analogs (full-size stats = the published dims)
    for entry in suite::table1() {
        let data = entry.generate::<T>(scale);
        let stats = MatrixStats::from_data(&data);
        let stats_full = stats.scaled_to(entry.n_full, entry.nnz_full);
        out.push(SuiteMatrix {
            name: entry.name.into(),
            data,
            stats,
            stats_full,
        });
    }
    // size sweep: 2-D Laplacians from 16k to 1M rows (scaled, deduped)
    let mut seen_sides = std::collections::HashSet::new();
    for side in [128usize, 256, 512, 1024] {
        let s = (side / (scale as f64).sqrt().max(1.0) as usize).max(32);
        if seen_sides.insert(s) {
            push_scaled(&mut out, format!("laplace2d_{s}x{s}"), stencil::laplace_2d::<T>(s, s), scale);
        }
    }
    // density sweep: 3-D stencils 7pt vs 27pt
    let side3 = (96 / (scale as f64).cbrt().max(1.0) as usize).max(8);
    push_scaled(
        &mut out,
        format!("stencil7_{side3}^3"),
        stencil::stencil_3d::<T>(side3, side3, side3, 0.0),
        scale,
    );
    push_scaled(
        &mut out,
        format!("stencil27_{side3}^3"),
        stencil::stencil_27pt::<T>(side3, side3, side3),
        scale,
    );
    // irregularity sweep: circuits with increasing hub weight
    let nc = (2_000_000 / scale).max(4096);
    for (i, (tag, hub_fraction)) in [("lo", 0.0002f64), ("mid", 0.002), ("hi", 0.01)]
        .into_iter()
        .enumerate()
    {
        push_scaled(
            &mut out,
            format!("circuit_{tag}"),
            circuit::circuit_with_config::<T>(
                nc,
                nc * 6,
                100 + i as u64,
                &circuit::CircuitConfig {
                    hub_fraction,
                    ..Default::default()
                },
            ),
            scale,
        );
    }
    // FEM block-size sweep (1 / 3 dofs per node)
    let nodes = (500_000 / scale).max(2048);
    push_scaled(&mut out, "fem_scalar", fem::fem::<T>(nodes, 6, 1, 201), scale);
    push_scaled(&mut out, "fem_block3", fem::fem::<T>(nodes / 3, 6, 3, 202), scale);
    // saddle-point + heterogeneous flow
    push_scaled(&mut out, "kkt_small", kkt::kkt::<T>((600_000 / scale).max(3072), 12, 0.5, 203), scale);
    let sp = (64 / (scale as f64).cbrt().max(1.0) as usize).max(8);
    push_scaled(
        &mut out,
        format!("porous_{sp}^3"),
        porous::porous_flow::<T>(sp, sp, sp, 4.0, 204),
        scale,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_breadth() {
        let s = spmv_suite::<f64>(512);
        assert!(s.len() >= 20, "suite size {}", s.len());
        // spans regular and irregular structures
        let max_cv = s.iter().map(|m| m.stats.row_cv).fold(0.0, f64::max);
        let min_cv = s.iter().map(|m| m.stats.row_cv).fold(f64::MAX, f64::min);
        assert!(max_cv > 1.0, "no irregular matrices (max cv {max_cv})");
        assert!(min_cv < 0.1, "no regular matrices (min cv {min_cv})");
        // all valid
        for m in &s {
            m.data.validate().unwrap();
            assert!(m.stats.nnz > 0, "{} empty", m.name);
        }
    }
}
