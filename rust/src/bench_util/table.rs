//! Plain-text table printer for paper-style benchmark output.

/// Column-aligned table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no rows were added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(c, cell)| format!("{:>width$}", cell, width = widths[c]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with 2 decimals (bench table convention).
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1.00".into()]);
        t.row(&["longer".into(), "2.50".into()]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[2].ends_with("1.00"));
        assert_eq!(t.len(), 2);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_wrong_width() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only one".into()]);
    }
}
