//! Summary statistics over timing samples.

/// Min/median/mean/max of a sample set (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub max: f64,
}

impl Stats {
    /// Compute from raw samples. Panics on empty input.
    pub fn from_samples(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Stats::from_samples(empty)");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Stats {
            min: sorted[0],
            median,
            mean: sorted.iter().sum::<f64>() / n as f64,
            max: sorted[n - 1],
        }
    }

    /// Derived throughput for `units` of work per run (e.g. bytes ->
    /// GB/s, flops -> GFLOP/s), using the mean time as the paper does.
    pub fn rate_giga(&self, units: f64) -> f64 {
        units / self.mean / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let s = Stats::from_samples(&[3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn even_median() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn rates() {
        let s = Stats::from_samples(&[0.5]);
        assert_eq!(s.rate_giga(1e9), 2.0); // 1 G-unit in 0.5s = 2 G/s
    }
}
