//! Summary statistics over timing samples.

/// Min/median/mean/max of a sample set (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub max: f64,
}

impl Stats {
    /// Compute from raw samples. Non-finite samples (a poisoned timer,
    /// an overflowed subtraction) are dropped first; an empty or
    /// all-non-finite input yields the all-zero `Stats` rather than a
    /// panic or NaN medians, so zero-rep timer configs stay harmless.
    pub fn from_samples(samples: &[f64]) -> Self {
        let mut sorted: Vec<f64> = samples.iter().copied().filter(|v| v.is_finite()).collect();
        if sorted.is_empty() {
            return Stats {
                min: 0.0,
                median: 0.0,
                mean: 0.0,
                max: 0.0,
            };
        }
        sorted.sort_by(f64::total_cmp);
        let n = sorted.len();
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Stats {
            min: sorted[0],
            median,
            mean: sorted.iter().sum::<f64>() / n as f64,
            max: sorted[n - 1],
        }
    }

    /// Derived throughput for `units` of work per run (e.g. bytes ->
    /// GB/s, flops -> GFLOP/s), using the mean time as the paper does.
    /// A degenerate (zero-mean) sample set reports 0 rather than
    /// dividing by zero.
    pub fn rate_giga(&self, units: f64) -> f64 {
        if self.mean > 0.0 {
            units / self.mean / 1e9
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let s = Stats::from_samples(&[3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn even_median() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn rates() {
        let s = Stats::from_samples(&[0.5]);
        assert_eq!(s.rate_giga(1e9), 2.0); // 1 G-unit in 0.5s = 2 G/s
    }

    #[test]
    fn empty_input_is_all_zero_not_a_panic() {
        let s = Stats::from_samples(&[]);
        assert_eq!(s, Stats { min: 0.0, median: 0.0, mean: 0.0, max: 0.0 });
        assert_eq!(s.rate_giga(1e9), 0.0); // no division by zero either
    }

    #[test]
    fn non_finite_samples_are_dropped() {
        let s = Stats::from_samples(&[f64::NAN, 2.0, f64::INFINITY, 4.0, f64::NEG_INFINITY]);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn all_non_finite_degrades_to_zero() {
        let s = Stats::from_samples(&[f64::NAN, f64::INFINITY]);
        assert_eq!(s.median, 0.0);
        assert!(s.median.is_finite());
        assert_eq!(s.rate_giga(1e9), 0.0);
    }
}
