//! Benchmark harness utilities (hand-rolled: the offline vendor set has
//! no criterion). Each bench binary under `rust/benches/` uses these to
//! time kernels and print paper-style tables.

mod spmv_suite;
mod stats;
mod table;
mod timer;

pub use spmv_suite::{spmv_suite, SuiteMatrix};
pub use stats::Stats;
pub use table::{f2, Table};
pub use timer::{time_secs, Timer};

/// Benchmark scale divisor: matrices are generated at `1/scale` of the
/// paper's published dimensions. Override with `SPARKLE_SCALE=<n>`;
/// `SPARKLE_SCALE=1` reproduces full-size structures (needs tens of GB
/// and hours on a laptop — the default keeps `make bench` minutes-scale).
pub fn bench_scale() -> usize {
    std::env::var("SPARKLE_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Repetitions for measured kernels (paper: 2 warmup + 10 timed, §6.3).
pub const WARMUP: usize = 2;
pub const REPS: usize = 10;
