//! Roofline evaluation: bandwidth saturation + arithmetic ceilings.

use crate::core::types::Precision;
use crate::perfmodel::device::DeviceSpec;

/// Roofline calculator for one device.
#[derive(Debug, Clone)]
pub struct Roofline {
    spec: DeviceSpec,
}

impl Roofline {
    /// Build from a device spec.
    pub fn new(spec: DeviceSpec) -> Self {
        Self { spec }
    }

    /// Underlying spec.
    pub fn spec(&self) -> &DeviceSpec {
        &self.spec
    }

    /// Achievable bandwidth (GB/s) for a streaming kernel moving
    /// `bytes` in total (Fig. 6 saturation shape: small arrays cannot
    /// fill the memory pipeline).
    pub fn bandwidth_at(&self, bytes: f64) -> f64 {
        self.spec.bw_measured * bytes / (bytes + self.spec.n_half_bytes)
    }

    /// Same, for kernels with a global synchronization (DOT in Fig. 6).
    pub fn sync_bandwidth_at(&self, bytes: f64) -> f64 {
        self.bandwidth_at(bytes) * self.spec.sync_penalty
    }

    /// Attainable GFLOP/s at arithmetic intensity `ai` (flop/byte) and
    /// precision `p` — the classical roofline (Fig. 7).
    pub fn attainable_gflops(&self, ai: f64, p: Precision) -> f64 {
        (ai * self.spec.bw_measured).min(self.spec.peak_at(p))
    }

    /// Arithmetic intensity at which the roofline ridges from bandwidth-
    /// to compute-bound.
    pub fn ridge_point(&self, p: Precision) -> f64 {
        self.spec.peak_at(p) / self.spec.bw_measured
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::device::Device;

    #[test]
    fn saturation_monotone_and_bounded() {
        let r = Roofline::new(Device::Gen12.spec());
        let small = r.bandwidth_at(4.0 * 1024.0);
        let medium = r.bandwidth_at(1024.0 * 1024.0);
        let large = r.bandwidth_at(512.0 * 1024.0 * 1024.0);
        assert!(small < medium && medium < large);
        assert!(large <= r.spec().bw_measured);
        assert!(large > 0.98 * r.spec().bw_measured);
    }

    #[test]
    fn dot_penalty_applies() {
        let r = Roofline::new(Device::Gen9.spec());
        let b = 64.0 * 1024.0 * 1024.0;
        assert!(r.sync_bandwidth_at(b) < r.bandwidth_at(b));
    }

    #[test]
    fn roofline_ceilings_match_paper() {
        // §6.3: GEN9 double CSR SpMV bound = AI 1/6 * 37 GB/s ≈ 6 GFLOP/s
        let r = Roofline::new(Device::Gen9.spec());
        let bound = r.attainable_gflops(1.0 / 6.0, Precision::Double);
        assert!((bound - 6.16).abs() < 0.1, "bound {bound}");
        // COO: AI 1/8 -> 4.6
        let coo = r.attainable_gflops(1.0 / 8.0, Precision::Double);
        assert!((coo - 4.6).abs() < 0.1, "coo {coo}");
        // GEN12 single CSR: AI 1/4 * 58 = 14.5 ; COO 1/6 -> 9.7 (§6.3)
        let r12 = Roofline::new(Device::Gen12.spec());
        let csr12 = r12.attainable_gflops(0.25, Precision::Single);
        assert!((csr12 - 14.5).abs() < 0.1, "csr12 {csr12}");
        let coo12 = r12.attainable_gflops(1.0 / 6.0, Precision::Single);
        assert!((coo12 - 9.67).abs() < 0.1, "coo12 {coo12}");
    }

    #[test]
    fn compute_bound_kernels_hit_peak() {
        let r = Roofline::new(Device::Gen12.spec());
        assert_eq!(
            r.attainable_gflops(1e6, Precision::Single),
            r.spec().peak_at(Precision::Single)
        );
        // GEN12 double emulation ridge is almost at zero intensity
        assert!(r.ridge_point(Precision::Double) < 0.2);
    }
}
