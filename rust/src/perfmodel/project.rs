//! Projection: matrix × kernel × device → GFLOP/s and achieved GB/s.
//!
//! This produces the series of Fig. 8 (SpMV GFLOP/s), Fig. 9 (solver
//! GFLOP/s) and Fig. 10 (bandwidth relative to theoretical peak).

use crate::core::types::Precision;
use crate::matgen::MatrixStats;
use crate::perfmodel::device::{Device, DeviceSpec};
use crate::perfmodel::roofline::Roofline;
use crate::perfmodel::traffic::{spmv_flops, spmv_traffic, spmv_useful_bytes, SpmvKernelKind};

/// Whose SpMV implementation: sparkle's or the vendor library's
/// (oneMKL / cuSPARSE / hipSPARSE depending on the device).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Implementation {
    Sparkle,
    Vendor,
}

/// Result of one SpMV projection.
#[derive(Debug, Clone)]
pub struct SpmvProjection {
    /// Projected throughput.
    pub gflops: f64,
    /// Achieved bandwidth (useful bytes / time).
    pub gbs: f64,
    /// Achieved bandwidth relative to the *theoretical* device peak
    /// (the Fig. 10 y-axis).
    pub relative_bw: f64,
    /// The §6.3-style roofline upper bound for this kernel/device.
    pub roofline_bound_gflops: f64,
    /// Estimated execution time, microseconds.
    pub time_us: f64,
}

/// Efficiency factor of a kernel implementation on a given structure.
///
/// Mechanistic, not random: row-parallel kernels lose efficiency to row-
/// length imbalance; the vendor kernel vectorizes long regular rows
/// better but degrades harder on irregular ones (which is exactly the
/// "inconsistent, outperforming for some cases, underperforming for
/// others" behaviour §6.5 reports for oneMKL on GEN12).
fn impl_efficiency(
    imp: Implementation,
    kind: SpmvKernelKind,
    stats: &MatrixStats,
    dev: &DeviceSpec,
) -> f64 {
    let base = dev.spmv_efficiency;
    match imp {
        Implementation::Sparkle => match kind {
            // balanced-by-nonzeros: insensitive to row imbalance
            SpmvKernelKind::Coo => base,
            // row-parallel: mild imbalance penalty
            SpmvKernelKind::Csr => base / (1.0 + 0.10 * stats.row_cv),
            // SIMD-regular storage: slightly better base behaviour
            SpmvKernelKind::Ell | SpmvKernelKind::SellP => (base * 1.03).min(0.97),
        },
        Implementation::Vendor => {
            // long regular rows vectorize well (+ up to 20%), short or
            // irregular rows underutilize the vendor kernel's fixed
            // chunking (hard penalty on row_cv)
            let regular_bonus = 1.0 + 0.20 * ((stats.avg_row - 8.0) / 24.0).clamp(-0.5, 1.0);
            let imbalance = 1.0 / (1.0 + 0.35 * stats.row_cv);
            (base * regular_bonus * imbalance).min(0.98)
        }
    }
}

/// Project one SpMV.
pub fn project_spmv(
    device: Device,
    imp: Implementation,
    kind: SpmvKernelKind,
    stats: &MatrixStats,
    p: Precision,
) -> SpmvProjection {
    let spec = device.spec();
    let roof = Roofline::new(spec.clone());
    let bytes = spmv_traffic(kind, stats, p, &spec);
    let flops = spmv_flops(stats);
    let eff = impl_efficiency(imp, kind, stats, &spec);
    let bw = roof.bandwidth_at(bytes) * eff; // GB/s
    // bandwidth-bound time + launch overhead; arithmetic ceiling applies
    // to the emulated-double case (GEN12 fp64: 8 GFLOP/s dominates)
    let t_mem_us = bytes / (bw * 1e3); // bytes / (GB/s) -> ns ; /1e3 -> us
    let t_compute_us = flops / (spec.peak_at(p) * 1e3);
    let time_us = t_mem_us.max(t_compute_us) + spec.launch_overhead_us;
    let gflops = flops / (time_us * 1e3);
    let gbs = spmv_useful_bytes(kind, stats, p) / (time_us * 1e3);
    // Fig. 10 accounting: achieved bandwidth inferred from throughput via
    // the §5 simple-model intensity (GFLOP/s ÷ (flop/byte)), relative to
    // the datasheet peak — this reproduces the paper's own derivation
    // chain (5.1 GFLOP/s × 6 B/flop = 30.6 GB/s ≈ 70% of 41.6 on GEN9)
    let inferred_bw = gflops / kind.paper_intensity(p);
    SpmvProjection {
        gflops,
        gbs,
        relative_bw: inferred_bw / spec.bw_theoretical,
        roofline_bound_gflops: roof
            .attainable_gflops(kind.paper_intensity(p), p),
        time_us,
    }
}

/// Project a full solver run: `iters` iterations of a solver described
/// by its per-iteration flops/bytes (from the `Solver` trait) plus the
/// per-iteration dispatch count (GMRES pays extra host round-trips —
/// §6.4's observation that GMRES lags on the ported backend).
#[allow(clippy::too_many_arguments)]
pub fn project_solver(
    device: Device,
    flops_per_iter: u64,
    bytes_per_iter: u64,
    dispatches_per_iter: u64,
    host_work_us_per_iter: f64,
    p: Precision,
    iters: usize,
) -> (f64 /* GFLOP/s */, f64 /* time ms */) {
    let spec = device.spec();
    let roof = Roofline::new(spec.clone());
    let bytes = bytes_per_iter as f64;
    let bw = roof.bandwidth_at(bytes) * spec.spmv_efficiency * spec.solver_efficiency;
    let t_mem_us = bytes / (bw * 1e3);
    let t_compute_us = flops_per_iter as f64 / (spec.peak_at(p) * 1e3);
    let per_iter_us = t_mem_us.max(t_compute_us)
        + dispatches_per_iter as f64 * spec.launch_overhead_us
        + host_work_us_per_iter;
    let total_us = per_iter_us * iters as f64;
    let gflops = (flops_per_iter as f64 * iters as f64) / (total_us * 1e3);
    (gflops, total_us / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(n: usize, nnz: usize, max_row: usize, cv: f64, bw: f64) -> MatrixStats {
        MatrixStats {
            n,
            nnz,
            avg_row: nnz as f64 / n as f64,
            max_row,
            row_cv: cv,
            bandwidth_frac: bw,
        }
    }

    /// §6.3: on GEN9/double, sparkle CSR should project close to the
    /// paper's measured 5.1 GFLOP/s (bound 6) and COO close to 3.8
    /// (bound 4.6), for a large well-behaved matrix.
    #[test]
    fn gen9_double_matches_paper_measurements() {
        let s = stats(2_000_000, 16_000_000, 10, 0.15, 0.002);
        let csr = project_spmv(
            Device::Gen9,
            Implementation::Sparkle,
            SpmvKernelKind::Csr,
            &s,
            Precision::Double,
        );
        assert!(
            (4.4..5.8).contains(&csr.gflops),
            "GEN9 CSR projected {:.2} GFLOP/s (paper: ~5.1)",
            csr.gflops
        );
        let coo = project_spmv(
            Device::Gen9,
            Implementation::Sparkle,
            SpmvKernelKind::Coo,
            &s,
            Precision::Double,
        );
        assert!(
            (3.2..4.4).contains(&coo.gflops),
            "GEN9 COO projected {:.2} GFLOP/s (paper: ~3.8)",
            coo.gflops
        );
        assert!(csr.gflops > coo.gflops);
    }

    /// §6.3: on GEN12/single both formats run near their bounds
    /// (14.5 / 9.7 GFLOP/s).
    #[test]
    fn gen12_single_near_roofline() {
        let s = stats(2_000_000, 16_000_000, 10, 0.15, 0.002);
        let csr = project_spmv(
            Device::Gen12,
            Implementation::Sparkle,
            SpmvKernelKind::Csr,
            &s,
            Precision::Single,
        );
        assert!(
            csr.gflops > 0.75 * csr.roofline_bound_gflops,
            "GEN12 CSR {:.2} of bound {:.2}",
            csr.gflops,
            csr.roofline_bound_gflops
        );
        let coo = project_spmv(
            Device::Gen12,
            Implementation::Sparkle,
            SpmvKernelKind::Coo,
            &s,
            Precision::Single,
        );
        assert!(coo.gflops > 0.75 * coo.roofline_bound_gflops);
    }

    /// GEN12 double emulation collapses to the 8 GFLOP/s ceiling.
    #[test]
    fn gen12_double_emulation_ceiling() {
        let s = stats(2_000_000, 16_000_000, 10, 0.15, 0.002);
        let csr = project_spmv(
            Device::Gen12,
            Implementation::Sparkle,
            SpmvKernelKind::Csr,
            &s,
            Precision::Double,
        );
        assert!(csr.gflops <= 8.0);
        // and single precision beats it by a lot
        let csr_s = project_spmv(
            Device::Gen12,
            Implementation::Sparkle,
            SpmvKernelKind::Csr,
            &s,
            Precision::Single,
        );
        assert!(csr_s.gflops > 1.2 * csr.gflops);
    }

    /// §6.5's vendor inconsistency: vendor wins on long regular rows,
    /// loses on irregular circuit-like rows.
    #[test]
    fn vendor_inconsistency_is_structural() {
        let regular = stats(2_000_000, 56_000_000, 30, 0.1, 0.002); // Cube_Coup-like
        let irregular = stats(3_000_000, 27_000_000, 10_000, 4.0, 0.15); // FullChip-like
        let p = Precision::Single;
        let dev = Device::Gen12;
        let v_reg = project_spmv(dev, Implementation::Vendor, SpmvKernelKind::Csr, &regular, p);
        let s_reg = project_spmv(dev, Implementation::Sparkle, SpmvKernelKind::Csr, &regular, p);
        let v_irr = project_spmv(dev, Implementation::Vendor, SpmvKernelKind::Csr, &irregular, p);
        let s_irr = project_spmv(dev, Implementation::Sparkle, SpmvKernelKind::Csr, &irregular, p);
        assert!(v_reg.gflops > s_reg.gflops, "vendor should win on regular");
        assert!(v_irr.gflops < s_irr.gflops, "vendor should lose on irregular");
    }

    /// Fig. 10: relative bandwidth lands in each device's published band
    /// for a well-behaved large matrix.
    #[test]
    fn relative_bandwidth_bands() {
        let s = stats(2_000_000, 16_000_000, 10, 0.15, 0.002);
        for dev in Device::ALL {
            let p = if dev == Device::Gen12 {
                Precision::Single
            } else {
                Precision::Double
            };
            let proj = project_spmv(dev, Implementation::Sparkle, SpmvKernelKind::Csr, &s, p);
            let (lo, hi) = dev.spec().relative_bw_band;
            assert!(
                proj.relative_bw > lo * 0.85 && proj.relative_bw < hi * 1.15,
                "{}: relative bw {:.2} outside [{:.2}, {:.2}]",
                dev.spec().name,
                proj.relative_bw,
                lo,
                hi
            );
        }
    }

    /// Fig. 9 shape: short-recurrence solvers cluster, GMRES lags.
    #[test]
    fn solver_projection_gmres_lags() {
        let n = 1_000_000usize;
        let nnz = 10 * n;
        let elem = 8usize;
        // per-iter numbers in the style of the Solver trait impls
        let cg_flops = 2 * nnz as u64 + 12 * n as u64;
        let cg_bytes = (nnz * (elem + 8) + 2 * n * elem + 13 * n * elem) as u64;
        let (cg_gf, _) =
            project_solver(Device::Gen9, cg_flops, cg_bytes, 10, 0.0, Precision::Double, 1000);
        let gmres_flops = 2 * nnz as u64 + 16 * 4 * n as u64;
        let gmres_bytes = (nnz * (elem + 8) + 2 * n * elem + 16 * 5 * n * elem) as u64;
        let (gm_gf, _) = project_solver(
            Device::Gen9,
            gmres_flops,
            gmres_bytes,
            40,
            50.0,
            Precision::Double,
            1000,
        );
        // paper §6.4: solvers land in 1.5-2.5 GFLOP/s on GEN9, GMRES lower
        assert!((1.2..3.0).contains(&cg_gf), "cg {cg_gf}");
        assert!(gm_gf < cg_gf, "gmres {gm_gf} vs cg {cg_gf}");
    }
}
