//! Device specification table.
//!
//! Numbers are the paper's own (§6.1, §6.2, Fig. 7) where reported, and
//! the public vendor datasheets for the CUDA/HIP comparison platforms of
//! Fig. 10.

use crate::core::types::Precision;

/// The GPUs of the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Device {
    /// Intel UHD Graphics P630 (integrated, gen 9).
    Gen9,
    /// Intel Iris Xe MAX (discrete, gen 12, "DG1").
    Gen12,
    /// NVIDIA V100 (the `cuda` backend platform of Fig. 10).
    V100,
    /// AMD Radeon VII (the `hip` backend platform of Fig. 10).
    RadeonVII,
}

impl Device {
    /// All modeled devices.
    pub const ALL: [Device; 4] = [Device::Gen9, Device::Gen12, Device::V100, Device::RadeonVII];

    /// The two Intel devices of the main evaluation.
    pub const INTEL: [Device; 2] = [Device::Gen9, Device::Gen12];

    /// Specification record.
    pub fn spec(self) -> DeviceSpec {
        match self {
            Device::Gen9 => DeviceSpec {
                name: "GEN9 (UHD P630)",
                bw_theoretical: 41.6,
                bw_measured: 37.0,
                peak_gflops: [105.0, 430.0, 810.0],
                // integrated GPU: small caches, quick saturation
                n_half_bytes: 192.0 * 1024.0,
                cache_bytes: 768 * 1024,
                launch_overhead_us: 8.0,
                sync_penalty: 0.82,
                spmv_efficiency: 0.90,
                solver_efficiency: 0.60,
                // §6.5: GEN9 reaches 60-70% of *theoretical* peak BW
                relative_bw_band: (0.55, 0.75),
            },
            Device::Gen12 => DeviceSpec {
                name: "GEN12 (Iris Xe MAX)",
                bw_theoretical: 68.0,
                bw_measured: 58.0,
                // no native fp64: 8 GFLOP/s emulated (§6.2)
                peak_gflops: [8.0, 2200.0, 4000.0],
                n_half_bytes: 512.0 * 1024.0,
                cache_bytes: 3 * 1024 * 1024,
                launch_overhead_us: 6.0,
                sync_penalty: 0.85,
                spmv_efficiency: 0.97,
                solver_efficiency: 0.70,
                relative_bw_band: (0.60, 0.90),
            },
            Device::V100 => DeviceSpec {
                name: "V100 (cuda)",
                bw_theoretical: 900.0,
                bw_measured: 820.0,
                peak_gflops: [7000.0, 14000.0, 28000.0],
                n_half_bytes: 8.0 * 1024.0 * 1024.0,
                cache_bytes: 6 * 1024 * 1024,
                launch_overhead_us: 4.0,
                sync_penalty: 0.88,
                spmv_efficiency: 0.95,
                solver_efficiency: 0.75,
                relative_bw_band: (0.60, 0.95),
            },
            Device::RadeonVII => DeviceSpec {
                name: "RadeonVII (hip)",
                bw_theoretical: 1024.0,
                bw_measured: 800.0,
                peak_gflops: [3360.0, 13440.0, 26880.0],
                n_half_bytes: 16.0 * 1024.0 * 1024.0,
                cache_bytes: 4 * 1024 * 1024,
                launch_overhead_us: 5.0,
                sync_penalty: 0.80,
                spmv_efficiency: 0.85,
                solver_efficiency: 0.70,
                relative_bw_band: (0.45, 0.70),
            },
        }
    }
}

/// Roofline-relevant properties of one device.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Datasheet bandwidth, GB/s (the Fig. 10 baseline).
    pub bw_theoretical: f64,
    /// Measured BabelStream peak, GB/s (§6.2).
    pub bw_measured: f64,
    /// Peak arithmetic throughput [double, single, half], GFLOP/s (Fig. 7).
    pub peak_gflops: [f64; 3],
    /// Bytes at which the bandwidth curve reaches half of peak (Fig. 6
    /// saturation shape).
    pub n_half_bytes: f64,
    /// Last-level cache: working sets below this see reduced gather
    /// traffic in the SpMV model.
    pub cache_bytes: usize,
    /// Fixed kernel-launch cost, microseconds.
    pub launch_overhead_us: f64,
    /// Bandwidth factor for globally-synchronizing kernels (DOT, Fig. 6).
    pub sync_penalty: f64,
    /// Base fraction of measured bandwidth SpMV-class kernels achieve
    /// on their *actual* traffic (§6.3: the paper's measured 5.1 of a
    /// 6.0-bound CSR implies near-stream bandwidth once row-pointer and
    /// vector traffic are accounted).
    pub spmv_efficiency: f64,
    /// Additional factor for full solver iterations (BLAS-1-dominated,
    /// synchronization-heavy small kernels; calibrated to the 1.5-2.5
    /// GFLOP/s GEN9 / 5-9 GFLOP/s GEN12 bands of §6.4).
    pub solver_efficiency: f64,
    /// §6.5 relative-to-theoretical-peak band (validation target for the
    /// Fig. 10 bench).
    pub relative_bw_band: (f64, f64),
}

impl DeviceSpec {
    /// Peak GFLOP/s at a precision.
    pub fn peak_at(&self, p: Precision) -> f64 {
        match p {
            Precision::Double => self.peak_gflops[0],
            Precision::Single => self.peak_gflops[1],
            Precision::Half => self.peak_gflops[2],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_calibration_numbers() {
        let g9 = Device::Gen9.spec();
        assert_eq!(g9.bw_theoretical, 41.6);
        assert_eq!(g9.bw_measured, 37.0);
        assert_eq!(g9.peak_at(Precision::Double), 105.0);
        let g12 = Device::Gen12.spec();
        assert_eq!(g12.bw_measured, 58.0);
        assert_eq!(g12.peak_at(Precision::Double), 8.0); // emulation!
        assert_eq!(g12.peak_at(Precision::Single), 2200.0);
    }

    #[test]
    fn gen12_is_1_6x_gen9_bandwidth() {
        // §6.2: "about 1.6x the GEN9 bandwidth"
        let ratio = Device::Gen12.spec().bw_measured / Device::Gen9.spec().bw_measured;
        assert!((ratio - 1.6).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn discrete_gpus_dwarf_integrated() {
        assert!(Device::V100.spec().bw_measured > 10.0 * Device::Gen12.spec().bw_measured);
    }
}
