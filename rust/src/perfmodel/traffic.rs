//! Per-kernel memory-traffic and FLOP accounting.
//!
//! §5 of the paper defines the simplified footprints (CSR: value+index
//! per nonzero, COO: value+2 indices) and §6.3 notes what the simple
//! model ignores — row pointers and vector access. This model accounts
//! both: the vector gather traffic is estimated from the matrix's column
//! locality and the device's cache size, which is what produces the
//! per-matrix scatter of Fig. 8.

use crate::core::types::Precision;
use crate::matgen::MatrixStats;
use crate::perfmodel::device::DeviceSpec;

/// Which SpMV implementation (traffic differs per storage format).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpmvKernelKind {
    /// Row-parallel CSR (sparkle's and the vendor library's format).
    Csr,
    /// Row-sorted COO with segmented accumulation.
    Coo,
    /// Column-major padded ELL (padding inflates traffic).
    Ell,
    /// Sliced ELL with per-slice padding.
    SellP,
}

impl SpmvKernelKind {
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            SpmvKernelKind::Csr => "csr",
            SpmvKernelKind::Coo => "coo",
            SpmvKernelKind::Ell => "ell",
            SpmvKernelKind::SellP => "sellp",
        }
    }

    /// §5's simplified arithmetic intensity (flop/byte) at a precision —
    /// the number the paper quotes (CSR 1/6 double, COO 1/8 double, ...).
    pub fn paper_intensity(self, p: Precision) -> f64 {
        let elem = p.bytes() as f64;
        match self {
            SpmvKernelKind::Csr => 2.0 / (elem + 4.0),
            SpmvKernelKind::Coo => 2.0 / (elem + 8.0),
            // paper doesn't quote ELL/SELL-P; same footprint as CSR plus
            // padding (handled in `spmv_traffic`)
            SpmvKernelKind::Ell | SpmvKernelKind::SellP => 2.0 / (elem + 4.0),
        }
    }
}

/// Which fused BLAS-1 kernel (see `kernels::reference`). Every fused
/// kernel replaces a composed sequence of simple BLAS-1 sweeps; the
/// model tracks both footprints so the roofline profile credits the
/// saved traffic. "Streams" count full-vector reads + writes per
/// element (the §5-style useful-bytes accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FusedBlasKind {
    /// `(x·y, y·y)` in one sweep (replaces `dot` + `dot`).
    DotNorm2,
    /// `x += αp; r -= αq; r·r` (replaces `axpy` + `axpy` + `dot`).
    AxpySubNorm2,
    /// `out = z + αx` (replaces `copy` + `axpy`).
    AddScaled,
    /// `p = r + β(p − ωv)` (replaces `axpy` + `axpby`).
    UpdateP,
    /// `p = u + β(q + βp)` (replaces `axpy`-style pair, CGS variant).
    UpdatePCgs,
    /// `r = s − ωt; r·r` (replaces `copy` + `axpy` + `dot`).
    SubScaledNorm2,
    /// `x += αp; x += ωs` stacked (replaces `axpy` + `axpy`).
    Axpy2,
    /// `out = βx` (replaces `copy` + `scal`).
    ScalInto,
    /// `h = <w, v>; w -= h·v` (replaces `dot` + `axpy`).
    DotAxpy,
}

impl FusedBlasKind {
    /// Display name (matches the kernel function name).
    pub fn name(self) -> &'static str {
        match self {
            FusedBlasKind::DotNorm2 => "dot_norm2",
            FusedBlasKind::AxpySubNorm2 => "axpy_sub_norm2",
            FusedBlasKind::AddScaled => "add_scaled",
            FusedBlasKind::UpdateP => "update_p",
            FusedBlasKind::UpdatePCgs => "update_p_cgs",
            FusedBlasKind::SubScaledNorm2 => "sub_scaled_norm2",
            FusedBlasKind::Axpy2 => "axpy2",
            FusedBlasKind::ScalInto => "scal_into",
            FusedBlasKind::DotAxpy => "dot_axpy",
        }
    }

    /// Useful FLOPs per element.
    pub fn flops_per_elem(self) -> f64 {
        match self {
            FusedBlasKind::DotNorm2 => 4.0,
            FusedBlasKind::AxpySubNorm2 => 6.0,
            FusedBlasKind::AddScaled => 2.0,
            FusedBlasKind::UpdateP => 4.0,
            FusedBlasKind::UpdatePCgs => 4.0,
            FusedBlasKind::SubScaledNorm2 => 4.0,
            FusedBlasKind::Axpy2 => 4.0,
            FusedBlasKind::ScalInto => 1.0,
            FusedBlasKind::DotAxpy => 4.0,
        }
    }

    /// Full-vector streams (reads + writes) the fused kernel moves.
    pub fn streams(self) -> f64 {
        match self {
            FusedBlasKind::DotNorm2 => 2.0,
            FusedBlasKind::AxpySubNorm2 => 6.0,
            FusedBlasKind::AddScaled => 3.0,
            FusedBlasKind::UpdateP => 4.0,
            FusedBlasKind::UpdatePCgs => 4.0,
            FusedBlasKind::SubScaledNorm2 => 3.0,
            FusedBlasKind::Axpy2 => 4.0,
            FusedBlasKind::ScalInto => 2.0,
            FusedBlasKind::DotAxpy => 4.0,
        }
    }

    /// Streams the composed (unfused) sequence would move — the saving
    /// credited by fusion is `composed_streams - streams`.
    pub fn composed_streams(self) -> f64 {
        match self {
            FusedBlasKind::DotNorm2 => 3.0,
            FusedBlasKind::AxpySubNorm2 => 7.0,
            FusedBlasKind::AddScaled => 5.0,
            FusedBlasKind::UpdateP => 6.0,
            FusedBlasKind::UpdatePCgs => 6.0,
            FusedBlasKind::SubScaledNorm2 => 6.0,
            FusedBlasKind::Axpy2 => 6.0,
            FusedBlasKind::ScalInto => 4.0,
            FusedBlasKind::DotAxpy => 5.0,
        }
    }

    /// Useful bytes of one fused call over length-`n` vectors.
    pub fn useful_bytes(self, n: usize, p: Precision) -> f64 {
        self.streams() * n as f64 * p.bytes() as f64
    }

    /// Useful FLOPs of one fused call over length-`n` vectors.
    pub fn flops(self, n: usize) -> f64 {
        self.flops_per_elem() * n as f64
    }
}

// ------------------------------------------------------------ batched MGS
//
// The GMRES orthogonalization works on a *growing* block of k basis
// vectors, so its per-call traffic depends on k and doesn't fit the
// fixed-shape `FusedBlasKind` table. These model the two gemv-like
// batched kernels; the composed figures are what the equivalent
// dot/axpy chain (plus trailing norm reduction) would move.

/// Useful FLOPs of one fused MGS projection sweep over a k-vector basis
/// of length-`n` columns: a 2-flop dot plus a 2-flop subtraction per
/// element and basis vector, plus the trailing `<w, w>` — identical
/// work to the composed chain, fusion only cuts bytes.
pub fn mgs_project_flops(k: usize, n: usize) -> f64 {
    ((4 * k + 2) * n) as f64
}

/// Useful bytes of the fused projection sweep: a leading 2-stream dot,
/// then one pipelined 4-stream pass of `w` per remaining basis vector
/// (v_prev, v_next, w read + write), and a 3-stream finishing pass —
/// `(4k + 1)·n` elements in total.
pub fn mgs_project_bytes(k: usize, n: usize, p: Precision) -> f64 {
    let streams = if k == 0 { 1 } else { 4 * k + 1 };
    (streams * n) as f64 * p.bytes() as f64
}

/// Bytes the composed sequence (k × (`dot` + `axpy`) + trailing `dot`)
/// would move: `(5k + 1)·n` elements.
pub fn mgs_project_composed_bytes(k: usize, n: usize, p: Precision) -> f64 {
    ((5 * k + 1) * n) as f64 * p.bytes() as f64
}

/// Useful FLOPs of the batched basis update `x += Σ_j y_j·v_j`.
pub fn mgs_update_flops(k: usize, n: usize) -> f64 {
    (2 * k * n) as f64
}

/// Useful bytes of the batched update: each basis column read once plus
/// one read + write of `x` — `(k + 2)·n` elements.
pub fn mgs_update_bytes(k: usize, n: usize, p: Precision) -> f64 {
    ((k + 2) * n) as f64 * p.bytes() as f64
}

/// Bytes the composed k-`axpy` sequence would move: `3k·n` elements.
pub fn mgs_update_composed_bytes(k: usize, n: usize, p: Precision) -> f64 {
    (3 * k * n) as f64 * p.bytes() as f64
}

/// Useful FLOPs of one SpMV (the paper counts 2 per stored nonzero).
pub fn spmv_flops(stats: &MatrixStats) -> f64 {
    2.0 * stats.nnz as f64
}

/// "Useful" bytes of one SpMV — the §5 simple-model footprint (matrix
/// data + one pass over x and y, no re-reads, no padding overhead). This
/// is the accounting behind Fig. 10's achieved-bandwidth axis.
pub fn spmv_useful_bytes(kind: SpmvKernelKind, stats: &MatrixStats, p: Precision) -> f64 {
    let elem = p.bytes() as f64;
    let n = stats.n as f64;
    let nnz = stats.nnz as f64;
    let matrix_bytes = match kind {
        SpmvKernelKind::Csr => nnz * (elem + 4.0) + (n + 1.0) * 4.0,
        SpmvKernelKind::Coo => nnz * (elem + 8.0),
        SpmvKernelKind::Ell | SpmvKernelKind::SellP => nnz * (elem + 4.0),
    };
    matrix_bytes + 2.0 * n * elem
}

/// Estimated bytes moved by one SpMV of `kind` on `dev`.
pub fn spmv_traffic(
    kind: SpmvKernelKind,
    stats: &MatrixStats,
    p: Precision,
    dev: &DeviceSpec,
) -> f64 {
    let elem = p.bytes() as f64;
    let n = stats.n as f64;
    let nnz = stats.nnz as f64;
    // matrix-structure traffic
    let matrix_bytes = match kind {
        SpmvKernelKind::Csr => nnz * (elem + 4.0) + (n + 1.0) * 4.0,
        SpmvKernelKind::Coo => nnz * (elem + 8.0),
        SpmvKernelKind::Ell => {
            // padded to the longest row
            let stored = n * stats.max_row as f64;
            stored * (elem + 4.0)
        }
        SpmvKernelKind::SellP => {
            // per-slice padding ≈ nnz * (1 + cv/4): slices absorb most of
            // the irregularity a global pad would pay for
            let stored = nnz * (1.0 + stats.row_cv / 4.0);
            stored * (elem + 4.0) + n / 32.0 * 8.0
        }
    };
    // vector traffic: y write + compulsory x read + gather misses.
    // x re-reads beyond the compulsory pass depend on locality: a narrow
    // band keeps the needed x window in cache, a scattered pattern does
    // not; an x that fits the LLC outright caps the miss rate.
    let x_bytes_compulsory = n * elem;
    let extra_accesses = (nnz - n).max(0.0);
    let locality_miss = (2.0 * stats.bandwidth_frac).min(1.0);
    let fits_cache = n * elem <= dev.cache_bytes as f64;
    let miss_rate = if fits_cache {
        0.15 * locality_miss
    } else {
        locality_miss
    };
    let gather_bytes = extra_accesses * elem * miss_rate;
    let y_bytes = n * elem;
    matrix_bytes + x_bytes_compulsory + gather_bytes + y_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::device::Device;

    fn stats(n: usize, nnz: usize, max_row: usize, cv: f64, bw: f64) -> MatrixStats {
        MatrixStats {
            n,
            nnz,
            avg_row: nnz as f64 / n as f64,
            max_row,
            row_cv: cv,
            bandwidth_frac: bw,
        }
    }

    #[test]
    fn paper_intensities() {
        assert!((SpmvKernelKind::Csr.paper_intensity(Precision::Double) - 1.0 / 6.0).abs() < 1e-12);
        assert!((SpmvKernelKind::Coo.paper_intensity(Precision::Double) - 1.0 / 8.0).abs() < 1e-12);
        assert!((SpmvKernelKind::Csr.paper_intensity(Precision::Single) - 0.25).abs() < 1e-12);
        assert!(
            (SpmvKernelKind::Coo.paper_intensity(Precision::Single) - 1.0 / 6.0).abs() < 1e-12
        );
    }

    #[test]
    fn coo_moves_more_than_csr() {
        let s = stats(100_000, 700_000, 9, 0.1, 0.01);
        let dev = Device::Gen9.spec();
        let csr = spmv_traffic(SpmvKernelKind::Csr, &s, Precision::Double, &dev);
        let coo = spmv_traffic(SpmvKernelKind::Coo, &s, Precision::Double, &dev);
        assert!(coo > csr);
        // ratio approaches (8+8)/(8+4) for nnz >> n
        assert!(coo / csr > 1.15 && coo / csr < 1.45, "{}", coo / csr);
    }

    #[test]
    fn ell_pays_for_long_rows() {
        let dev = Device::Gen9.spec();
        let regular = stats(10_000, 70_000, 7, 0.05, 0.01);
        let skewed = stats(10_000, 70_000, 2000, 5.0, 0.01);
        let e_reg = spmv_traffic(SpmvKernelKind::Ell, &regular, Precision::Double, &dev);
        let e_skew = spmv_traffic(SpmvKernelKind::Ell, &skewed, Precision::Double, &dev);
        assert!(e_skew > 50.0 * e_reg, "{e_skew} vs {e_reg}");
        // SELL-P absorbs it
        let s_skew = spmv_traffic(SpmvKernelKind::SellP, &skewed, Precision::Double, &dev);
        assert!(s_skew < e_skew / 10.0);
    }

    #[test]
    fn scattered_columns_add_gather_traffic() {
        let dev = Device::V100.spec();
        let local = stats(2_000_000, 14_000_000, 9, 0.1, 0.001);
        let scattered = stats(2_000_000, 14_000_000, 9, 0.1, 0.3);
        let t_local = spmv_traffic(SpmvKernelKind::Csr, &local, Precision::Double, &dev);
        let t_scat = spmv_traffic(SpmvKernelKind::Csr, &scattered, Precision::Double, &dev);
        assert!(t_scat > 1.2 * t_local);
    }

    #[test]
    fn cache_fit_suppresses_misses() {
        let dev = Device::V100.spec(); // 6 MiB LLC
        let small = stats(100_000, 1_000_000, 12, 0.1, 0.3); // x = 0.8 MB fits
        let large = stats(10_000_000, 100_000_000, 12, 0.1, 0.3); // x = 80 MB doesn't
        let t_small = spmv_traffic(SpmvKernelKind::Csr, &small, Precision::Double, &dev);
        let t_large = spmv_traffic(SpmvKernelKind::Csr, &large, Precision::Double, &dev);
        // per-nnz traffic must be clearly higher out of cache
        let per_small = t_small / small.nnz as f64;
        let per_large = t_large / large.nnz as f64;
        assert!(per_large > 1.2 * per_small, "{per_large} vs {per_small}");
    }

    #[test]
    fn flops_are_2nnz() {
        let s = stats(10, 55, 7, 0.0, 0.0);
        assert_eq!(spmv_flops(&s), 110.0);
    }

    #[test]
    fn fused_kernels_always_save_streams() {
        use FusedBlasKind::*;
        for k in [
            DotNorm2,
            AxpySubNorm2,
            AddScaled,
            UpdateP,
            UpdatePCgs,
            SubScaledNorm2,
            Axpy2,
            ScalInto,
            DotAxpy,
        ] {
            assert!(
                k.streams() < k.composed_streams(),
                "{} must cut traffic",
                k.name()
            );
            assert!(k.flops_per_elem() > 0.0);
            // bytes scale with n and precision
            assert_eq!(
                k.useful_bytes(100, Precision::Double),
                k.streams() * 800.0
            );
            assert_eq!(
                k.useful_bytes(100, Precision::Single),
                k.streams() * 400.0
            );
            assert_eq!(k.flops(50), 50.0 * k.flops_per_elem());
        }
        // one CG iteration's BLAS-1 sweeps: fused cuts 16 streams to 11
        let fused: f64 = [AxpySubNorm2, DotNorm2].iter().map(|k| k.streams()).sum();
        let composed: f64 = [AxpySubNorm2, DotNorm2]
            .iter()
            .map(|k| k.composed_streams())
            .sum();
        assert!(composed - fused >= 2.0);
    }

    #[test]
    fn batched_mgs_models_save_bytes_never_flops() {
        let n = 1000;
        for k in 1..=32 {
            // fusion is traffic-only: identical flops, fewer bytes
            assert!(
                mgs_project_bytes(k, n, Precision::Double)
                    < mgs_project_composed_bytes(k, n, Precision::Double),
                "k = {k}"
            );
            assert!(
                mgs_update_bytes(k, n, Precision::Double)
                    <= mgs_update_composed_bytes(k, n, Precision::Double),
                "k = {k}"
            );
            assert!(mgs_project_flops(k, n) > 0.0);
            assert!(mgs_update_flops(k, n) > 0.0);
        }
        // the batched update beats the axpy chain once the basis has
        // more than one column (k = 1 is a plain axpy either way)
        assert!(
            mgs_update_bytes(2, n, Precision::Double)
                < mgs_update_composed_bytes(2, n, Precision::Double)
        );
        // per-iteration sweep count: one sweep of w per basis vector
        // (4k+1 streams) instead of two plus the norm pass (5k+1)
        assert_eq!(mgs_project_bytes(8, n, Precision::Single), (33 * n) as f64 * 4.0);
        assert_eq!(
            mgs_project_composed_bytes(8, n, Precision::Single),
            (41 * n) as f64 * 4.0
        );
        // empty basis degenerates to the lone trailing reduction
        assert_eq!(mgs_project_bytes(0, n, Precision::Double), (n * 8) as f64);
        assert_eq!(mgs_project_flops(0, n), (2 * n) as f64);
    }
}
