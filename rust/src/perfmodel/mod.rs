//! Analytic GPU performance model — the testbed substitute.
//!
//! The paper's evaluation hardware (Intel GEN9/GEN12 via DevCloud, NVIDIA
//! V100, AMD RadeonVII) is not attached to this environment, so the
//! figures are reproduced through a calibrated roofline model — the same
//! methodology the paper itself uses in §6.2/§6.3 to derive its
//! performance bounds (measured bandwidth × arithmetic intensity), here
//! extended with per-kernel traffic accounting and locality/balance
//! penalties so per-matrix scatter emerges from matrix *structure*.
//!
//! Calibration sources (all from the paper):
//! * Fig. 6 / §6.2 — measured peak bandwidths (37 / 58 GB/s), saturating
//!   curve shape, DOT sync penalty.
//! * Fig. 7 — precision-specific arithmetic peaks (GEN9 105/430/810
//!   GFLOP/s, GEN12 8/2200/4000).
//! * §6.3 — SpMV efficiency vs roofline bound (CSR 5.1 of 6, COO 3.8 of
//!   4.6 on GEN9; both near bound on GEN12).
//! * §6.5 / Fig. 10 — relative-to-peak bands per platform (~90% GEN12 /
//!   CUDA-class, 60–70% GEN9 / RadeonVII).

pub mod device;
pub mod project;
pub mod roofline;
pub mod traffic;

pub use device::{Device, DeviceSpec};
pub use project::{project_solver, project_spmv, SpmvProjection};
pub use roofline::Roofline;
pub use traffic::{spmv_flops, spmv_traffic, SpmvKernelKind};
