//! `sparkle` CLI launcher.
//!
//! Hand-rolled argument parsing (the offline vendor set has no clap).
//!
//! Commands:
//!   info                          runtime + artifact status
//!   gen <name> [--scale N] [--out FILE.mtx]
//!                                 generate a Table-1 analog matrix
//!   spmv <file.mtx|name> [--exec E] [--format F] [--reps N]
//!                                 time one SpMV
//!   solve <file.mtx|name> [--solver S] [--exec E] [--tol T] [--iters N]
//!                                 run a Krylov solver
//!   project <name> [--device D]   device-model projection for a matrix
//!   devices                       print the modeled GPU table

use std::collections::HashMap;

use sparkle::bench_util::{f2, Table, Timer};
use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::core::matrix_data::MatrixData;
use sparkle::matgen::{suite, MatrixStats};
use sparkle::matrix::{Coo, Csr, Dense, Ell, Hybrid, SellP};
use sparkle::perfmodel::project::Implementation;
use sparkle::perfmodel::{project_spmv, Device, SpmvKernelKind};
use sparkle::solver::{BiCgStab, Cg, Cgs, Fcg, Gmres, Richardson, Solver, SolverConfig};
use sparkle::stop::Criterion;
use sparkle::vendor_mkl::VendorCsr;
use sparkle::{Dim2, Result, SparkleError};

/// Parsed `--key value` options + positional arguments.
struct Opts {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Opts {
    fn parse(args: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = it
                    .peek()
                    .filter(|v| !v.starts_with("--"))
                    .map(|v| v.to_string())
                    .unwrap_or_else(|| "true".into());
                if val != "true" {
                    it.next();
                }
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a.clone());
            }
        }
        Self { positional, flags }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.into())
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

fn executor(name: &str) -> Result<std::sync::Arc<Executor>> {
    match name {
        "reference" => Ok(Executor::reference()),
        "par" => Ok(Executor::par()),
        "xla" => Executor::xla("artifacts"),
        other => Err(SparkleError::Parse(format!(
            "unknown executor `{other}` (reference|par|xla)"
        ))),
    }
}

/// Load a matrix: a path ending in .mtx, or a Table-1 name.
fn load_matrix(spec: &str, scale: usize) -> Result<MatrixData<f64>> {
    if spec.ends_with(".mtx") {
        sparkle::io::read_matrix_market(spec)
    } else {
        suite::table1_entry(spec)
            .map(|e| e.generate::<f64>(scale))
            .ok_or_else(|| {
                SparkleError::Parse(format!(
                    "`{spec}` is neither an .mtx path nor a Table-1 name"
                ))
            })
    }
}

fn cmd_info() -> Result<()> {
    println!("sparkle {}", env!("CARGO_PKG_VERSION"));
    println!("executors: reference, par ({} threads), xla",
             std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    if std::path::Path::new("artifacts/manifest.tsv").exists() {
        let exec = Executor::xla("artifacts")?;
        let rt = exec.xla_runtime().unwrap();
        println!(
            "artifacts: {} registered (platform {})",
            rt.manifest().len(),
            rt.platform_name()
        );
    } else {
        println!("artifacts: NOT BUILT — run `make artifacts`");
    }
    Ok(())
}

fn cmd_devices() {
    let mut t = Table::new(&[
        "device", "BW theo", "BW meas", "f64 GF/s", "f32 GF/s", "f16 GF/s",
    ]);
    for d in Device::ALL {
        let s = d.spec();
        t.row(&[
            s.name.into(),
            f2(s.bw_theoretical),
            f2(s.bw_measured),
            f2(s.peak_gflops[0]),
            f2(s.peak_gflops[1]),
            f2(s.peak_gflops[2]),
        ]);
    }
    t.print();
}

fn cmd_gen(o: &Opts) -> Result<()> {
    let name = o
        .positional
        .get(1)
        .ok_or_else(|| SparkleError::Parse("gen needs a matrix name".into()))?;
    let scale = o.get_usize("scale", 64);
    let data = load_matrix(name, scale)?;
    let stats = MatrixStats::from_data(&data);
    println!(
        "{name}: n={} nnz={} avg_row={:.1} max_row={} cv={:.2}",
        stats.n, stats.nnz, stats.avg_row, stats.max_row, stats.row_cv
    );
    let out = o.get("out", "");
    if !out.is_empty() {
        sparkle::io::write_matrix_market(&out, &data)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_spmv(o: &Opts) -> Result<()> {
    let spec = o
        .positional
        .get(1)
        .ok_or_else(|| SparkleError::Parse("spmv needs a matrix".into()))?;
    let data = load_matrix(spec, o.get_usize("scale", 64))?;
    let stats = MatrixStats::from_data(&data);
    let exec = executor(&o.get("exec", "par"))?;
    let reps = o.get_usize("reps", 10);
    let format = o.get("format", "csr");
    let b = Dense::filled(exec.clone(), Dim2::new(stats.n, 1), 1.0);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(stats.n, 1));
    let op: Box<dyn LinOp<f64>> = match format.as_str() {
        "csr" => Box::new(Csr::from_data(exec.clone(), &data)?),
        "coo" => Box::new(Coo::from_data(exec.clone(), &data)?),
        "ell" => Box::new(Ell::from_data(exec.clone(), &data)?),
        "sellp" => Box::new(SellP::from_data(exec.clone(), &data)?),
        "hybrid" => Box::new(Hybrid::from_data(exec.clone(), &data)?),
        "vendor" => Box::new(VendorCsr::new(Csr::from_data(exec.clone(), &data)?)),
        other => {
            return Err(SparkleError::Parse(format!(
                "unknown format `{other}` (csr|coo|ell|sellp|hybrid|vendor)"
            )))
        }
    };
    let st = Timer::new(2, reps).run(|| op.apply(&b, &mut x).unwrap());
    let flops = 2.0 * stats.nnz as f64;
    println!(
        "{spec} [{format} on {}]: {:.3} ms/apply, {:.2} GFLOP/s (n={}, nnz={})",
        exec.name(),
        st.mean * 1e3,
        st.rate_giga(flops),
        stats.n,
        stats.nnz
    );
    Ok(())
}

fn cmd_solve(o: &Opts) -> Result<()> {
    let spec = o
        .positional
        .get(1)
        .ok_or_else(|| SparkleError::Parse("solve needs a matrix".into()))?;
    let data = load_matrix(spec, o.get_usize("scale", 64))?;
    let stats = MatrixStats::from_data(&data);
    let exec = executor(&o.get("exec", "par"))?;
    let tol = o.get_f64("tol", 1e-8);
    let iters = o.get_usize("iters", 1000);
    let crit = Criterion::residual(tol, iters);
    let mut cfg = SolverConfig::with_criterion(crit);
    cfg.record_history = o.get("history", "false") == "true";
    let solver_name = o.get("solver", "cg");
    let solver: Box<dyn Solver<f64>> = match solver_name.as_str() {
        "cg" => Box::new(Cg::new(cfg.clone())),
        "fcg" => Box::new(Fcg::new(cfg.clone())),
        "bicgstab" => Box::new(BiCgStab::new(cfg.clone())),
        "cgs" => Box::new(Cgs::new(cfg.clone())),
        "gmres" => Box::new(Gmres::new(cfg.clone())),
        "richardson" => Box::new(Richardson::new(cfg.clone(), 0.9)),
        other => {
            return Err(SparkleError::Parse(format!(
                "unknown solver `{other}` (cg|fcg|bicgstab|cgs|gmres|richardson)"
            )))
        }
    };
    let a = Csr::from_data(exec.clone(), &data)?;
    let b = Dense::filled(exec.clone(), Dim2::new(stats.n, 1), 1.0);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(stats.n, 1));
    let t0 = std::time::Instant::now();
    let result = solver.solve(&a, &b, &mut x)?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "{solver_name} on {spec} [{}]: converged={} iters={} residual={:.3e} time={:.1} ms",
        exec.name(),
        result.converged,
        result.iterations,
        result.resnorm,
        secs * 1e3
    );
    if cfg.record_history {
        for (i, r) in result.history.iter().enumerate() {
            println!("  iter {i:>4}: {r:.6e}");
        }
    }
    Ok(())
}

fn cmd_project(o: &Opts) -> Result<()> {
    let name = o
        .positional
        .get(1)
        .ok_or_else(|| SparkleError::Parse("project needs a Table-1 name".into()))?;
    let entry = suite::table1_entry(name)
        .ok_or_else(|| SparkleError::Parse(format!("unknown Table-1 matrix `{name}`")))?;
    let data = entry.generate::<f64>(o.get_usize("scale", 128));
    let stats = MatrixStats::from_data(&data).scaled_to(entry.n_full, entry.nnz_full);
    let mut t = Table::new(&["device", "prec", "kernel", "GF/s", "bound", "rel BW"]);
    for dev in Device::ALL {
        let p = if dev == Device::Gen12 {
            sparkle::Precision::Single
        } else {
            sparkle::Precision::Double
        };
        for (label, imp, kind) in [
            ("sparkle csr", Implementation::Sparkle, SpmvKernelKind::Csr),
            ("sparkle coo", Implementation::Sparkle, SpmvKernelKind::Coo),
            ("vendor csr", Implementation::Vendor, SpmvKernelKind::Csr),
        ] {
            let proj = project_spmv(dev, imp, kind, &stats, p);
            t.row(&[
                dev.spec().name.into(),
                p.to_string(),
                label.into(),
                f2(proj.gflops),
                f2(proj.roofline_bound_gflops),
                f2(proj.relative_bw),
            ]);
        }
    }
    println!("projection for {name} at published size (n={}, nnz={}):", entry.n_full, entry.nnz_full);
    t.print();
    Ok(())
}

fn cmd_stream(o: &Opts) -> Result<()> {
    use sparkle::kernels::stream::{self, StreamArrays, StreamKernel};
    let exec = executor(&o.get("exec", "par"))?;
    let n = o.get_usize("n", 1 << 22);
    let reps = o.get_usize("reps", 10);
    let mut arrays = StreamArrays::<f64>::new(n);
    let mut t = Table::new(&["kernel", "GB/s (best)", "GB/s (mean)"]);
    for kernel in StreamKernel::ALL {
        let bytes = (kernel.bytes_per_element(8) * n) as f64;
        let st = Timer::new(2, reps).run(|| {
            stream::run(&exec, kernel, &mut arrays).unwrap();
        });
        t.row(&[
            kernel.name().into(),
            f2(bytes / st.min / 1e9),
            f2(st.rate_giga(bytes)),
        ]);
    }
    println!(
        "BabelStream on {} ({} elements, {} reps after 2 warmups):",
        exec.name(),
        n,
        reps
    );
    t.print();
    Ok(())
}

fn usage() {
    eprintln!(
        "usage: sparkle <command>\n\
         commands:\n\
           info                             runtime + artifact status\n\
           devices                          modeled GPU spec table\n\
           gen <name> [--scale N] [--out F] generate a Table-1 analog\n\
           spmv <mtx|name> [--exec E] [--format F] [--reps N] [--scale N]\n\
           stream [--exec E] [--n N] [--reps N]  BabelStream kernels\n\
           solve <mtx|name> [--solver S] [--exec E] [--tol T] [--iters N]\n\
           project <name> [--scale N]       device-model projection"
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = Opts::parse(&args);
    let cmd = opts.positional.first().cloned().unwrap_or_default();
    let result = match cmd.as_str() {
        "info" => cmd_info(),
        "devices" => {
            cmd_devices();
            Ok(())
        }
        "gen" => cmd_gen(&opts),
        "spmv" => cmd_spmv(&opts),
        "solve" => cmd_solve(&opts),
        "stream" => cmd_stream(&opts),
        "project" => cmd_project(&opts),
        _ => {
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
