//! Aggregation of an event stream into per-kernel / per-phase
//! roofline accounting — the report the paper's evaluation (§4–§6)
//! is built from.

use std::path::Path;

use super::event::{Event, KernelClass};
use crate::bench_util::{f2, Table};
use crate::core::types::Precision;
use crate::perfmodel::{Device, Roofline};

fn jnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn jstr_opt(v: &Option<String>) -> String {
    match v {
        Some(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
        None => "null".to_string(),
    }
}

/// Accumulated counters for one kernel (keyed by class + name + exec).
#[derive(Debug, Clone, PartialEq)]
pub struct KernelProfile {
    pub class: KernelClass,
    pub name: String,
    pub exec: String,
    pub calls: usize,
    pub seconds: f64,
    pub flops: f64,
    pub bytes: f64,
}

impl KernelProfile {
    /// Achieved GFLOP/s over all calls.
    pub fn gflops(&self) -> f64 {
        self.flops / self.seconds.max(1e-9) / 1e9
    }

    /// Achieved GB/s of useful traffic over all calls.
    pub fn gbs(&self) -> f64 {
        self.bytes / self.seconds.max(1e-9) / 1e9
    }

    /// Arithmetic intensity (flop/byte) of the useful-work model.
    pub fn intensity(&self) -> f64 {
        if self.bytes > 0.0 {
            self.flops / self.bytes
        } else {
            0.0
        }
    }

    /// Achieved fraction of the roofline-attainable rate at this
    /// kernel's intensity, clamped to 1.0 (host caches can beat a
    /// DRAM roofline on cache-resident workloads). `None` when the
    /// kernel has no flop model or never ran.
    pub fn efficiency(&self, roofline: &Roofline, p: Precision) -> Option<f64> {
        if self.flops <= 0.0 || self.bytes <= 0.0 || self.seconds <= 0.0 {
            return None;
        }
        let attainable = roofline.attainable_gflops(self.intensity(), p);
        if attainable <= 0.0 {
            return None;
        }
        Some((self.gflops() / attainable).min(1.0))
    }
}

/// Accumulated counters for one kernel class (the per-phase view:
/// "how much of this solve was SpMV vs BLAS-1 vs runtime dispatch").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseProfile {
    pub class: KernelClass,
    pub calls: usize,
    pub seconds: f64,
    pub flops: f64,
    pub bytes: f64,
}

/// A run's aggregated telemetry: per-kernel and per-phase breakdowns
/// plus solver/resilience/autotune headline numbers. Build one with
/// [`from_events`](Self::from_events), render it with
/// [`summary_table`](Self::summary_table), persist it with
/// [`write_json`](Self::write_json).
#[derive(Debug, Clone)]
pub struct Profile {
    /// Device whose roofline efficiencies are computed against.
    pub device: Device,
    /// Precision used for the roofline peak.
    pub precision: Precision,
    pub kernels: Vec<KernelProfile>,
    pub phases: Vec<PhaseProfile>,
    /// Solver of the last `SolverDone` event, if any.
    pub solver: Option<String>,
    pub iterations: usize,
    pub converged: bool,
    pub final_resnorm: f64,
    /// Total events aggregated.
    pub events: usize,
    pub checkpoints: usize,
    pub rollbacks: usize,
    pub fallbacks: usize,
    pub retries: usize,
    pub autotune_format: Option<String>,
    pub autotune_source: Option<String>,
}

impl Profile {
    /// Fold an event stream into a report. Order-insensitive except
    /// that the *last* `SolverDone` / `AutotuneDecision` wins.
    pub fn from_events(events: &[Event], device: Device, precision: Precision) -> Self {
        let mut profile = Profile {
            device,
            precision,
            kernels: Vec::new(),
            phases: Vec::new(),
            solver: None,
            iterations: 0,
            converged: false,
            final_resnorm: f64::NAN,
            events: events.len(),
            checkpoints: 0,
            rollbacks: 0,
            fallbacks: 0,
            retries: 0,
            autotune_format: None,
            autotune_source: None,
        };
        for event in events {
            match event {
                Event::KernelStop {
                    class,
                    name,
                    exec,
                    seconds,
                    flops,
                    bytes,
                } => profile.add_kernel(*class, name, exec, *seconds, *flops, *bytes),
                Event::Launch {
                    artifact, seconds, ..
                } => profile.add_kernel(KernelClass::Runtime, artifact, "xla", *seconds, 0.0, 0.0),
                Event::SolverDone {
                    solver,
                    iterations,
                    converged,
                    resnorm,
                } => {
                    profile.solver = Some(solver.clone());
                    profile.iterations = *iterations;
                    profile.converged = *converged;
                    profile.final_resnorm = *resnorm;
                }
                Event::Checkpoint { .. } => profile.checkpoints += 1,
                Event::Rollback { .. } => profile.rollbacks += 1,
                Event::Fallback { .. } => profile.fallbacks += 1,
                Event::Retry { .. } => profile.retries += 1,
                Event::AutotuneDecision { format, source, .. } => {
                    profile.autotune_format = Some(format.clone());
                    profile.autotune_source = Some(source.clone());
                }
                _ => {}
            }
        }
        profile
    }

    fn add_kernel(
        &mut self,
        class: KernelClass,
        name: &str,
        exec: &str,
        seconds: f64,
        flops: f64,
        bytes: f64,
    ) {
        let entry = match self
            .kernels
            .iter_mut()
            .find(|k| k.class == class && k.name == name && k.exec == exec)
        {
            Some(k) => k,
            None => {
                self.kernels.push(KernelProfile {
                    class,
                    name: name.to_string(),
                    exec: exec.to_string(),
                    calls: 0,
                    seconds: 0.0,
                    flops: 0.0,
                    bytes: 0.0,
                });
                self.kernels.last_mut().expect("just pushed")
            }
        };
        entry.calls += 1;
        entry.seconds += seconds;
        entry.flops += flops;
        entry.bytes += bytes;
        let phase = match self.phases.iter_mut().find(|p| p.class == class) {
            Some(p) => p,
            None => {
                self.phases.push(PhaseProfile {
                    class,
                    calls: 0,
                    seconds: 0.0,
                    flops: 0.0,
                    bytes: 0.0,
                });
                self.phases.last_mut().expect("just pushed")
            }
        };
        phase.calls += 1;
        phase.seconds += seconds;
        phase.flops += flops;
        phase.bytes += bytes;
    }

    /// Roofline model of the profile's device.
    pub fn roofline(&self) -> Roofline {
        Roofline::new(self.device.spec())
    }

    /// Total kernel-attributed wall time.
    pub fn total_seconds(&self) -> f64 {
        self.phases.iter().map(|p| p.seconds).sum()
    }

    /// Best SpMV roofline efficiency across kernels (the headline
    /// number of the paper's evaluation). `None` if no SpMV ran.
    pub fn best_spmv_efficiency(&self) -> Option<f64> {
        let roofline = self.roofline();
        self.kernels
            .iter()
            .filter(|k| k.class == KernelClass::Spmv)
            .filter_map(|k| k.efficiency(&roofline, self.precision))
            .fold(None, |best, e| {
                Some(best.map_or(e, |b: f64| b.max(e)))
            })
    }

    /// Per-kernel summary rendered with `bench_util::Table`.
    pub fn summary_table(&self) -> Table {
        let roofline = self.roofline();
        let mut table = Table::new(&[
            "kernel", "class", "exec", "calls", "time_ms", "GFLOP/s", "GB/s", "eff",
        ]);
        for k in &self.kernels {
            let eff = match k.efficiency(&roofline, self.precision) {
                Some(e) => f2(e),
                None => "-".to_string(),
            };
            table.row(&[
                k.name.clone(),
                k.class.name().to_string(),
                k.exec.clone(),
                k.calls.to_string(),
                f2(k.seconds * 1e3),
                f2(k.gflops()),
                f2(k.gbs()),
                eff,
            ]);
        }
        table
    }

    /// Serialize the whole report (schema `sparkle/observe/v1`).
    pub fn to_json(&self) -> String {
        let roofline = self.roofline();
        let mut s = String::with_capacity(4096);
        s.push_str("{\n");
        s.push_str("  \"schema\": \"sparkle/observe/v1\",\n");
        s.push_str(&format!(
            "  \"device\": \"{}\",\n",
            self.device.spec().name
        ));
        s.push_str(&format!(
            "  \"precision\": \"{}\",\n",
            self.precision.name()
        ));
        s.push_str(&format!("  \"solver\": {},\n", jstr_opt(&self.solver)));
        s.push_str(&format!("  \"iterations\": {},\n", self.iterations));
        s.push_str(&format!("  \"converged\": {},\n", self.converged));
        s.push_str(&format!(
            "  \"final_resnorm\": {},\n",
            jnum(self.final_resnorm)
        ));
        s.push_str(&format!("  \"events\": {},\n", self.events));
        s.push_str(&format!("  \"checkpoints\": {},\n", self.checkpoints));
        s.push_str(&format!("  \"rollbacks\": {},\n", self.rollbacks));
        s.push_str(&format!("  \"fallbacks\": {},\n", self.fallbacks));
        s.push_str(&format!("  \"retries\": {},\n", self.retries));
        s.push_str(&format!(
            "  \"autotune_format\": {},\n",
            jstr_opt(&self.autotune_format)
        ));
        s.push_str(&format!(
            "  \"autotune_source\": {},\n",
            jstr_opt(&self.autotune_source)
        ));
        s.push_str("  \"kernels\": [\n");
        for (i, k) in self.kernels.iter().enumerate() {
            let eff = match k.efficiency(&roofline, self.precision) {
                Some(e) => jnum(e),
                None => "null".to_string(),
            };
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"class\": \"{}\", \"exec\": \"{}\", \"calls\": {}, \
                 \"seconds\": {}, \"gflops\": {}, \"gbs\": {}, \"intensity\": {}, \
                 \"efficiency\": {}}}{}\n",
                k.name,
                k.class.name(),
                k.exec,
                k.calls,
                jnum(k.seconds),
                jnum(k.gflops()),
                jnum(k.gbs()),
                jnum(k.intensity()),
                eff,
                if i + 1 < self.kernels.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"phases\": [\n");
        for (i, p) in self.phases.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"class\": \"{}\", \"calls\": {}, \"seconds\": {}, \"flops\": {}, \
                 \"bytes\": {}}}{}\n",
                p.class.name(),
                p.calls,
                jnum(p.seconds),
                jnum(p.flops),
                jnum(p.bytes),
                if i + 1 < self.phases.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n");
        s.push_str("}\n");
        s
    }

    /// Write [`to_json`](Self::to_json) to `path`.
    pub fn write_json(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spmv_stop(seconds: f64) -> Event {
        Event::KernelStop {
            class: KernelClass::Spmv,
            name: "csr".to_string(),
            exec: "par".to_string(),
            seconds,
            flops: 2.0 * 4900.0,
            bytes: 4900.0 * 12.0 + 1001.0 * 4.0 + 2.0 * 1000.0 * 8.0,
        }
    }

    #[test]
    fn aggregates_calls_and_counts_bookkeeping_events() {
        let events = vec![
            spmv_stop(1e-5),
            spmv_stop(1e-5),
            Event::Checkpoint {
                solver: "cg".to_string(),
                at_iter: 10,
                true_resnorm: 1e-3,
            },
            Event::Retry {
                what: "execute".to_string(),
                attempt: 1,
            },
            Event::SolverDone {
                solver: "cg".to_string(),
                iterations: 42,
                converged: true,
                resnorm: 1e-9,
            },
        ];
        let p = Profile::from_events(&events, Device::Gen12, Precision::Double);
        assert_eq!(p.kernels.len(), 1);
        assert_eq!(p.kernels[0].calls, 2);
        assert_eq!(p.phases.len(), 1);
        assert_eq!(p.checkpoints, 1);
        assert_eq!(p.retries, 1);
        assert_eq!(p.iterations, 42);
        assert!(p.converged);
        assert_eq!(p.solver.as_deref(), Some("cg"));
    }

    #[test]
    fn efficiency_is_clamped_to_unit_interval() {
        // absurdly fast "measurement": would beat the roofline, must
        // clamp to exactly 1.0
        let p = Profile::from_events(&[spmv_stop(1e-12)], Device::Gen12, Precision::Double);
        let eff = p.best_spmv_efficiency().expect("spmv ran");
        assert_eq!(eff, 1.0);
        // plausibly slow measurement: strictly inside (0, 1)
        let p = Profile::from_events(&[spmv_stop(1.0)], Device::Gen12, Precision::Double);
        let eff = p.best_spmv_efficiency().expect("spmv ran");
        assert!(eff > 0.0 && eff < 1.0, "eff {eff}");
    }

    #[test]
    fn zero_flop_kernels_report_no_efficiency() {
        let events = vec![Event::Launch {
            artifact: "spmv_csr_f64".to_string(),
            seconds: 1e-4,
            ok: true,
        }];
        let p = Profile::from_events(&events, Device::Gen12, Precision::Double);
        assert_eq!(p.kernels.len(), 1);
        let roofline = p.roofline();
        assert_eq!(p.kernels[0].efficiency(&roofline, p.precision), None);
        assert_eq!(p.best_spmv_efficiency(), None);
    }

    #[test]
    fn json_report_carries_schema_and_kernels() {
        let p = Profile::from_events(&[spmv_stop(1e-5)], Device::Gen12, Precision::Double);
        let json = p.to_json();
        assert!(json.contains("\"schema\": \"sparkle/observe/v1\""));
        assert!(json.contains("\"name\": \"csr\""));
        assert!(json.contains("\"efficiency\": "));
        // summary table renders one data row
        assert_eq!(p.summary_table().len(), 1);
    }
}
