//! Event taxonomy and the [`Logger`] trait.
//!
//! Every instrumented layer reports through the same flat [`Event`]
//! enum so one sink sees the whole story of a solve: kernel launches
//! with their flop/byte models, solver iterations, recovery actions,
//! autotune decisions and runtime dispatch health. Events are plain
//! data (`Clone + PartialEq`) and serialize to single JSON lines via
//! [`Event::to_json_line`]; [`Event::from_json_line`] parses exactly
//! that format back, which is what makes the JSON-lines sink
//! round-trippable in tests.

/// Coarse kernel family, used to group per-kernel counters into
/// per-phase breakdowns in [`Profile`](crate::observe::Profile).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelClass {
    /// Sparse matrix-vector products (`kernels/spmv.rs`).
    Spmv,
    /// BLAS-1 vector operations (`kernels/blas.rs`).
    Blas,
    /// Ported-backend artifact launches (`runtime/client.rs`).
    Runtime,
}

impl KernelClass {
    /// Lowercase tag used in JSON output.
    pub fn name(self) -> &'static str {
        match self {
            KernelClass::Spmv => "spmv",
            KernelClass::Blas => "blas",
            KernelClass::Runtime => "runtime",
        }
    }

    /// Inverse of [`name`](Self::name).
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "spmv" => Some(KernelClass::Spmv),
            "blas" => Some(KernelClass::Blas),
            "runtime" => Some(KernelClass::Runtime),
            _ => None,
        }
    }
}

/// One observation from an instrumented code path.
///
/// String fields are owned so parsed events compare equal to emitted
/// ones; the allocation only happens when a logger is enabled (the
/// disabled path never constructs an `Event` at all).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A timed kernel began (paired with a following `KernelStop`).
    KernelStart { class: KernelClass, name: String },
    /// A timed kernel finished. `flops`/`bytes` are the useful-work
    /// model from `perfmodel::traffic` (SpMV) or the textbook BLAS-1
    /// footprint, which is what the roofline efficiency is computed
    /// against.
    KernelStop {
        class: KernelClass,
        name: String,
        exec: String,
        seconds: f64,
        flops: f64,
        bytes: f64,
    },
    /// A builder-driven solve began.
    SolverStart { solver: String, rows: usize },
    /// One Krylov iteration completed with the given recurrence
    /// residual norm.
    SolverIteration {
        solver: String,
        iteration: usize,
        resnorm: f64,
    },
    /// A builder-driven solve finished.
    SolverDone {
        solver: String,
        iterations: usize,
        converged: bool,
        resnorm: f64,
    },
    /// `ResilientSolver` advanced its verified checkpoint.
    Checkpoint {
        solver: String,
        at_iter: usize,
        true_resnorm: f64,
    },
    /// `ResilientSolver` rolled back to the last checkpoint.
    Rollback { solver: String, reason: String },
    /// The recurrence residual drifted away from the verified one.
    Drift {
        solver: String,
        recurrence: f64,
        true_resnorm: f64,
    },
    /// The fallback chain moved to its next solver.
    Fallback { from: String, to: String },
    /// Autotune timed one candidate format.
    AutotuneCandidate {
        format: String,
        median_us: f64,
        applies: usize,
    },
    /// Autotune committed to a format.
    AutotuneDecision {
        format: String,
        source: String,
        predicted_us: f64,
    },
    /// One ported-backend artifact execution (after retries).
    Launch {
        artifact: String,
        seconds: f64,
        ok: bool,
    },
    /// One failed dispatch attempt inside the retry loop.
    Retry { what: String, attempt: u32 },
    /// The runtime circuit breaker opened (backend degraded to host).
    BreakerOpen { failures: u64 },
}

/// Receiver for [`Event`]s. Implementations must be `Send + Sync`
/// because the logger slot is global (kernels have no per-call context
/// to thread a logger through).
pub trait Logger: Send + Sync {
    /// Handle one event. Called only while the logger is installed and
    /// [`enabled`](Self::enabled).
    fn log(&self, event: &Event);

    /// Whether this logger wants events at all. Returning `false`
    /// short-circuits the global emit path to a single relaxed atomic
    /// load — no event is constructed, no allocation happens.
    fn enabled(&self) -> bool {
        true
    }
}

/// The do-nothing logger: installing it keeps the event path disabled,
/// exactly as if no logger were installed.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullLogger;

impl Logger for NullLogger {
    fn log(&self, _event: &Event) {}

    fn enabled(&self) -> bool {
        false
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push(' '),
            c => out.push(c),
        }
    }
    out
}

/// Format a float as a JSON value (`null` for non-finite — JSON has no
/// NaN/Inf). Rust's `Display` for floats is shortest-round-trip, so a
/// finite value parses back bit-identically.
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn raw<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat)?;
    Some(line[at + pat.len()..].trim_start())
}

fn str_field(line: &str, key: &str) -> Option<String> {
    let rest = raw(line, key)?.strip_prefix('"')?;
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => return Some(out),
            '\\' => match chars.next()? {
                'n' => out.push('\n'),
                't' => out.push('\t'),
                other => out.push(other),
            },
            other => out.push(other),
        }
    }
    None
}

fn num_field(line: &str, key: &str) -> Option<f64> {
    let rest = raw(line, key)?;
    let end = rest
        .find(|c: char| c == ',' || c == '}')
        .unwrap_or(rest.len());
    let token = rest[..end].trim();
    if token == "null" {
        return Some(f64::NAN);
    }
    token.parse().ok()
}

fn usize_field(line: &str, key: &str) -> Option<usize> {
    let v = num_field(line, key)?;
    if v.is_finite() && v >= 0.0 {
        Some(v as usize)
    } else {
        None
    }
}

fn bool_field(line: &str, key: &str) -> Option<bool> {
    let rest = raw(line, key)?;
    if rest.starts_with("true") {
        Some(true)
    } else if rest.starts_with("false") {
        Some(false)
    } else {
        None
    }
}

impl Event {
    /// Lowercase type tag (the `"ev"` field of the JSON line).
    pub fn kind(&self) -> &'static str {
        match self {
            Event::KernelStart { .. } => "kernel_start",
            Event::KernelStop { .. } => "kernel_stop",
            Event::SolverStart { .. } => "solver_start",
            Event::SolverIteration { .. } => "solver_iteration",
            Event::SolverDone { .. } => "solver_done",
            Event::Checkpoint { .. } => "checkpoint",
            Event::Rollback { .. } => "rollback",
            Event::Drift { .. } => "drift",
            Event::Fallback { .. } => "fallback",
            Event::AutotuneCandidate { .. } => "autotune_candidate",
            Event::AutotuneDecision { .. } => "autotune_decision",
            Event::Launch { .. } => "launch",
            Event::Retry { .. } => "retry",
            Event::BreakerOpen { .. } => "breaker_open",
        }
    }

    /// Serialize to one JSON object on a single line.
    pub fn to_json_line(&self) -> String {
        let tag = self.kind();
        match self {
            Event::KernelStart { class, name } => format!(
                "{{\"ev\": \"{tag}\", \"class\": \"{}\", \"name\": \"{}\"}}",
                class.name(),
                escape(name)
            ),
            Event::KernelStop {
                class,
                name,
                exec,
                seconds,
                flops,
                bytes,
            } => format!(
                "{{\"ev\": \"{tag}\", \"class\": \"{}\", \"name\": \"{}\", \"exec\": \"{}\", \
                 \"seconds\": {}, \"flops\": {}, \"bytes\": {}}}",
                class.name(),
                escape(name),
                escape(exec),
                num(*seconds),
                num(*flops),
                num(*bytes)
            ),
            Event::SolverStart { solver, rows } => format!(
                "{{\"ev\": \"{tag}\", \"solver\": \"{}\", \"rows\": {rows}}}",
                escape(solver)
            ),
            Event::SolverIteration {
                solver,
                iteration,
                resnorm,
            } => format!(
                "{{\"ev\": \"{tag}\", \"solver\": \"{}\", \"iteration\": {iteration}, \
                 \"resnorm\": {}}}",
                escape(solver),
                num(*resnorm)
            ),
            Event::SolverDone {
                solver,
                iterations,
                converged,
                resnorm,
            } => format!(
                "{{\"ev\": \"{tag}\", \"solver\": \"{}\", \"iterations\": {iterations}, \
                 \"converged\": {converged}, \"resnorm\": {}}}",
                escape(solver),
                num(*resnorm)
            ),
            Event::Checkpoint {
                solver,
                at_iter,
                true_resnorm,
            } => format!(
                "{{\"ev\": \"{tag}\", \"solver\": \"{}\", \"at_iter\": {at_iter}, \
                 \"true_resnorm\": {}}}",
                escape(solver),
                num(*true_resnorm)
            ),
            Event::Rollback { solver, reason } => format!(
                "{{\"ev\": \"{tag}\", \"solver\": \"{}\", \"reason\": \"{}\"}}",
                escape(solver),
                escape(reason)
            ),
            Event::Drift {
                solver,
                recurrence,
                true_resnorm,
            } => format!(
                "{{\"ev\": \"{tag}\", \"solver\": \"{}\", \"recurrence\": {}, \
                 \"true_resnorm\": {}}}",
                escape(solver),
                num(*recurrence),
                num(*true_resnorm)
            ),
            Event::Fallback { from, to } => format!(
                "{{\"ev\": \"{tag}\", \"from\": \"{}\", \"to\": \"{}\"}}",
                escape(from),
                escape(to)
            ),
            Event::AutotuneCandidate {
                format,
                median_us,
                applies,
            } => format!(
                "{{\"ev\": \"{tag}\", \"format\": \"{}\", \"median_us\": {}, \
                 \"applies\": {applies}}}",
                escape(format),
                num(*median_us)
            ),
            Event::AutotuneDecision {
                format,
                source,
                predicted_us,
            } => format!(
                "{{\"ev\": \"{tag}\", \"format\": \"{}\", \"source\": \"{}\", \
                 \"predicted_us\": {}}}",
                escape(format),
                escape(source),
                num(*predicted_us)
            ),
            Event::Launch {
                artifact,
                seconds,
                ok,
            } => format!(
                "{{\"ev\": \"{tag}\", \"artifact\": \"{}\", \"seconds\": {}, \"ok\": {ok}}}",
                escape(artifact),
                num(*seconds)
            ),
            Event::Retry { what, attempt } => format!(
                "{{\"ev\": \"{tag}\", \"what\": \"{}\", \"attempt\": {attempt}}}",
                escape(what)
            ),
            Event::BreakerOpen { failures } => {
                format!("{{\"ev\": \"{tag}\", \"failures\": {failures}}}")
            }
        }
    }

    /// Parse one line produced by [`to_json_line`](Self::to_json_line).
    /// Not a general JSON parser — it understands exactly the sink's
    /// own output, which is all the round-trip guarantee requires.
    pub fn from_json_line(line: &str) -> Option<Event> {
        let tag = str_field(line, "ev")?;
        match tag.as_str() {
            "kernel_start" => Some(Event::KernelStart {
                class: KernelClass::from_name(&str_field(line, "class")?)?,
                name: str_field(line, "name")?,
            }),
            "kernel_stop" => Some(Event::KernelStop {
                class: KernelClass::from_name(&str_field(line, "class")?)?,
                name: str_field(line, "name")?,
                exec: str_field(line, "exec")?,
                seconds: num_field(line, "seconds")?,
                flops: num_field(line, "flops")?,
                bytes: num_field(line, "bytes")?,
            }),
            "solver_start" => Some(Event::SolverStart {
                solver: str_field(line, "solver")?,
                rows: usize_field(line, "rows")?,
            }),
            "solver_iteration" => Some(Event::SolverIteration {
                solver: str_field(line, "solver")?,
                iteration: usize_field(line, "iteration")?,
                resnorm: num_field(line, "resnorm")?,
            }),
            "solver_done" => Some(Event::SolverDone {
                solver: str_field(line, "solver")?,
                iterations: usize_field(line, "iterations")?,
                converged: bool_field(line, "converged")?,
                resnorm: num_field(line, "resnorm")?,
            }),
            "checkpoint" => Some(Event::Checkpoint {
                solver: str_field(line, "solver")?,
                at_iter: usize_field(line, "at_iter")?,
                true_resnorm: num_field(line, "true_resnorm")?,
            }),
            "rollback" => Some(Event::Rollback {
                solver: str_field(line, "solver")?,
                reason: str_field(line, "reason")?,
            }),
            "drift" => Some(Event::Drift {
                solver: str_field(line, "solver")?,
                recurrence: num_field(line, "recurrence")?,
                true_resnorm: num_field(line, "true_resnorm")?,
            }),
            "fallback" => Some(Event::Fallback {
                from: str_field(line, "from")?,
                to: str_field(line, "to")?,
            }),
            "autotune_candidate" => Some(Event::AutotuneCandidate {
                format: str_field(line, "format")?,
                median_us: num_field(line, "median_us")?,
                applies: usize_field(line, "applies")?,
            }),
            "autotune_decision" => Some(Event::AutotuneDecision {
                format: str_field(line, "format")?,
                source: str_field(line, "source")?,
                predicted_us: num_field(line, "predicted_us")?,
            }),
            "launch" => Some(Event::Launch {
                artifact: str_field(line, "artifact")?,
                seconds: num_field(line, "seconds")?,
                ok: bool_field(line, "ok")?,
            }),
            "retry" => Some(Event::Retry {
                what: str_field(line, "what")?,
                attempt: usize_field(line, "attempt")? as u32,
            }),
            "breaker_open" => Some(Event::BreakerOpen {
                failures: num_field(line, "failures")? as u64,
            }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_with_quotes_and_backslashes_round_trip() {
        let e = Event::Rollback {
            solver: "cg".to_string(),
            reason: "transient: execute \"spmv\" failed \\ twice".to_string(),
        };
        let line = e.to_json_line();
        assert_eq!(Event::from_json_line(&line), Some(e));
    }

    #[test]
    fn non_finite_floats_serialize_as_null() {
        let e = Event::SolverIteration {
            solver: "cg".to_string(),
            iteration: 1,
            resnorm: f64::NAN,
        };
        let line = e.to_json_line();
        assert!(line.contains("\"resnorm\": null"), "{line}");
        // null parses back to NaN (the event compares unequal — NaN —
        // but the parse itself must not fail)
        match Event::from_json_line(&line) {
            Some(Event::SolverIteration { resnorm, .. }) => assert!(resnorm.is_nan()),
            other => panic!("bad parse: {other:?}"),
        }
    }

    #[test]
    fn unknown_tag_is_rejected() {
        assert_eq!(Event::from_json_line("{\"ev\": \"nonsense\"}"), None);
        assert_eq!(Event::from_json_line("not json at all"), None);
    }

    #[test]
    fn kernel_class_names_round_trip() {
        for class in [KernelClass::Spmv, KernelClass::Blas, KernelClass::Runtime] {
            assert_eq!(KernelClass::from_name(class.name()), Some(class));
        }
        assert_eq!(KernelClass::from_name("bogus"), None);
    }
}
