//! Runtime observability: a Ginkgo-style Logger/Event layer.
//!
//! The paper's evaluation method is per-kernel achieved-vs-roofline
//! accounting; the sibling Ginkgo ports expose that accounting through
//! an event/Logger layer instead of ad-hoc benches. This module is
//! that layer for sparkle:
//!
//! - [`Event`] — flat taxonomy covering kernel start/stop (with
//!   flop/byte models from [`perfmodel::traffic`](crate::perfmodel)),
//!   solver iterations, resilience checkpoints/rollbacks/fallbacks,
//!   autotune candidates/decisions, and runtime dispatch health.
//! - [`Logger`] — the sink trait; [`Record`] (in-memory),
//!   [`JsonlLogger`] (streaming JSON lines) and [`NullLogger`] are
//!   built in.
//! - [`Profile`] — aggregates an event stream into per-kernel and
//!   per-phase breakdowns with GF/s, GB/s and roofline efficiency
//!   against a [`perfmodel::Device`](crate::perfmodel::Device).
//!
//! # Zero cost when disabled
//!
//! The logger slot is global (kernel dispatch has no per-call context
//! to thread a logger through). [`emit`] takes a *closure* that builds
//! the event, and the disabled path is a single relaxed atomic load:
//! no event is constructed, nothing allocates, no lock is touched.
//! Instrumented call sites therefore cost one branch when nothing is
//! installed.
//!
//! # Usage
//!
//! ```ignore
//! let rec = std::sync::Arc::new(observe::Record::new());
//! let _scope = observe::install_scoped(rec.clone());
//! solver.solve(&a, &b, &mut x)?;
//! drop(_scope); // previous logger (usually none) restored
//! let profile = observe::Profile::from_events(
//!     &rec.events(), Device::Gen12, Precision::Double);
//! profile.summary_table().print();
//! ```

pub mod event;
pub mod profile;
pub mod sink;

pub use event::{Event, KernelClass, Logger, NullLogger};
pub use profile::{KernelProfile, PhaseProfile, Profile};
pub use sink::{JsonlLogger, Record};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use crate::core::types::Precision;
use crate::matgen::MatrixStats;
use crate::perfmodel::traffic::{spmv_flops, spmv_useful_bytes, FusedBlasKind, SpmvKernelKind};

/// Fast-path switch: `true` iff an enabled logger is installed.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// The installed logger, if any. Only read after `ENABLED` says so,
/// and on the (cold) install/uninstall paths.
static LOGGER: RwLock<Option<Arc<dyn Logger>>> = RwLock::new(None);

/// Whether an enabled logger is currently installed. One relaxed
/// atomic load — this is the branch every instrumented site pays.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Install `logger` globally, replacing (and returning) any previous
/// one. Prefer [`install_scoped`] which restores the previous logger
/// automatically.
pub fn install(logger: Arc<dyn Logger>) -> Option<Arc<dyn Logger>> {
    let on = logger.enabled();
    let prev = {
        let mut slot = LOGGER.write().unwrap_or_else(|p| p.into_inner());
        slot.replace(logger)
    };
    ENABLED.store(on, Ordering::Relaxed);
    prev
}

/// Remove the global logger, returning it.
pub fn uninstall() -> Option<Arc<dyn Logger>> {
    let prev = {
        let mut slot = LOGGER.write().unwrap_or_else(|p| p.into_inner());
        slot.take()
    };
    ENABLED.store(false, Ordering::Relaxed);
    prev
}

/// Install `logger` for the lifetime of the returned guard; dropping
/// the guard restores whatever was installed before.
pub fn install_scoped(logger: Arc<dyn Logger>) -> ScopedLogger {
    let prev = install(logger);
    ScopedLogger { prev }
}

/// RAII guard from [`install_scoped`].
pub struct ScopedLogger {
    prev: Option<Arc<dyn Logger>>,
}

impl Drop for ScopedLogger {
    fn drop(&mut self) {
        match self.prev.take() {
            Some(prev) => {
                install(prev);
            }
            None => {
                uninstall();
            }
        }
    }
}

/// Emit an event. `make` runs only when an enabled logger is
/// installed, so the disabled path constructs nothing.
#[inline]
pub fn emit(make: impl FnOnce() -> Event) {
    if enabled() {
        dispatch(&make());
    }
}

#[cold]
fn dispatch(event: &Event) {
    let slot = LOGGER.read().unwrap_or_else(|p| p.into_inner());
    if let Some(logger) = slot.as_ref() {
        logger.log(event);
    }
}

/// Convenience helper for the six Krylov drivers: one iteration of
/// `solver` finished with recurrence residual `resnorm`.
#[inline]
pub fn solver_iteration(solver: &'static str, iteration: usize, resnorm: f64) {
    emit(|| Event::SolverIteration {
        solver: solver.to_string(),
        iteration,
        resnorm,
    });
}

/// Scoped kernel timer. Construction emits [`Event::KernelStart`];
/// dropping it emits [`Event::KernelStop`] carrying the wall time and
/// the useful-work model. `new` returns `None` when no logger is
/// enabled, so bind it as `let _obs = ...;` and the disabled path
/// costs one branch.
pub struct KernelGuard {
    class: KernelClass,
    name: &'static str,
    exec: &'static str,
    flops: f64,
    bytes: f64,
    start: Instant,
}

impl KernelGuard {
    /// Start timing `name` (a kernel of `class` on executor `exec`)
    /// with the given useful-work model. Returns `None` (no timing,
    /// no events) when observability is off.
    #[inline]
    pub fn new(
        class: KernelClass,
        name: &'static str,
        exec: &'static str,
        flops: f64,
        bytes: f64,
    ) -> Option<KernelGuard> {
        if !enabled() {
            return None;
        }
        dispatch(&Event::KernelStart {
            class,
            name: name.to_string(),
        });
        Some(KernelGuard {
            class,
            name,
            exec,
            flops,
            bytes,
            start: Instant::now(),
        })
    }
}

impl Drop for KernelGuard {
    fn drop(&mut self) {
        let seconds = self.start.elapsed().as_secs_f64();
        dispatch(&Event::KernelStop {
            class: self.class,
            name: self.name.to_string(),
            exec: self.exec.to_string(),
            seconds,
            flops: self.flops,
            bytes: self.bytes,
        });
    }
}

/// Guard for an SpMV kernel: flop/byte model from
/// `perfmodel::traffic` (2·nnz flops; format-specific useful bytes).
#[inline]
pub fn spmv_guard(
    name: &'static str,
    exec: &'static str,
    rows: usize,
    nnz: usize,
    precision: Precision,
) -> Option<KernelGuard> {
    if !enabled() {
        return None;
    }
    let kind = match name {
        "csr" => SpmvKernelKind::Csr,
        "coo" => SpmvKernelKind::Coo,
        "ell" => SpmvKernelKind::Ell,
        _ => SpmvKernelKind::SellP,
    };
    let stats = MatrixStats {
        n: rows,
        nnz,
        avg_row: nnz as f64 / rows.max(1) as f64,
        max_row: 0,
        row_cv: 0.0,
        bandwidth_frac: 0.0,
    };
    KernelGuard::new(
        KernelClass::Spmv,
        name,
        exec,
        spmv_flops(&stats),
        spmv_useful_bytes(kind, &stats, precision),
    )
}

/// Guard for a BLAS-1 kernel with an explicit flop/byte model.
#[inline]
pub fn blas_guard(
    name: &'static str,
    exec: &'static str,
    flops: f64,
    bytes: f64,
) -> Option<KernelGuard> {
    if !enabled() {
        return None;
    }
    KernelGuard::new(KernelClass::Blas, name, exec, flops, bytes)
}

/// Guard for a fused BLAS-1 kernel: the flop/byte model comes from
/// `perfmodel::traffic::FusedBlasKind`, so the roofline profile credits
/// the *fused* (reduced) byte count, not the composed sequence's.
#[inline]
pub fn fused_blas_guard(
    kind: FusedBlasKind,
    exec: &'static str,
    n: usize,
    precision: Precision,
) -> Option<KernelGuard> {
    if !enabled() {
        return None;
    }
    KernelGuard::new(
        KernelClass::Blas,
        kind.name(),
        exec,
        kind.flops(n),
        kind.useful_bytes(n, precision),
    )
}

/// Guard for a fused SpMV+dot kernel (`x = A b` with `(w·x, x·x)` in
/// the same logical pass): the SpMV footprint plus one extra read of w,
/// with x read once instead of the composed path's twice.
#[inline]
pub fn spmv_dot_guard(
    name: &'static str,
    exec: &'static str,
    rows: usize,
    nnz: usize,
    precision: Precision,
) -> Option<KernelGuard> {
    if !enabled() {
        return None;
    }
    let kind = match name {
        "csr_dot" => SpmvKernelKind::Csr,
        "ell_dot" => SpmvKernelKind::Ell,
        _ => SpmvKernelKind::SellP,
    };
    let stats = MatrixStats {
        n: rows,
        nnz,
        avg_row: nnz as f64 / rows.max(1) as f64,
        max_row: 0,
        row_cv: 0.0,
        bandwidth_frac: 0.0,
    };
    let elem = precision.bytes() as f64;
    KernelGuard::new(
        KernelClass::Spmv,
        name,
        exec,
        spmv_flops(&stats) + 4.0 * rows as f64,
        spmv_useful_bytes(kind, &stats, precision) + rows as f64 * elem,
    )
}
