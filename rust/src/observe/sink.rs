//! Built-in logger sinks: in-memory [`Record`] and streaming
//! JSON-lines ([`JsonlLogger`]).

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::sync::Mutex;

use super::event::{Event, Logger};

/// In-memory sink: keeps every event, in order, for later inspection
/// or aggregation into a [`Profile`](crate::observe::Profile).
#[derive(Debug, Default)]
pub struct Record {
    events: Mutex<Vec<Event>>,
}

impl Record {
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot of all events logged so far.
    pub fn events(&self) -> Vec<Event> {
        self.events
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .clone()
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        self.events
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .clear();
    }
}

impl Logger for Record {
    fn log(&self, event: &Event) {
        self.events
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push(event.clone());
    }
}

enum JsonlSink {
    Memory(Mutex<Vec<String>>),
    File(Mutex<BufWriter<File>>),
}

/// Streaming JSON-lines sink: one JSON object per event, either
/// buffered in memory ([`in_memory`](Self::in_memory)) or appended to
/// a file ([`to_file`](Self::to_file)).
pub struct JsonlLogger {
    sink: JsonlSink,
}

impl JsonlLogger {
    /// Buffer lines in memory; retrieve them with
    /// [`lines`](Self::lines).
    pub fn in_memory() -> Self {
        JsonlLogger {
            sink: JsonlSink::Memory(Mutex::new(Vec::new())),
        }
    }

    /// Stream lines to `path` (truncating any existing file).
    pub fn to_file(path: impl AsRef<Path>) -> io::Result<Self> {
        let file = File::create(path)?;
        Ok(JsonlLogger {
            sink: JsonlSink::File(Mutex::new(BufWriter::new(file))),
        })
    }

    /// Lines collected so far (empty for file-backed sinks).
    pub fn lines(&self) -> Vec<String> {
        match &self.sink {
            JsonlSink::Memory(lines) => lines.lock().unwrap_or_else(|p| p.into_inner()).clone(),
            JsonlSink::File(_) => Vec::new(),
        }
    }

    /// Flush buffered output (no-op for the in-memory sink).
    pub fn flush(&self) -> io::Result<()> {
        match &self.sink {
            JsonlSink::Memory(_) => Ok(()),
            JsonlSink::File(w) => w.lock().unwrap_or_else(|p| p.into_inner()).flush(),
        }
    }
}

impl Logger for JsonlLogger {
    fn log(&self, event: &Event) {
        let line = event.to_json_line();
        match &self.sink {
            JsonlSink::Memory(lines) => {
                lines.lock().unwrap_or_else(|p| p.into_inner()).push(line);
            }
            JsonlSink::File(w) => {
                let mut w = w.lock().unwrap_or_else(|p| p.into_inner());
                // a failed telemetry write must never take the solve
                // down with it
                let _ = writeln!(w, "{line}");
            }
        }
    }
}

impl Drop for JsonlLogger {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::observe::event::KernelClass;

    #[test]
    fn record_keeps_order_and_clears() {
        let rec = Record::new();
        assert!(rec.is_empty());
        rec.log(&Event::SolverStart {
            solver: "cg".to_string(),
            rows: 16,
        });
        rec.log(&Event::KernelStart {
            class: KernelClass::Spmv,
            name: "csr".to_string(),
        });
        assert_eq!(rec.len(), 2);
        match &rec.events()[0] {
            Event::SolverStart { solver, rows } => {
                assert_eq!(solver, "cg");
                assert_eq!(*rows, 16);
            }
            other => panic!("order lost: {other:?}"),
        }
        rec.clear();
        assert!(rec.is_empty());
    }

    #[test]
    fn in_memory_jsonl_lines_parse_back() {
        let sink = JsonlLogger::in_memory();
        let e = Event::Fallback {
            from: "cg".to_string(),
            to: "bicgstab".to_string(),
        };
        sink.log(&e);
        let lines = sink.lines();
        assert_eq!(lines.len(), 1);
        assert_eq!(Event::from_json_line(&lines[0]), Some(e));
    }
}
