//! Recovery policies: [`ResilientSolver`] wraps the plain Krylov
//! drivers with checkpoint/restart, true-residual verification and a
//! solver fallback chain.
//!
//! The wrapper runs its inner solver in *segments* of
//! `checkpoint_every` iterations. Each segment boundary doubles as the
//! true-residual recompute cadence: the recurrence residual the inner
//! solver reports is cross-checked against `||b - A x||` computed on
//! host data, which is what catches silent corruption (bit-flips) that
//! the recurrence happily propagates. On breakdown, transient failure
//! or a stagnant/worsened segment, the iterate is rolled back to the
//! last verified checkpoint and the solve restarts; after
//! `max_restarts` rollbacks the next solver in the chain takes over
//! from the checkpoint.

use crate::core::error::{Result, SparkleError};
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::matrix::dense::Dense;
use crate::solver::{
    BiCgStab, Cg, Cgs, Fcg, Gmres, Richardson, SolveResult, Solver, SolverConfig,
};
use crate::stop::{Breakdown, Criterion, StopStatus};

use super::detect::BreakdownPolicy;

/// Buildable solver identities for the fallback chain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SolverKind {
    /// Conjugate Gradient (SPD systems).
    Cg,
    /// Flexible CG.
    Fcg,
    /// BiCGSTAB (general systems).
    BiCgStab,
    /// CGS (general systems).
    Cgs,
    /// GMRES(m) with the given restart length.
    Gmres { restart: usize },
    /// Richardson with relaxation factor omega.
    Richardson { omega: f64 },
}

impl SolverKind {
    /// Solver name (matches each driver's `Solver::name`).
    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Cg => "cg",
            SolverKind::Fcg => "fcg",
            SolverKind::BiCgStab => "bicgstab",
            SolverKind::Cgs => "cgs",
            SolverKind::Gmres { .. } => "gmres",
            SolverKind::Richardson { .. } => "richardson",
        }
    }

    /// Instantiate the driver with the given config.
    pub fn build<T: Value>(&self, config: SolverConfig) -> Box<dyn Solver<T>> {
        match self {
            SolverKind::Cg => Box::new(Cg::new(config)),
            SolverKind::Fcg => Box::new(Fcg::new(config)),
            SolverKind::BiCgStab => Box::new(BiCgStab::new(config)),
            SolverKind::Cgs => Box::new(Cgs::new(config)),
            SolverKind::Gmres { restart } => {
                Box::new(Gmres::new(config).with_restart((*restart).max(1)))
            }
            SolverKind::Richardson { omega } => {
                Box::new(Richardson::new(config, T::from_f64(*omega)))
            }
        }
    }
}

/// Knobs of the recovery loop.
#[derive(Debug, Clone, Copy)]
pub struct RecoveryPolicy {
    /// Segment length: iterations between checkpoints, which is also
    /// the true-residual recompute cadence.
    pub checkpoint_every: usize,
    /// Rollback-and-restart attempts per chain entry before falling
    /// back to the next solver.
    pub max_restarts: usize,
    /// A segment counts as progress when its verified true residual
    /// shrinks below `best * min_improvement` (slightly under 1.0 so
    /// float noise does not count as progress).
    pub min_improvement: f64,
    /// Flag recurrence drift when `true_res > recurrence * drift_factor`.
    pub drift_factor: f64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        Self {
            checkpoint_every: 50,
            max_restarts: 2,
            min_improvement: 0.999,
            drift_factor: 100.0,
        }
    }
}

/// What happened during a resilient solve, in order.
#[derive(Debug, Clone)]
pub enum RecoveryEvent {
    /// Inner solver reported a structured breakdown; rolled back.
    BreakdownRestart {
        solver: &'static str,
        breakdown: Breakdown,
        at_iter: usize,
    },
    /// Inner solve (or residual verification) returned an error;
    /// rolled back.
    TransientRestart {
        solver: &'static str,
        error: String,
    },
    /// A segment finished without improving the true residual; rolled
    /// back.
    StagnationRestart {
        solver: &'static str,
        true_resnorm: f64,
    },
    /// The recurrence residual disagreed with the verified one by more
    /// than `drift_factor` (silent corruption or lost orthogonality).
    DriftDetected {
        solver: &'static str,
        recurrence: f64,
        true_resnorm: f64,
    },
    /// Restarts exhausted; the next chain entry took over.
    Fallback {
        from: &'static str,
        to: &'static str,
    },
}

/// Structured outcome of a resilient solve.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    /// Aggregate result. `resnorm` here is the *verified* true residual
    /// norm, and `status` carries the final breakdown when recovery was
    /// exhausted.
    pub result: SolveResult,
    /// Chain entry that produced the final state.
    pub solver: &'static str,
    /// Verified `||b - A x||` of the returned iterate.
    pub true_resnorm: f64,
    /// Rollback-restarts performed (breakdown + transient + stagnation).
    pub restarts: usize,
    /// Chain fallbacks performed.
    pub fallbacks: usize,
    /// Full event log, in order.
    pub events: Vec<RecoveryEvent>,
}

impl SolveOutcome {
    /// Converged only after at least one recovery action.
    pub fn recovered(&self) -> bool {
        self.result.converged && !self.events.is_empty()
    }
}

/// Fault-tolerant wrapper around the plain Krylov drivers.
///
/// ```
/// # use sparkle::resilience::ResilientSolver;
/// # use sparkle::stop::Criterion;
/// let solver = ResilientSolver::new(Criterion::residual(1e-8, 2000));
/// // solver.solve_outcome(&a, &b, &mut x)?
/// ```
#[derive(Debug, Clone)]
pub struct ResilientSolver {
    chain: Vec<SolverKind>,
    criterion: Criterion,
    policy: RecoveryPolicy,
    breakdown: BreakdownPolicy,
}

impl ResilientSolver {
    /// Default chain CG → BiCGSTAB → GMRES(30) with stagnation
    /// detection enabled for the inner segments.
    pub fn new(criterion: Criterion) -> Self {
        Self {
            chain: vec![
                SolverKind::Cg,
                SolverKind::BiCgStab,
                SolverKind::Gmres { restart: 30 },
            ],
            criterion,
            policy: RecoveryPolicy::default(),
            breakdown: BreakdownPolicy {
                stagnation_window: 25,
                ..BreakdownPolicy::default()
            },
        }
    }

    /// Replace the fallback chain (must not be empty).
    pub fn with_chain(mut self, chain: Vec<SolverKind>) -> Self {
        assert!(!chain.is_empty(), "fallback chain must not be empty");
        self.chain = chain;
        self
    }

    /// Replace the recovery policy.
    pub fn with_policy(mut self, policy: RecoveryPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Replace the breakdown-detection policy handed to inner solvers.
    pub fn with_breakdown(mut self, breakdown: BreakdownPolicy) -> Self {
        self.breakdown = breakdown;
        self
    }

    fn converged(&self, true_res: f64, bnorm: f64) -> bool {
        (self.criterion.rel_tol > 0.0 && true_res <= self.criterion.rel_tol * bnorm)
            || (self.criterion.abs_tol > 0.0 && true_res <= self.criterion.abs_tol)
    }

    /// `||b - A x||` from host data. Retried a few times because with a
    /// faulty operator the verification apply itself can fail
    /// transiently or come back poisoned.
    fn true_residual<T: Value>(
        a: &dyn LinOp<T>,
        b: &Dense<T>,
        x: &Dense<T>,
    ) -> Result<f64> {
        let once = |x: &Dense<T>| -> Result<f64> {
            let mut r = b.clone();
            a.apply_advanced(-T::one(), x, T::one(), &mut r)?;
            Ok(r.norm2_host())
        };
        let mut last: Result<f64> = Ok(f64::NAN);
        for _ in 0..3 {
            match once(x) {
                Ok(v) if v.is_finite() => return Ok(v),
                other => last = other,
            }
        }
        last
    }

    /// Full recovery loop; returns the structured outcome (never an
    /// error for numerical failures — those are in `result.status`).
    pub fn solve_outcome<T: Value>(
        &self,
        a: &dyn LinOp<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveOutcome> {
        a.check_conformant(b, x)?;
        let bnorm = b.norm2_host();
        let budget = if self.criterion.max_iters == 0 {
            usize::MAX
        } else {
            self.criterion.max_iters
        };
        let seg = self.policy.checkpoint_every.max(1);

        let mut events: Vec<RecoveryEvent> = Vec::new();
        let mut total = 0usize;
        let mut restarts = 0usize;
        let mut last_breakdown: Option<Breakdown> = None;

        // establish a verified starting checkpoint
        let mut best_true = match Self::true_residual(a, b, x) {
            Ok(v) if v.is_finite() => v,
            _ => {
                // the caller's initial guess is unverifiable — restart
                // from zero, the one state we can always trust
                x.fill(T::zero());
                bnorm
            }
        };
        let mut checkpoint = x.clone();

        if self.converged(best_true, bnorm) {
            return Ok(SolveOutcome {
                result: SolveResult {
                    iterations: 0,
                    resnorm: best_true,
                    converged: true,
                    status: StopStatus::Converged,
                    history: Vec::new(),
                },
                solver: self.chain[0].name(),
                true_resnorm: best_true,
                restarts: 0,
                fallbacks: 0,
                events,
            });
        }

        let mut final_solver = self.chain[0].name();
        let mut fallbacks = 0usize;

        'chain: for (ci, kind) in self.chain.iter().enumerate() {
            if ci > 0 {
                events.push(RecoveryEvent::Fallback {
                    from: self.chain[ci - 1].name(),
                    to: kind.name(),
                });
                crate::observe::emit(|| crate::observe::Event::Fallback {
                    from: self.chain[ci - 1].name().to_string(),
                    to: kind.name().to_string(),
                });
                fallbacks = ci;
            }
            final_solver = kind.name();
            let mut restarts_left = self.policy.max_restarts;

            // every pass through this loop either consumes iteration
            // budget (any Ok segment) or burns one of the bounded
            // restarts, so the solve always terminates
            loop {
                if total >= budget {
                    break 'chain;
                }
                let mut crit = self.criterion.clone();
                crit.max_iters = seg.min(budget - total);
                let mut cfg = SolverConfig::with_criterion(crit);
                cfg.breakdown = self.breakdown;
                let solver = kind.build::<T>(cfg);

                // run one segment, classify it into either verified
                // progress (continue), convergence (return), or a
                // rollback event (fall through)
                let rollback: RecoveryEvent = match solver.solve(a, b, x) {
                    Err(e) => RecoveryEvent::TransientRestart {
                        solver: kind.name(),
                        error: e.to_string(),
                    },
                    Ok(r) => {
                        total += r.iterations.max(1);
                        match Self::true_residual(a, b, x) {
                            Err(e) => RecoveryEvent::TransientRestart {
                                solver: kind.name(),
                                error: e.to_string(),
                            },
                            Ok(tr) if !tr.is_finite() => {
                                // the iterate itself is poisoned
                                let bd = r.breakdown().unwrap_or(Breakdown::NanResidual);
                                last_breakdown = Some(bd);
                                RecoveryEvent::BreakdownRestart {
                                    solver: kind.name(),
                                    breakdown: bd,
                                    at_iter: total,
                                }
                            }
                            Ok(tr) => {
                                if r.resnorm.is_finite()
                                    && r.resnorm >= 0.0
                                    && tr > r.resnorm * self.policy.drift_factor
                                    && tr > self.criterion.abs_tol
                                {
                                    events.push(RecoveryEvent::DriftDetected {
                                        solver: kind.name(),
                                        recurrence: r.resnorm,
                                        true_resnorm: tr,
                                    });
                                    crate::observe::emit(|| crate::observe::Event::Drift {
                                        solver: kind.name().to_string(),
                                        recurrence: r.resnorm,
                                        true_resnorm: tr,
                                    });
                                }
                                // convergence is only ever declared on
                                // the verified residual — a lying
                                // recurrence cannot produce a silent
                                // wrong answer here
                                if self.converged(tr, bnorm) {
                                    return Ok(SolveOutcome {
                                        result: SolveResult {
                                            iterations: total,
                                            resnorm: tr,
                                            converged: true,
                                            status: StopStatus::Converged,
                                            history: r.history,
                                        },
                                        solver: kind.name(),
                                        true_resnorm: tr,
                                        restarts,
                                        fallbacks,
                                        events,
                                    });
                                }
                                if let Some(bd) = r.breakdown() {
                                    last_breakdown = Some(bd);
                                    // the iterate is finite; keep it as
                                    // the checkpoint if it improved
                                    if tr < best_true {
                                        checkpoint.copy_from(x)?;
                                        best_true = tr;
                                        crate::observe::emit(|| {
                                            crate::observe::Event::Checkpoint {
                                                solver: kind.name().to_string(),
                                                at_iter: total,
                                                true_resnorm: tr,
                                            }
                                        });
                                    }
                                    RecoveryEvent::BreakdownRestart {
                                        solver: kind.name(),
                                        breakdown: bd,
                                        at_iter: total,
                                    }
                                } else if tr < best_true * self.policy.min_improvement {
                                    // verified progress: advance the
                                    // checkpoint, no restart burned
                                    checkpoint.copy_from(x)?;
                                    best_true = tr;
                                    crate::observe::emit(|| crate::observe::Event::Checkpoint {
                                        solver: kind.name().to_string(),
                                        at_iter: total,
                                        true_resnorm: tr,
                                    });
                                    continue;
                                } else {
                                    // a whole segment without progress
                                    RecoveryEvent::StagnationRestart {
                                        solver: kind.name(),
                                        true_resnorm: tr,
                                    }
                                }
                            }
                        }
                    }
                };

                // roll back to the last verified checkpoint and burn
                // one restart; when exhausted, the next chain entry
                // takes over from the same checkpoint
                x.copy_from(&checkpoint)?;
                crate::observe::emit(|| crate::observe::Event::Rollback {
                    solver: kind.name().to_string(),
                    reason: match &rollback {
                        RecoveryEvent::BreakdownRestart { breakdown, .. } => {
                            format!("breakdown: {breakdown:?}")
                        }
                        RecoveryEvent::TransientRestart { error, .. } => {
                            format!("transient: {error}")
                        }
                        RecoveryEvent::StagnationRestart { true_resnorm, .. } => {
                            format!("stagnation at {true_resnorm:.3e}")
                        }
                        other => format!("{other:?}"),
                    },
                });
                events.push(rollback);
                restarts += 1;
                if restarts_left == 0 {
                    continue 'chain;
                }
                restarts_left -= 1;
            }
        }

        // recovery exhausted: hand back the best verified iterate
        x.copy_from(&checkpoint)?;
        let status = match last_breakdown {
            Some(bd) => StopStatus::Diverged(bd),
            None => StopStatus::BudgetExhausted,
        };
        Ok(SolveOutcome {
            result: SolveResult {
                iterations: total,
                resnorm: best_true,
                converged: false,
                status,
                history: Vec::new(),
            },
            solver: final_solver,
            true_resnorm: best_true,
            restarts,
            fallbacks,
            events,
        })
    }
}

impl<T: Value> Solver<T> for ResilientSolver {
    /// [`solve_outcome`](ResilientSolver::solve_outcome) folded into the
    /// common solver interface: a breakdown that survived all recovery
    /// surfaces as [`SparkleError::Breakdown`]; plain budget exhaustion
    /// stays an `Ok` non-converged result like every other driver.
    fn solve(
        &self,
        a: &dyn LinOp<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveResult> {
        let outcome = self.solve_outcome(a, b, x)?;
        if let StopStatus::Diverged(reason) = outcome.result.status {
            return Err(SparkleError::Breakdown {
                solver: "resilient",
                iters: outcome.result.iterations,
                resnorm: outcome.true_resnorm,
                reason,
            });
        }
        Ok(outcome.result)
    }

    fn name(&self) -> &'static str {
        "resilient"
    }

    fn flops_per_iter(&self, nnz: usize, n: usize) -> u64 {
        self.chain[0]
            .build::<T>(SolverConfig::default())
            .flops_per_iter(nnz, n)
    }

    fn bytes_per_iter(&self, nnz: usize, n: usize, elem: usize) -> u64 {
        self.chain[0]
            .build::<T>(SolverConfig::default())
            .bytes_per_iter(nnz, n, elem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::executor::Executor;
    use crate::matrix::Csr;
    use crate::testing::prng::Prng;
    use crate::testing::prop::{gen_sparse, gen_vec};
    use crate::Dim2;

    fn spd(seed: u64, n: usize) -> (crate::MatrixData<f64>, Vec<f64>) {
        let mut rng = Prng::new(seed);
        let mut data = gen_sparse::<f64>(&mut rng, n, n, 3);
        data.symmetrize();
        data.shift_diagonal(1.0);
        let b = gen_vec::<f64>(&mut rng, n);
        (data, b)
    }

    #[test]
    fn clean_solve_has_no_events() {
        let (data, bv) = spd(71, 120);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(120, 1));
        let solver = ResilientSolver::new(Criterion::residual(1e-9, 1000));
        let out = solver.solve_outcome(&a, &b, &mut x).unwrap();
        assert!(out.result.converged, "{out:?}");
        assert!(out.events.is_empty(), "{:?}", out.events);
        assert!(!out.recovered());
        assert!(out.true_resnorm <= 1e-9 * b.norm2_host());
        // and the iterate really solves the system
        let mut r = b.clone();
        a.apply_advanced(-1.0, &x, 1.0, &mut r).unwrap();
        assert!(r.norm2_host() <= 1e-9 * b.norm2_host() * 1.01);
    }

    #[test]
    fn fallback_chain_rescues_wrong_solver_choice() {
        // Richardson with a hopeless omega diverges/stagnates; the
        // chain falls back to BiCGSTAB which converges
        let (data, bv) = spd(73, 100);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(100, 1));
        let solver = ResilientSolver::new(Criterion::residual(1e-9, 2000))
            .with_chain(vec![
                SolverKind::Richardson { omega: 1.9 },
                SolverKind::BiCgStab,
            ]);
        let out = solver.solve_outcome(&a, &b, &mut x).unwrap();
        assert!(out.result.converged, "{out:?}");
        assert_eq!(out.solver, "bicgstab");
        assert!(out.fallbacks >= 1);
        assert!(out
            .events
            .iter()
            .any(|e| matches!(e, RecoveryEvent::Fallback { .. })));
    }

    #[test]
    fn converged_initial_guess_short_circuits() {
        let (data, bv) = spd(75, 80);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(80, 1));
        let solver = ResilientSolver::new(Criterion::residual(1e-9, 1000));
        solver.solve_outcome(&a, &b, &mut x).unwrap();
        // second solve starts at the solution
        let out = solver.solve_outcome(&a, &b, &mut x).unwrap();
        assert!(out.result.converged);
        assert_eq!(out.result.iterations, 0);
    }

    #[test]
    fn budget_exhaustion_is_ok_not_error() {
        let (data, bv) = spd(77, 100);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(100, 1));
        let solver = ResilientSolver::new(Criterion::residual(1e-30, 12));
        let r = Solver::<f64>::solve(&solver, &a, &b, &mut x).unwrap();
        assert!(!r.converged);
        assert_eq!(r.status, StopStatus::BudgetExhausted);
    }
}
