//! Transient-failure machinery for the ported backend: bounded
//! retry-with-backoff around artifact dispatch and a circuit breaker
//! that flips the runtime into degraded (host-fallback) mode after
//! repeated failures.
//!
//! The split of responsibilities mirrors what the AMD/Intel porting
//! papers report about immature device stacks: *transient* launch
//! failures are worth a couple of retries, while a stack that keeps
//! failing should be taken out of the dispatch path entirely rather
//! than fail every solve iteration.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::time::Duration;

use crate::core::error::Result;

/// Bounded retry with exponential backoff.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts (1 = no retry).
    pub attempts: u32,
    /// Sleep before the first retry.
    pub base_backoff: Duration,
    /// Backoff multiplier between consecutive retries.
    pub multiplier: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            attempts: 3,
            base_backoff: Duration::from_millis(2),
            multiplier: 2,
        }
    }
}

impl RetryPolicy {
    /// No retries at all — every failure is final.
    pub fn none() -> Self {
        Self {
            attempts: 1,
            ..Self::default()
        }
    }

    /// Run `f` up to [`attempts`](Self::attempts) times, sleeping with
    /// exponential backoff between attempts; returns the first success
    /// or the last error.
    pub fn run<T>(&self, f: impl FnMut() -> Result<T>) -> Result<T> {
        self.run_observed("dispatch", f)
    }

    /// Like [`run`](Self::run), but each failed attempt emits an
    /// [`Event::Retry`](crate::observe::Event::Retry) tagged with
    /// `what` so a logger can see transient-failure churn as it
    /// happens.
    pub fn run_observed<T>(
        &self,
        what: &'static str,
        mut f: impl FnMut() -> Result<T>,
    ) -> Result<T> {
        let attempts = self.attempts.max(1);
        let mut backoff = self.base_backoff;
        let mut last_err = None;
        for attempt in 0..attempts {
            match f() {
                Ok(v) => return Ok(v),
                Err(e) => {
                    crate::observe::emit(|| crate::observe::Event::Retry {
                        what: what.to_string(),
                        attempt: attempt + 1,
                    });
                    last_err = Some(e);
                }
            }
            if attempt + 1 < attempts && !backoff.is_zero() {
                std::thread::sleep(backoff);
                backoff *= self.multiplier.max(1);
            }
        }
        Err(last_err.expect("attempts >= 1 ran at least once"))
    }
}

/// Trip-after-N-consecutive-failures circuit breaker.
///
/// All-atomic so it can sit behind the `Arc<XlaRuntime>` that every
/// format/kernels handle shares. Once open it stays open (the PJRT
/// runtime has no health probe to close it again); callers route
/// around the backend via [`is_open`](Self::is_open).
#[derive(Debug)]
pub struct CircuitBreaker {
    threshold: u32,
    consecutive: AtomicU32,
    failures_total: AtomicU64,
    open: AtomicBool,
}

impl CircuitBreaker {
    /// Breaker that opens after `threshold` consecutive failures
    /// (`0` = never opens).
    pub fn new(threshold: u32) -> Self {
        Self {
            threshold,
            consecutive: AtomicU32::new(0),
            failures_total: AtomicU64::new(0),
            open: AtomicBool::new(false),
        }
    }

    /// Record a failed dispatch (after retries were exhausted).
    pub fn record_failure(&self) {
        self.failures_total.fetch_add(1, Ordering::Relaxed);
        let seen = self.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
        if self.threshold > 0 && seen >= self.threshold {
            self.open.store(true, Ordering::Relaxed);
        }
    }

    /// Record a successful dispatch (resets the consecutive counter;
    /// does not close an already-open breaker).
    pub fn record_success(&self) {
        self.consecutive.store(0, Ordering::Relaxed);
    }

    /// Whether the breaker has opened.
    pub fn is_open(&self) -> bool {
        self.open.load(Ordering::Relaxed)
    }

    /// Force the breaker open (tests, operator override).
    pub fn trip(&self) {
        self.open.store(true, Ordering::Relaxed);
    }

    /// Force the breaker closed and forget the failure streak.
    pub fn reset(&self) {
        self.open.store(false, Ordering::Relaxed);
        self.consecutive.store(0, Ordering::Relaxed);
    }

    /// Total failures ever recorded.
    pub fn failures_total(&self) -> u64 {
        self.failures_total.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::error::SparkleError;
    use std::cell::Cell;

    fn flaky(fail_first: u32) -> impl FnMut() -> Result<u32> {
        let calls = Cell::new(0u32);
        move || {
            let c = calls.get() + 1;
            calls.set(c);
            if c <= fail_first {
                Err(SparkleError::Runtime(format!("transient #{c}")))
            } else {
                Ok(c)
            }
        }
    }

    #[test]
    fn retry_recovers_from_transients() {
        let p = RetryPolicy {
            attempts: 3,
            base_backoff: Duration::ZERO,
            multiplier: 2,
        };
        assert_eq!(p.run(flaky(2)).unwrap(), 3);
    }

    #[test]
    fn retry_surfaces_last_error() {
        let p = RetryPolicy {
            attempts: 2,
            base_backoff: Duration::ZERO,
            multiplier: 2,
        };
        let err = p.run(flaky(10)).unwrap_err();
        assert!(err.to_string().contains("transient #2"));
    }

    #[test]
    fn retry_none_is_single_shot() {
        let mut calls = 0u32;
        let _ = RetryPolicy::none().run(|| -> Result<()> {
            calls += 1;
            Err(SparkleError::Runtime("x".into()))
        });
        assert_eq!(calls, 1);
    }

    #[test]
    fn breaker_trips_on_consecutive_failures_only() {
        let b = CircuitBreaker::new(3);
        b.record_failure();
        b.record_failure();
        b.record_success(); // streak broken
        b.record_failure();
        b.record_failure();
        assert!(!b.is_open());
        b.record_failure();
        assert!(b.is_open());
        assert_eq!(b.failures_total(), 5);
        b.reset();
        assert!(!b.is_open());
    }

    #[test]
    fn zero_threshold_never_opens() {
        let b = CircuitBreaker::new(0);
        for _ in 0..100 {
            b.record_failure();
        }
        assert!(!b.is_open());
        b.trip();
        assert!(b.is_open());
    }
}
