//! Deterministic fault injection for resilience testing.
//!
//! [`FaultyOp`] wraps any [`LinOp`] and injects the three failure modes
//! the porting papers report from immature device stacks — NaN payloads
//! (bad kernel output), silent bit-flips (memory corruption), and
//! transient apply errors (failed launches) — from a seedable PRNG, so
//! detection and recovery are exercised in CI without real hardware and
//! every run is reproducible from its seed.

use std::cell::RefCell;
use std::sync::Arc;

use crate::core::dim::Dim2;
use crate::core::error::{Result, SparkleError};
use crate::core::executor::Executor;
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::matrix::dense::Dense;
use crate::testing::prng::Prng;

/// What to inject, and how often.
///
/// Probabilities are per `apply`; their sum should stay ≤ 1. With the
/// default spec no faults fire — construct with struct-update syntax:
///
/// ```
/// # use sparkle::resilience::FaultSpec;
/// let spec = FaultSpec { seed: 7, nan_prob: 0.2, ..FaultSpec::default() };
/// ```
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    /// PRNG seed; equal seeds give identical fault schedules.
    pub seed: u64,
    /// Probability of overwriting one output element with NaN.
    pub nan_prob: f64,
    /// Probability of flipping one high bit of one output element.
    pub bitflip_prob: f64,
    /// Probability of failing the whole apply with a transient error.
    pub transient_prob: f64,
    /// Stop injecting after this many faults (`0` = unlimited).
    pub max_faults: usize,
    /// Leave the first N applies clean (lets a solve get going).
    pub armed_after: usize,
}

impl Default for FaultSpec {
    fn default() -> Self {
        Self {
            seed: 0x5EED,
            nan_prob: 0.0,
            bitflip_prob: 0.0,
            transient_prob: 0.0,
            max_faults: 0,
            armed_after: 0,
        }
    }
}

/// One injected fault, for post-mortem assertions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// An output element was overwritten with NaN.
    NanPayload,
    /// One bit of an output element was flipped.
    BitFlip { bit: u32 },
    /// The apply failed with `SparkleError::Runtime`.
    Transient,
}

/// Record of a fired fault: which apply, what kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// 1-based apply counter at which the fault fired.
    pub apply_index: usize,
    /// What was injected.
    pub kind: FaultKind,
}

struct InjectState {
    rng: Prng,
    applies: usize,
    log: Vec<FaultEvent>,
}

struct Plan {
    kind: FaultKind,
    raw: u64,
}

/// A [`LinOp`] wrapper that injects deterministic faults.
///
/// Interior mutability via `RefCell` is sound here: `LinOp` is neither
/// `Send` nor `Sync` by design (see `core/linop.rs`), so applies are
/// never concurrent.
pub struct FaultyOp<T> {
    inner: Box<dyn LinOp<T>>,
    spec: FaultSpec,
    state: RefCell<InjectState>,
}

impl<T: Value> FaultyOp<T> {
    /// Wrap `inner`, injecting faults per `spec`.
    pub fn new(inner: impl LinOp<T> + 'static, spec: FaultSpec) -> Self {
        Self::from_boxed(Box::new(inner), spec)
    }

    /// Wrap an already-boxed operator.
    pub fn from_boxed(inner: Box<dyn LinOp<T>>, spec: FaultSpec) -> Self {
        Self {
            inner,
            spec,
            state: RefCell::new(InjectState {
                rng: Prng::new(spec.seed),
                applies: 0,
                log: Vec::new(),
            }),
        }
    }

    /// Total applies seen (including failed ones).
    pub fn applies(&self) -> usize {
        self.state.borrow().applies
    }

    /// Faults fired so far, in order.
    pub fn faults(&self) -> Vec<FaultEvent> {
        self.state.borrow().log.clone()
    }

    /// Decide (and log) the fault for this apply, if any. All random
    /// draws happen here so the schedule depends only on the seed and
    /// the apply count, not on vector contents.
    fn plan(&self) -> Option<Plan> {
        let mut st = self.state.borrow_mut();
        st.applies += 1;
        let apply_index = st.applies;
        if apply_index <= self.spec.armed_after {
            return None;
        }
        if self.spec.max_faults > 0 && st.log.len() >= self.spec.max_faults {
            return None;
        }
        let u = st.rng.unit();
        let raw = st.rng.next_u64();
        let kind = if u < self.spec.transient_prob {
            FaultKind::Transient
        } else if u < self.spec.transient_prob + self.spec.nan_prob {
            FaultKind::NanPayload
        } else if u < self.spec.transient_prob + self.spec.nan_prob + self.spec.bitflip_prob {
            // bits 40..=62: high mantissa + exponent — corruption that is
            // large enough to matter, never the harmless low mantissa
            FaultKind::BitFlip {
                bit: 40 + ((raw >> 32) % 23) as u32,
            }
        } else {
            return None;
        };
        st.log.push(FaultEvent { apply_index, kind });
        Some(Plan { kind, raw })
    }

    fn corrupt(&self, x: &mut Dense<T>, plan: &Plan) {
        let xs = x.as_mut_slice();
        if xs.is_empty() {
            return;
        }
        let idx = (plan.raw % xs.len() as u64) as usize;
        match plan.kind {
            FaultKind::NanPayload => xs[idx] = T::from_f64(f64::NAN),
            FaultKind::BitFlip { bit } => {
                let v = xs[idx].as_f64();
                xs[idx] = T::from_f64(f64::from_bits(v.to_bits() ^ (1u64 << bit)));
            }
            FaultKind::Transient => unreachable!("transient faults never reach corrupt()"),
        }
    }
}

impl<T: Value> LinOp<T> for FaultyOp<T> {
    fn shape(&self) -> Dim2 {
        self.inner.shape()
    }

    fn executor(&self) -> &Arc<Executor> {
        self.inner.executor()
    }

    fn apply(&self, b: &Dense<T>, x: &mut Dense<T>) -> Result<()> {
        let plan = self.plan();
        if matches!(plan, Some(Plan { kind: FaultKind::Transient, .. })) {
            return Err(SparkleError::Runtime(
                "injected transient apply failure".into(),
            ));
        }
        self.inner.apply(b, x)?;
        if let Some(p) = plan {
            self.corrupt(x, &p);
        }
        Ok(())
    }

    fn apply_advanced(&self, alpha: T, b: &Dense<T>, beta: T, x: &mut Dense<T>) -> Result<()> {
        let plan = self.plan();
        if matches!(plan, Some(Plan { kind: FaultKind::Transient, .. })) {
            return Err(SparkleError::Runtime(
                "injected transient apply failure".into(),
            ));
        }
        self.inner.apply_advanced(alpha, b, beta, x)?;
        if let Some(p) = plan {
            self.corrupt(x, &p);
        }
        Ok(())
    }

    fn op_name(&self) -> &'static str {
        "faulty"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::stencil;
    use crate::matrix::Csr;

    fn op(spec: FaultSpec) -> (FaultyOp<f64>, Dense<f64>, Dense<f64>) {
        let exec = Executor::reference();
        let data = stencil::laplace_2d::<f64>(8, 4);
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::filled(exec.clone(), Dim2::new(32, 1), 1.0);
        let x = Dense::zeros(exec, Dim2::new(32, 1));
        (FaultyOp::new(a, spec), b, x)
    }

    #[test]
    fn no_faults_by_default_and_delegates() {
        let (f, b, mut x) = op(FaultSpec::default());
        for _ in 0..10 {
            f.apply(&b, &mut x).unwrap();
        }
        assert_eq!(f.applies(), 10);
        assert!(f.faults().is_empty());
        assert!(x.as_slice().iter().all(|v| v.is_finite()));
        assert_eq!(f.shape(), Dim2::new(32, 32));
        assert_eq!(f.op_name(), "faulty");
    }

    #[test]
    fn nan_payload_poisons_one_element() {
        let (f, b, mut x) = op(FaultSpec {
            seed: 1,
            nan_prob: 1.0,
            ..FaultSpec::default()
        });
        f.apply(&b, &mut x).unwrap();
        assert_eq!(x.as_slice().iter().filter(|v| v.is_nan()).count(), 1);
        assert_eq!(f.faults().len(), 1);
        assert_eq!(f.faults()[0].kind, FaultKind::NanPayload);
    }

    #[test]
    fn transient_fails_without_touching_x() {
        let (f, b, mut x) = op(FaultSpec {
            seed: 2,
            transient_prob: 1.0,
            ..FaultSpec::default()
        });
        x.fill(7.0);
        let err = f.apply(&b, &mut x).unwrap_err();
        assert!(err.to_string().contains("injected transient"));
        assert!(x.as_slice().iter().all(|&v| v == 7.0), "x untouched");
    }

    #[test]
    fn bitflip_changes_exactly_one_element() {
        let (f, b, mut x) = op(FaultSpec {
            seed: 3,
            bitflip_prob: 1.0,
            max_faults: 1,
            ..FaultSpec::default()
        });
        let (fc, bc, mut xc) = op(FaultSpec::default());
        f.apply(&b, &mut x).unwrap();
        fc.apply(&bc, &mut xc).unwrap();
        let diffs = x
            .as_slice()
            .iter()
            .zip(xc.as_slice())
            .filter(|(a, b)| a.to_bits() != b.to_bits())
            .count();
        assert_eq!(diffs, 1);
        assert!(matches!(f.faults()[0].kind, FaultKind::BitFlip { bit } if (40..=62).contains(&bit)));
    }

    #[test]
    fn schedule_is_deterministic_and_respects_arming() {
        let spec = FaultSpec {
            seed: 42,
            nan_prob: 0.3,
            transient_prob: 0.2,
            bitflip_prob: 0.1,
            armed_after: 3,
            max_faults: 4,
            ..FaultSpec::default()
        };
        let run = |spec| {
            let (f, b, mut x) = op(spec);
            for _ in 0..20 {
                let _ = f.apply(&b, &mut x);
                x.fill(0.0);
            }
            f.faults()
        };
        let a = run(spec);
        let b = run(spec);
        assert_eq!(a, b, "same seed, same schedule");
        assert!(a.len() <= 4, "max_faults respected");
        assert!(a.iter().all(|e| e.apply_index > 3), "armed_after respected");
        assert!(!a.is_empty(), "faults do fire at these rates over 17 applies");
    }
}
