//! Resilience: breakdown detection, checkpoint/restart recovery,
//! backend degradation and fault injection.
//!
//! The porting papers this repo reproduces are blunt about immature
//! device stacks: kernels fail transiently, numerics go bad silently,
//! and a math library that only benchmarks — never recovers — cannot
//! serve real traffic. This subsystem layers four defenses over the
//! solver stack:
//!
//! * **Detection** ([`detect`]): every Krylov driver feeds its
//!   recurrence scalars and residual norms through a
//!   [`BreakdownDetector`]; NaN/Inf residuals, collapsed denominators
//!   and stagnation surface as structured
//!   [`StopStatus::Diverged`](crate::stop::StopStatus) results instead
//!   of spinning to `max_iters`.
//! * **Recovery** ([`recover`]): [`ResilientSolver`] checkpoints the
//!   iterate every `checkpoint_every` iterations, verifies the *true*
//!   residual `||b - A x||` at each checkpoint (catching recurrence
//!   drift from silent corruption), rolls back on breakdown and falls
//!   back along a solver chain (CG → BiCGSTAB → GMRES by default).
//! * **Backend degradation** ([`retry`]): xla artifact dispatch is
//!   retried with backoff; a [`CircuitBreaker`] flips the runtime into
//!   degraded mode after repeated failures, after which kernels route
//!   to the host `par` implementations (the data is always resident on
//!   host — see `DESIGN.md`).
//! * **Fault injection** ([`inject`]): [`FaultyOp`] wraps any operator
//!   and injects NaN payloads, bit-flips and transient errors from a
//!   seedable PRNG, so all of the above is testable in CI without
//!   flaky hardware.

pub mod detect;
pub mod inject;
pub mod recover;
pub mod retry;

pub use detect::{BreakdownDetector, BreakdownPolicy};
pub use inject::{FaultEvent, FaultKind, FaultSpec, FaultyOp};
pub use recover::{
    RecoveryEvent, RecoveryPolicy, ResilientSolver, SolveOutcome, SolverKind,
};
pub use retry::{CircuitBreaker, RetryPolicy};
