//! Breakdown detection policy shared by all Krylov drivers.
//!
//! Every solver builds one [`BreakdownDetector`] per solve from the
//! [`BreakdownPolicy`] in its [`SolverConfig`](crate::solver::SolverConfig)
//! and feeds it (a) each recurrence denominator before dividing by it
//! and (b) each new residual norm. The detector answers with a
//! structured [`Breakdown`] the moment the iteration becomes
//! unsalvageable, so drivers stop instead of spinning NaNs to
//! `max_iters`.

use crate::stop::Breakdown;

/// Thresholds for breakdown detection.
///
/// The defaults are deliberately conservative: the denominator floor
/// sits far below anything a healthy double-precision recurrence
/// produces (benches that iterate 1000x past convergence bottom out
/// around 1e-32), and stagnation detection is off unless a window is
/// configured — [`ResilientSolver`](crate::resilience::ResilientSolver)
/// turns it on for its inner segments.
#[derive(Debug, Clone, Copy)]
pub struct BreakdownPolicy {
    /// A recurrence denominator with |v| below this is reported as a
    /// [`Breakdown::ZeroDenominator`]. `0.0` disables the floor
    /// (NaN/Inf operands are still reported).
    pub denominator_floor: f64,
    /// Report [`Breakdown::Stagnation`] when the residual norm fails to
    /// improve by [`stagnation_improvement`](Self::stagnation_improvement)
    /// for this many consecutive iterations. `0` disables.
    pub stagnation_window: usize,
    /// Relative improvement that resets the stagnation window: a new
    /// residual counts as progress when
    /// `resnorm < best * (1 - stagnation_improvement)`.
    pub stagnation_improvement: f64,
}

impl Default for BreakdownPolicy {
    fn default() -> Self {
        Self {
            denominator_floor: 1e-280,
            stagnation_window: 0,
            stagnation_improvement: 1e-3,
        }
    }
}

impl BreakdownPolicy {
    /// Policy that never reports a breakdown for finite values
    /// (NaN/Inf operands and residuals are still caught).
    pub fn lenient() -> Self {
        Self {
            denominator_floor: 0.0,
            stagnation_window: 0,
            ..Self::default()
        }
    }

    /// Fresh per-solve detector state.
    pub fn detector(&self) -> BreakdownDetector {
        BreakdownDetector {
            policy: *self,
            best: f64::INFINITY,
            since_best: 0,
        }
    }
}

/// Per-solve detection state (stagnation tracking).
#[derive(Debug, Clone)]
pub struct BreakdownDetector {
    policy: BreakdownPolicy,
    best: f64,
    since_best: usize,
}

impl BreakdownDetector {
    /// Check a recurrence scalar that the solver is about to divide by
    /// (or that a division just produced). `what` names the scalar for
    /// the structured report.
    pub fn scalar(&self, what: &'static str, v: f64) -> Option<Breakdown> {
        if !v.is_finite() {
            return Some(Breakdown::NanOperand { what });
        }
        if self.policy.denominator_floor > 0.0 && v.abs() < self.policy.denominator_floor {
            return Some(Breakdown::ZeroDenominator { what });
        }
        None
    }

    /// Feed one new residual norm; reports NaN/Inf immediately and
    /// stagnation once the configured window elapses with no progress.
    pub fn residual(&mut self, resnorm: f64) -> Option<Breakdown> {
        if !resnorm.is_finite() {
            return Some(Breakdown::NanResidual);
        }
        if self.policy.stagnation_window == 0 {
            return None;
        }
        if resnorm < self.best * (1.0 - self.policy.stagnation_improvement) {
            self.best = resnorm;
            self.since_best = 0;
        } else {
            self.since_best += 1;
            if self.since_best >= self.policy.stagnation_window {
                return Some(Breakdown::Stagnation {
                    window: self.policy.stagnation_window,
                });
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_flags_nan_and_zero() {
        let det = BreakdownPolicy::default().detector();
        assert_eq!(
            det.scalar("rho", f64::NAN),
            Some(Breakdown::NanOperand { what: "rho" })
        );
        assert_eq!(
            det.scalar("rho", f64::INFINITY),
            Some(Breakdown::NanOperand { what: "rho" })
        );
        assert_eq!(
            det.scalar("omega", 0.0),
            Some(Breakdown::ZeroDenominator { what: "omega" })
        );
        assert_eq!(det.scalar("rho", 1e-32), None, "healthy tiny scalar passes");
        assert_eq!(det.scalar("rho", -3.5), None);
    }

    #[test]
    fn lenient_still_flags_nan() {
        let det = BreakdownPolicy::lenient().detector();
        assert_eq!(det.scalar("rho", 0.0), None);
        assert!(det.scalar("rho", f64::NAN).is_some());
    }

    #[test]
    fn stagnation_window_counts_no_progress() {
        let policy = BreakdownPolicy {
            stagnation_window: 3,
            ..BreakdownPolicy::default()
        };
        let mut det = policy.detector();
        assert_eq!(det.residual(1.0), None);
        assert_eq!(det.residual(0.5), None); // progress resets
        assert_eq!(det.residual(0.499), None); // < 0.1% improvement: no progress
        assert_eq!(det.residual(0.499), None);
        assert_eq!(
            det.residual(0.499),
            Some(Breakdown::Stagnation { window: 3 })
        );
    }

    #[test]
    fn residual_nan_always_reported() {
        let mut det = BreakdownPolicy::default().detector();
        assert_eq!(det.residual(f64::NAN), Some(Breakdown::NanResidual));
        let mut det = BreakdownPolicy::lenient().detector();
        assert_eq!(det.residual(f64::INFINITY), Some(Breakdown::NanResidual));
    }

    #[test]
    fn disabled_window_never_stagnates() {
        let mut det = BreakdownPolicy::default().detector();
        for _ in 0..10_000 {
            assert_eq!(det.residual(1.0), None);
        }
    }
}
