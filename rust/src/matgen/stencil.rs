//! Regular grid stencils: the CFD / thermal matrices of Table 1
//! (atmosmodj: 7-pt 3-D advection stencil; thermal2: unstructured but
//! stencil-like FEM thermal problem).
//!
//! All stencils are diagonally dominant, so every solver in the paper's
//! set converges on them — matching the role these matrices play in §6.4.

use crate::core::dim::Dim2;
use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;

/// 5-point 2-D Laplacian on an `nx × ny` grid (SPD).
pub fn laplace_2d<T: Value>(nx: usize, ny: usize) -> MatrixData<T> {
    let n = nx * ny;
    let mut d = MatrixData::new(Dim2::square(n));
    let idx = |i: usize, j: usize| (i * ny + j) as i32;
    for i in 0..nx {
        for j in 0..ny {
            let c = idx(i, j);
            d.push(c, c, T::from_f64(4.0));
            if i > 0 {
                d.push(c, idx(i - 1, j), T::from_f64(-1.0));
            }
            if i + 1 < nx {
                d.push(c, idx(i + 1, j), T::from_f64(-1.0));
            }
            if j > 0 {
                d.push(c, idx(i, j - 1), T::from_f64(-1.0));
            }
            if j + 1 < ny {
                d.push(c, idx(i, j + 1), T::from_f64(-1.0));
            }
        }
    }
    d.normalize();
    d
}

/// 7-point 3-D stencil with an optional nonsymmetric advection term
/// (`advect != 0` skews the ±x couplings) — the atmosmodj analog.
pub fn stencil_3d<T: Value>(nx: usize, ny: usize, nz: usize, advect: f64) -> MatrixData<T> {
    let n = nx * ny * nz;
    let mut d = MatrixData::new(Dim2::square(n));
    let idx = |i: usize, j: usize, k: usize| ((i * ny + j) * nz + k) as i32;
    for i in 0..nx {
        for j in 0..ny {
            for k in 0..nz {
                let c = idx(i, j, k);
                d.push(c, c, T::from_f64(6.0 + advect.abs()));
                if i > 0 {
                    d.push(c, idx(i - 1, j, k), T::from_f64(-1.0 - advect));
                }
                if i + 1 < nx {
                    d.push(c, idx(i + 1, j, k), T::from_f64(-1.0 + advect));
                }
                if j > 0 {
                    d.push(c, idx(i, j - 1, k), T::from_f64(-1.0));
                }
                if j + 1 < ny {
                    d.push(c, idx(i, j + 1, k), T::from_f64(-1.0));
                }
                if k > 0 {
                    d.push(c, idx(i, j, k - 1), T::from_f64(-1.0));
                }
                if k + 1 < nz {
                    d.push(c, idx(i, j, k + 1), T::from_f64(-1.0));
                }
            }
        }
    }
    d.normalize();
    d
}

/// 27-point 3-D stencil (dense couplings; the Bump/Cube_Coup analogs use
/// it as the base block pattern).
pub fn stencil_27pt<T: Value>(nx: usize, ny: usize, nz: usize) -> MatrixData<T> {
    let n = nx * ny * nz;
    let mut d = MatrixData::new(Dim2::square(n));
    let idx = |i: usize, j: usize, k: usize| ((i * ny + j) * nz + k) as i32;
    for i in 0..nx {
        for j in 0..ny {
            for k in 0..nz {
                let c = idx(i, j, k);
                for di in -1i64..=1 {
                    for dj in -1i64..=1 {
                        for dk in -1i64..=1 {
                            let (ni, nj, nk) =
                                (i as i64 + di, j as i64 + dj, k as i64 + dk);
                            if ni < 0
                                || nj < 0
                                || nk < 0
                                || ni >= nx as i64
                                || nj >= ny as i64
                                || nk >= nz as i64
                            {
                                continue;
                            }
                            let val = if di == 0 && dj == 0 && dk == 0 {
                                26.5
                            } else {
                                -1.0
                            };
                            d.push(
                                c,
                                idx(ni as usize, nj as usize, nk as usize),
                                T::from_f64(val),
                            );
                        }
                    }
                }
            }
        }
    }
    d.normalize();
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laplace_2d_structure() {
        let d = laplace_2d::<f64>(4, 4);
        assert_eq!(d.dim.rows, 16);
        // interior rows have 5 entries, corners 3
        let lens = d.row_lengths();
        assert_eq!(lens.iter().copied().max().unwrap(), 5);
        assert_eq!(lens.iter().copied().min().unwrap(), 3);
        // symmetric
        let dense = d.to_dense_vec();
        for i in 0..16 {
            for j in 0..16 {
                assert_eq!(dense[i * 16 + j], dense[j * 16 + i]);
            }
        }
    }

    #[test]
    fn stencil_3d_nnz_close_to_7_per_row() {
        let d = stencil_3d::<f64>(8, 8, 8, 0.0);
        let stats = crate::matgen::MatrixStats::from_data(&d);
        assert_eq!(stats.n, 512);
        assert!(stats.avg_row > 6.0 && stats.avg_row <= 7.0, "{stats:?}");
        assert!(stats.row_cv < 0.2);
    }

    #[test]
    fn advection_breaks_symmetry_but_not_dominance() {
        let d = stencil_3d::<f64>(4, 4, 4, 0.3);
        let dense = d.to_dense_vec();
        let n = 64;
        let mut sym = true;
        for i in 0..n {
            for j in 0..n {
                if (dense[i * n + j] - dense[j * n + i]).abs() > 1e-12 {
                    sym = false;
                }
            }
        }
        assert!(!sym);
        for i in 0..n {
            let diag = dense[i * n + i].abs();
            let off: f64 = (0..n)
                .filter(|&j| j != i)
                .map(|j| dense[i * n + j].abs())
                .sum();
            assert!(diag >= off, "row {i} lost dominance");
        }
    }

    #[test]
    fn stencil_27pt_max_row() {
        let d = stencil_27pt::<f64>(4, 4, 4);
        assert_eq!(d.row_lengths().iter().copied().max().unwrap(), 27);
    }
}
