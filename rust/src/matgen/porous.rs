//! Porous-media flow matrices (StocF-1456 analog).
//!
//! Flow in porous media is a 7-point stencil with *strongly
//! heterogeneous* coefficients: permeability jumps of several orders of
//! magnitude between cells (stochastic fields — hence "StocF"). The
//! jumps destroy the smooth-coefficient structure stencils have and are
//! what makes these systems ill-conditioned in practice.

use crate::core::dim::Dim2;
use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;
use crate::testing::prng::Prng;

/// 3-D heterogeneous-permeability flow matrix on an `nx×ny×nz` grid.
/// `contrast` is the log10 range of the permeability field.
pub fn porous_flow<T: Value>(
    nx: usize,
    ny: usize,
    nz: usize,
    contrast: f64,
    seed: u64,
) -> MatrixData<T> {
    let mut rng = Prng::new(seed);
    let n = nx * ny * nz;
    // log-uniform permeability per cell
    let perm: Vec<f64> = (0..n)
        .map(|_| 10f64.powf(rng.uniform(-contrast / 2.0, contrast / 2.0)))
        .collect();
    let idx = |i: usize, j: usize, k: usize| (i * ny + j) * nz + k;
    let mut d = MatrixData::new(Dim2::square(n));
    let mut diag = vec![0.0f64; n];
    let couple = |a: usize, b: usize, d: &mut MatrixData<T>, diag: &mut [f64]| {
        // harmonic average transmissibility (the standard finite-volume
        // two-point flux approximation)
        let t = 2.0 * perm[a] * perm[b] / (perm[a] + perm[b]);
        d.push(a as i32, b as i32, T::from_f64(-t));
        d.push(b as i32, a as i32, T::from_f64(-t));
        diag[a] += t;
        diag[b] += t;
    };
    for i in 0..nx {
        for j in 0..ny {
            for k in 0..nz {
                let c = idx(i, j, k);
                if i + 1 < nx {
                    couple(c, idx(i + 1, j, k), &mut d, &mut diag);
                }
                if j + 1 < ny {
                    couple(c, idx(i, j + 1, k), &mut d, &mut diag);
                }
                if k + 1 < nz {
                    couple(c, idx(i, j, k + 1), &mut d, &mut diag);
                }
            }
        }
    }
    for (i, &v) in diag.iter().enumerate() {
        // small well/compressibility term keeps the matrix nonsingular
        d.push(i as i32, i as i32, T::from_f64(v + 1e-3));
    }
    d.normalize();
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure_is_7pt() {
        let d = porous_flow::<f64>(6, 6, 6, 3.0, 1);
        let s = crate::matgen::MatrixStats::from_data(&d);
        assert_eq!(s.n, 216);
        assert!(s.max_row <= 7);
        assert!(s.avg_row > 5.0);
    }

    #[test]
    fn value_contrast_spans_orders_of_magnitude() {
        let d = porous_flow::<f64>(8, 8, 8, 6.0, 2);
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for e in &d.entries {
            if e.row != e.col {
                let v = e.val.abs();
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
        assert!(hi / lo > 1e3, "contrast {:.1e}", hi / lo);
    }

    #[test]
    fn spd_and_cg_solvable() {
        use crate::core::executor::Executor;
        use crate::matrix::{Csr, Dense};
        use crate::solver::{Cg, Solver, SolverConfig};
        use crate::stop::Criterion;
        let d = porous_flow::<f64>(6, 6, 6, 2.0, 3);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &d).unwrap();
        let b = Dense::filled(exec.clone(), crate::Dim2::new(216, 1), 1.0);
        let mut x = Dense::zeros(exec.clone(), crate::Dim2::new(216, 1));
        let r = Cg::new(SolverConfig::with_criterion(Criterion::residual(1e-8, 2000)))
            .solve(&a, &b, &mut x)
            .unwrap();
        assert!(r.converged, "{r:?}");
    }
}
