//! Circuit-simulation matrices (rajat31, circuit5M, FullChip analogs).
//!
//! Circuit matrices have power-law degree distributions: most nets touch
//! a handful of nodes, while supply rails / clock trees touch thousands —
//! the "few very dense rows" that break pure-ELL storage and stress
//! load-balancing in SpMV (the paper's hardest Fig. 8 outliers).
//!
//! Generator: every node gets a short local stamp (resistor-like coupling
//! to nearby indices), a Pareto-distributed subset of nodes becomes hubs
//! with long random fan-out, and the diagonal is made dominant (circuit
//! conductance matrices are).

use crate::core::dim::Dim2;
use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;
use crate::testing::prng::Prng;

/// Tuning knobs for the circuit generator.
#[derive(Debug, Clone)]
pub struct CircuitConfig {
    /// Average local (non-hub) connections per node.
    pub local_degree: usize,
    /// Fraction of nodes that are hubs (power rails, clock nets).
    pub hub_fraction: f64,
    /// Pareto shape for hub fan-out (smaller = heavier tail).
    pub hub_alpha: f64,
    /// Cap on a single hub's fan-out (keeps generation linear).
    pub max_hub_degree: usize,
}

impl Default for CircuitConfig {
    fn default() -> Self {
        Self {
            local_degree: 3,
            hub_fraction: 0.002,
            hub_alpha: 1.1,
            max_hub_degree: 20_000,
        }
    }
}

/// Generate a circuit-like conductance matrix of dimension `n` with
/// roughly `target_nnz` nonzeros.
pub fn circuit<T: Value>(n: usize, target_nnz: usize, seed: u64) -> MatrixData<T> {
    circuit_with_config(n, target_nnz, seed, &CircuitConfig::default())
}

/// Generator with explicit knobs.
pub fn circuit_with_config<T: Value>(
    n: usize,
    target_nnz: usize,
    seed: u64,
    cfg: &CircuitConfig,
) -> MatrixData<T> {
    let mut rng = Prng::new(seed);
    let mut d = MatrixData::new(Dim2::square(n));
    // local stamps: short-range couplings (structurally symmetric)
    let local_budget = target_nnz.saturating_sub(n) / 2; // half for sym pair
    let per_node = (local_budget / n.max(1)).max(1).min(cfg.local_degree.max(1));
    for i in 0..n {
        for _ in 0..per_node {
            // mostly-local neighbor: index within a window, occasionally far
            let span = if rng.unit() < 0.9 { 64 } else { n };
            let lo = i.saturating_sub(span / 2);
            let hi = (i + span / 2).min(n - 1);
            let j = lo + rng.below(hi - lo + 1);
            if j != i {
                let g = T::from_f64(-rng.uniform(0.1, 1.0));
                d.push(i as i32, j as i32, g);
                d.push(j as i32, i as i32, g);
            }
        }
    }
    // hubs: power-law fan-out
    let hubs = ((n as f64 * cfg.hub_fraction).ceil() as usize).max(1);
    for _ in 0..hubs {
        let h = rng.below(n);
        let deg = (rng.pareto(32.0, cfg.hub_alpha) as usize)
            .min(cfg.max_hub_degree)
            .min(n / 2);
        for _ in 0..deg {
            let j = rng.below(n);
            if j != h {
                let g = T::from_f64(-rng.uniform(0.01, 0.2));
                d.push(h as i32, j as i32, g);
                d.push(j as i32, h as i32, g);
            }
        }
    }
    // conductance diagonal: dominant (sum of |off-diag| + leak)
    d.normalize();
    let mut row_abs = vec![0.0f64; n];
    for e in &d.entries {
        if e.row != e.col {
            row_abs[e.row as usize] += e.val.as_f64().abs();
        }
    }
    for i in 0..n {
        d.push(i as i32, i as i32, T::from_f64(row_abs[i] + 0.1));
    }
    d.normalize();
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::MatrixStats;

    #[test]
    fn power_law_tail_present() {
        let d = circuit::<f64>(20_000, 90_000, 42);
        let stats = MatrixStats::from_data(&d);
        assert_eq!(stats.n, 20_000);
        // heavy tail: max row far above average
        assert!(
            stats.max_row as f64 > 8.0 * stats.avg_row,
            "max {} avg {}",
            stats.max_row,
            stats.avg_row
        );
        // the tail (max_row) is the circuit signature; cv stays moderate
        // because most rows are short and regular, as in real rajat/chip
        // matrices
        assert!(stats.row_cv > 0.3, "cv {}", stats.row_cv);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = circuit::<f64>(1000, 5000, 7);
        let b = circuit::<f64>(1000, 5000, 7);
        assert_eq!(a.nnz(), b.nnz());
        assert_eq!(a.entries[10], b.entries[10]);
        let c = circuit::<f64>(1000, 5000, 8);
        assert_ne!(a.nnz(), c.nnz());
    }

    #[test]
    fn diagonally_dominant() {
        let d = circuit::<f64>(500, 2500, 3);
        let dense = d.to_dense_vec();
        for i in 0..500 {
            let diag = dense[i * 500 + i].abs();
            let off: f64 = (0..500)
                .filter(|&j| j != i)
                .map(|j| dense[i * 500 + j].abs())
                .sum();
            assert!(diag > off - 1e-9, "row {i}: {diag} vs {off}");
        }
    }

    #[test]
    fn nnz_in_target_ballpark() {
        let target = 50_000;
        let d = circuit::<f64>(10_000, target, 11);
        let nnz = d.nnz();
        assert!(
            nnz as f64 > target as f64 * 0.4 && (nnz as f64) < target as f64 * 3.0,
            "nnz {nnz} vs target {target}"
        );
    }
}
