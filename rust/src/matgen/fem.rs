//! Unstructured FEM matrices (thermal2, CurlCurl_4, Bump_2911,
//! Cube_Coup_dt0 analogs).
//!
//! Real FEM matrices come from meshes: nodes couple to a bounded number
//! of geometric neighbors, giving narrow-banded, structurally symmetric
//! patterns with moderate row-length variation. We emulate a mesh by
//! jittering points on a grid and coupling each node to its `degree`
//! nearest grid neighbors plus a few random jitter edges; vector-valued
//! elements (CurlCurl: edge elements, Bump/Cube: 3-dof geomechanics) are
//! modeled with `block` coupled unknowns per node — which is what raises
//! nnz/row to the 11-57 range of Table 1.

use crate::core::dim::Dim2;
use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;
use crate::testing::prng::Prng;

/// Unstructured FEM-like SPD matrix.
///
/// * `nodes` — mesh nodes; matrix dimension is `nodes * block`.
/// * `degree` — geometric neighbors per node.
/// * `block` — unknowns per node (1 = scalar field, 3 = displacement).
pub fn fem<T: Value>(nodes: usize, degree: usize, block: usize, seed: u64) -> MatrixData<T> {
    let mut rng = Prng::new(seed);
    let n = nodes * block;
    let mut d = MatrixData::new(Dim2::square(n));
    // mesh nodes on a jittered 2-D grid; neighbor = close index in a
    // row-major grid embedding (captures FEM bandwidth after ordering)
    let side = (nodes as f64).sqrt().ceil() as usize;
    for node in 0..nodes {
        let mut neighbors = Vec::with_capacity(degree);
        let (gi, gj) = (node / side, node % side);
        // grid neighbors in a widening ring until degree is met
        'ring: for radius in 1..=3usize {
            for di in -(radius as i64)..=(radius as i64) {
                for dj in -(radius as i64)..=(radius as i64) {
                    if di.abs().max(dj.abs()) != radius as i64 {
                        continue;
                    }
                    let (ni, nj) = (gi as i64 + di, gj as i64 + dj);
                    if ni < 0 || nj < 0 {
                        continue;
                    }
                    let nb = ni as usize * side + nj as usize;
                    if nb < nodes && nb != node {
                        neighbors.push(nb);
                        if neighbors.len() >= degree {
                            break 'ring;
                        }
                    }
                }
            }
        }
        // a couple of long-range edges (mesh irregularity)
        if rng.unit() < 0.05 {
            neighbors.push(rng.below(nodes));
        }
        for &nb in &neighbors {
            // couple all block dofs of node and neighbor
            for bi in 0..block {
                for bj in 0..block {
                    let v = T::from_f64(-rng.uniform(0.2, 1.0) / block as f64);
                    d.push(
                        (node * block + bi) as i32,
                        (nb * block + bj) as i32,
                        v,
                    );
                }
            }
        }
        // intra-node block coupling
        for bi in 0..block {
            for bj in 0..block {
                if bi != bj {
                    d.push(
                        (node * block + bi) as i32,
                        (node * block + bj) as i32,
                        T::from_f64(-rng.uniform(0.05, 0.3)),
                    );
                }
            }
        }
    }
    d.symmetrize();
    // SPD via diagonal dominance
    let mut row_abs = vec![0.0f64; n];
    for e in &d.entries {
        if e.row != e.col {
            row_abs[e.row as usize] += e.val.as_f64().abs();
        }
    }
    for (i, &ra) in row_abs.iter().enumerate() {
        d.push(i as i32, i as i32, T::from_f64(ra + 1.0));
    }
    d.normalize();
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::MatrixStats;

    #[test]
    fn scalar_field_degree() {
        let d = fem::<f64>(1000, 6, 1, 1);
        let s = MatrixStats::from_data(&d);
        assert_eq!(s.n, 1000);
        // ~degree*2 (symmetrized) + diag
        assert!(s.avg_row > 5.0 && s.avg_row < 16.0, "{s:?}");
        assert!(s.row_cv < 0.6, "{s:?}");
    }

    #[test]
    fn block3_raises_row_density() {
        let scalar = MatrixStats::from_data(&fem::<f64>(500, 6, 1, 2));
        let block3 = MatrixStats::from_data(&fem::<f64>(500, 6, 3, 2));
        assert!(block3.avg_row > 2.0 * scalar.avg_row, "{block3:?} vs {scalar:?}");
    }

    #[test]
    fn structurally_symmetric_and_spd_ish() {
        let d = fem::<f64>(200, 5, 1, 3);
        let n = 200;
        let dense = d.to_dense_vec();
        for i in 0..n {
            for j in 0..n {
                assert!(
                    (dense[i * n + j] - dense[j * n + i]).abs() < 1e-12,
                    "({i},{j}) asymmetric"
                );
            }
            let diag = dense[i * n + i];
            let off: f64 = (0..n)
                .filter(|&j| j != i)
                .map(|j| dense[i * n + j].abs())
                .sum();
            assert!(diag > off, "row {i} not dominant");
        }
    }

    #[test]
    fn cg_converges_on_fem_system() {
        use crate::core::executor::Executor;
        use crate::matrix::{Csr, Dense};
        use crate::solver::{Cg, Solver, SolverConfig};
        use crate::stop::Criterion;
        let d = fem::<f64>(300, 6, 1, 4);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &d).unwrap();
        let b = Dense::filled(exec.clone(), crate::Dim2::new(300, 1), 1.0);
        let mut x = Dense::zeros(exec.clone(), crate::Dim2::new(300, 1));
        let r = Cg::new(SolverConfig::with_criterion(Criterion::residual(1e-8, 500)))
            .solve(&a, &b, &mut x)
            .unwrap();
        assert!(r.converged, "{r:?}");
    }
}
