//! The Table-1 test-matrix registry.
//!
//! One entry per matrix of the paper's Table 1, mapped to the generator
//! class that reproduces its origin and structure. `generate` takes a
//! `scale` divisor so laptop runs can use faithful-but-smaller analogs
//! (scale=1 reproduces the full published dimensions; the perf model
//! projects full-size numbers from the scaled structure statistics).

use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;
use crate::matgen::{circuit, fem, kkt, porous, stencil};

/// Generator class of a suite matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MatrixClass {
    /// Power-law circuit conductance matrix.
    Circuit { local_degree: usize },
    /// 7-pt 3-D stencil with advection skew.
    Stencil3d { advect: f64 },
    /// Saddle-point KKT block system.
    Kkt { hess_degree: usize },
    /// Unstructured FEM with `block` dofs per node.
    Fem { degree: usize, block: usize },
    /// Heterogeneous porous-media flow (7-pt + diagonal transmissibility).
    Porous { contrast: f64 },
}

/// One row of Table 1.
#[derive(Debug, Clone)]
pub struct SuiteEntry {
    /// SuiteSparse name of the matrix this entry substitutes.
    pub name: &'static str,
    /// Origin column of Table 1.
    pub origin: &'static str,
    /// Published dimension.
    pub n_full: usize,
    /// Published nonzeros.
    pub nnz_full: usize,
    pub class: MatrixClass,
    /// Generator seed (fixed: the suite is deterministic).
    pub seed: u64,
}

/// The ten matrices of the paper's Table 1.
pub fn table1() -> Vec<SuiteEntry> {
    use MatrixClass::*;
    vec![
        SuiteEntry {
            name: "rajat31",
            origin: "Circuit Simulation Problem",
            n_full: 4_690_002,
            nnz_full: 20_316_253,
            class: Circuit { local_degree: 2 },
            seed: 31,
        },
        SuiteEntry {
            name: "atmosmodj",
            origin: "CFD Problem",
            n_full: 1_270_432,
            nnz_full: 8_814_880,
            class: Stencil3d { advect: 0.3 },
            seed: 32,
        },
        SuiteEntry {
            name: "nlpkkt160",
            origin: "Nonlinear Programming Problem",
            n_full: 8_345_600,
            nnz_full: 225_422_112,
            class: Kkt { hess_degree: 26 },
            seed: 33,
        },
        SuiteEntry {
            name: "thermal2",
            origin: "Unstructured FEM",
            n_full: 1_228_045,
            nnz_full: 8_580_313,
            class: Fem { degree: 3, block: 1 },
            seed: 34,
        },
        SuiteEntry {
            name: "CurlCurl_4",
            origin: "2nd order Maxwell",
            n_full: 2_380_515,
            nnz_full: 26_515_867,
            class: Fem { degree: 5, block: 1 },
            seed: 35,
        },
        SuiteEntry {
            name: "Bump_2911",
            origin: "3D Geomechanical Simulation",
            n_full: 2_911_419,
            nnz_full: 127_729_899,
            class: Fem { degree: 7, block: 3 },
            seed: 36,
        },
        SuiteEntry {
            name: "Cube_Coup_dt0",
            origin: "3D Consolidation Problem",
            n_full: 2_164_760,
            nnz_full: 124_406_070,
            class: Fem { degree: 9, block: 3 },
            seed: 37,
        },
        SuiteEntry {
            name: "StocF-1456",
            origin: "Flow in Porous Medium",
            n_full: 1_465_137,
            nnz_full: 21_005_389,
            class: Porous { contrast: 6.0 },
            seed: 38,
        },
        SuiteEntry {
            name: "circuit5M",
            origin: "Circuit Simulation Problem",
            n_full: 5_558_326,
            nnz_full: 59_524_291,
            class: Circuit { local_degree: 5 },
            seed: 39,
        },
        SuiteEntry {
            name: "FullChip",
            origin: "Circuit Simulation Problem",
            n_full: 2_987_012,
            nnz_full: 26_621_990,
            class: Circuit { local_degree: 4 },
            seed: 40,
        },
    ]
}

/// Look up a Table-1 entry by SuiteSparse name.
pub fn table1_entry(name: &str) -> Option<SuiteEntry> {
    table1().into_iter().find(|e| e.name == name)
}

impl SuiteEntry {
    /// Generate the analog at `1/scale` of the published dimension
    /// (`scale = 1` is full size). Dimension and nnz track the published
    /// values proportionally; structure class is preserved at any scale.
    pub fn generate<T: Value>(&self, scale: usize) -> MatrixData<T> {
        let scale = scale.max(1);
        let n_target = (self.n_full / scale).max(512);
        let nnz_target = (self.nnz_full / scale).max(n_target);
        match self.class {
            MatrixClass::Circuit { local_degree } => circuit::circuit_with_config(
                n_target,
                nnz_target,
                self.seed,
                &circuit::CircuitConfig {
                    local_degree,
                    ..Default::default()
                },
            ),
            MatrixClass::Stencil3d { advect } => {
                let side = (n_target as f64).cbrt().round() as usize;
                stencil::stencil_3d(side.max(4), side.max(4), side.max(4), advect)
            }
            MatrixClass::Kkt { hess_degree } => {
                // n = nh + nh/2 -> nh = 2n/3
                kkt::kkt(n_target * 2 / 3, hess_degree, 0.5, self.seed)
            }
            MatrixClass::Fem { degree, block } => {
                fem::fem(n_target / block, degree, block, self.seed)
            }
            MatrixClass::Porous { contrast } => {
                let side = (n_target as f64).cbrt().round() as usize;
                porous::porous_flow(side.max(4), side.max(4), side.max(4), contrast, self.seed)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::MatrixStats;

    #[test]
    fn registry_matches_paper_table() {
        let t = table1();
        assert_eq!(t.len(), 10);
        assert_eq!(t[0].name, "rajat31");
        assert_eq!(t[2].nnz_full, 225_422_112);
        assert!(table1_entry("FullChip").is_some());
        assert!(table1_entry("nope").is_none());
    }

    #[test]
    fn scaled_generation_tracks_density() {
        // every entry at scale 256: nnz/row within 2.5x of the published
        // density (structure class preserved)
        for entry in table1() {
            let data = entry.generate::<f64>(256);
            let stats = MatrixStats::from_data(&data);
            let published_density = entry.nnz_full as f64 / entry.n_full as f64;
            let ratio = stats.avg_row / published_density;
            assert!(
                (0.4..2.5).contains(&ratio),
                "{}: generated {:.1}/row vs published {:.1}/row",
                entry.name,
                stats.avg_row,
                published_density
            );
        }
    }

    #[test]
    fn deterministic_per_entry() {
        let e = table1_entry("thermal2").unwrap();
        let a = e.generate::<f64>(512);
        let b = e.generate::<f64>(512);
        assert_eq!(a.nnz(), b.nnz());
    }

    #[test]
    fn circuit_entries_have_heavy_tails_fem_do_not() {
        let fullchip = table1_entry("FullChip").unwrap().generate::<f64>(128);
        let thermal = table1_entry("thermal2").unwrap().generate::<f64>(128);
        let s_c = MatrixStats::from_data(&fullchip);
        let s_t = MatrixStats::from_data(&thermal);
        assert!(s_c.row_cv > 2.0 * s_t.row_cv, "{s_c:?} vs {s_t:?}");
    }
}
