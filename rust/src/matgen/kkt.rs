//! Saddle-point KKT matrices (nlpkkt160 analog).
//!
//! Interior-point KKT systems have the 2×2 block form
//! `[[H, Aᵀ], [A, -δI]]` with H an SPD Hessian (stencil-like) and A a
//! sparse constraint Jacobian. nlpkkt160 is a 3-D PDE-constrained
//! optimization problem — H is a 27-point-stencil-like block, which is
//! why its nnz/row (~27) is the highest of Table 1's non-FEM rows.

use crate::core::dim::Dim2;
use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;
use crate::testing::prng::Prng;

/// KKT system with `nh` primal unknowns and `na = nh/2` constraints.
/// Total dimension `nh + nh/2`; regularization `delta` keeps iterative
/// solvers stable (the real nlpkkt matrices are similarly regularized).
pub fn kkt<T: Value>(nh: usize, hess_degree: usize, delta: f64, seed: u64) -> MatrixData<T> {
    let mut rng = Prng::new(seed);
    let na = nh / 2;
    let n = nh + na;
    let mut d = MatrixData::new(Dim2::square(n));
    // H block: banded SPD with hess_degree couplings per row
    for i in 0..nh {
        for step in 1..=hess_degree / 2 {
            let j = (i + step) % nh;
            let v = T::from_f64(-rng.uniform(0.2, 0.8));
            d.push(i as i32, j as i32, v);
            d.push(j as i32, i as i32, v);
        }
    }
    // A block (na x nh): each constraint touches ~4 primal variables
    for c in 0..na {
        for _ in 0..4 {
            let j = rng.below(nh);
            let v = T::from_f64(rng.uniform(-1.0, 1.0));
            d.push((nh + c) as i32, j as i32, v); // A
            d.push(j as i32, (nh + c) as i32, v); // A^T
        }
    }
    d.normalize();
    // diagonal: dominant on H, -delta regularization on the (2,2) block
    let mut row_abs = vec![0.0f64; n];
    for e in &d.entries {
        if e.row != e.col {
            row_abs[e.row as usize] += e.val.as_f64().abs();
        }
    }
    for i in 0..nh {
        d.push(i as i32, i as i32, T::from_f64(row_abs[i] + 1.0));
    }
    for c in 0..na {
        let i = nh + c;
        // dominance keeps the whole system solvable by the paper's
        // unsymmetric solvers; the sign keeps the saddle-point character
        d.push(i as i32, i as i32, T::from_f64(row_abs[i] + delta.max(0.1)));
    }
    d.normalize();
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::MatrixStats;

    #[test]
    fn block_structure_dims() {
        let d = kkt::<f64>(1000, 26, 0.5, 9);
        assert_eq!(d.dim.rows, 1500);
        let s = MatrixStats::from_data(&d);
        assert!(s.avg_row > 10.0, "{s:?}");
    }

    #[test]
    fn constraint_rows_sparser_than_hessian_rows() {
        let d = kkt::<f64>(2000, 26, 0.5, 10);
        let lens = d.row_lengths();
        let h_avg: f64 = lens[..2000].iter().sum::<usize>() as f64 / 2000.0;
        let a_avg: f64 = lens[2000..].iter().sum::<usize>() as f64 / 1000.0;
        assert!(h_avg > 2.0 * a_avg, "H {h_avg} vs A {a_avg}");
    }

    #[test]
    fn bicgstab_converges_on_kkt() {
        use crate::core::executor::Executor;
        use crate::matrix::{Csr, Dense};
        use crate::solver::{BiCgStab, Solver, SolverConfig};
        use crate::stop::Criterion;
        let d = kkt::<f64>(400, 8, 1.0, 12);
        let n = d.dim.rows;
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &d).unwrap();
        let b = Dense::filled(exec.clone(), crate::Dim2::new(n, 1), 1.0);
        let mut x = Dense::zeros(exec.clone(), crate::Dim2::new(n, 1));
        let r = BiCgStab::new(SolverConfig::with_criterion(Criterion::residual(1e-8, 1000)))
            .solve(&a, &b, &mut x)
            .unwrap();
        assert!(r.converged, "{r:?}");
    }
}
