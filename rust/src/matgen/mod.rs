//! Synthetic matrix generators — the SuiteSparse Matrix Collection
//! substitute (DESIGN.md §Substitutions).
//!
//! The paper benchmarks on SuiteSparse matrices (Table 1 + a wide SpMV
//! suite). Offline we generate structural analogs: each generator
//! controls exactly the properties SpMV/solver performance depends on —
//! dimension, nnz, row-length distribution, and column-access locality —
//! matched per origin class (circuit simulation, CFD stencils,
//! unstructured FEM, saddle-point KKT, porous-media flow).

pub mod circuit;
pub mod fem;
pub mod kkt;
pub mod porous;
pub mod stencil;
pub mod suite;

pub use suite::{table1, table1_entry, MatrixClass, SuiteEntry};

use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;

/// Structural statistics of a generated matrix (consumed by the perf
/// model and printed by the table benches).
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixStats {
    pub n: usize,
    pub nnz: usize,
    pub avg_row: f64,
    pub max_row: usize,
    /// Coefficient of variation of row lengths (0 = perfectly regular).
    pub row_cv: f64,
    /// Mean |col - row| distance normalized by n — proxy for the
    /// column-access locality of the SpMV gather (0 = diagonal).
    pub bandwidth_frac: f64,
}

impl MatrixStats {
    /// Rescale to a target dimension, preserving shape statistics
    /// (density, irregularity, locality). Used to project paper-size
    /// performance from a scaled-down generated analog.
    pub fn scaled_to(&self, n_target: usize, nnz_target: usize) -> Self {
        let factor = n_target as f64 / self.n.max(1) as f64;
        Self {
            n: n_target,
            nnz: nnz_target,
            avg_row: nnz_target as f64 / n_target.max(1) as f64,
            max_row: ((self.max_row as f64) * factor).round().max(1.0) as usize,
            row_cv: self.row_cv,
            bandwidth_frac: self.bandwidth_frac,
        }
    }

    /// Compute stats from assembly data.
    pub fn from_data<T: Value>(data: &MatrixData<T>) -> Self {
        let n = data.dim.rows;
        let nnz = data.nnz();
        let lens = data.row_lengths();
        let avg = nnz as f64 / n.max(1) as f64;
        let var = lens
            .iter()
            .map(|&l| (l as f64 - avg) * (l as f64 - avg))
            .sum::<f64>()
            / n.max(1) as f64;
        let max_row = lens.iter().copied().max().unwrap_or(0);
        let mean_dist = if nnz == 0 {
            0.0
        } else {
            data.entries
                .iter()
                .map(|e| (e.row - e.col).abs() as f64)
                .sum::<f64>()
                / nnz as f64
                / n.max(1) as f64
        };
        Self {
            n,
            nnz,
            avg_row: avg,
            max_row,
            row_cv: if avg > 0.0 { var.sqrt() / avg } else { 0.0 },
            bandwidth_frac: mean_dist,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::dim::Dim2;

    #[test]
    fn stats_of_identity() {
        let mut d = MatrixData::<f64>::new(Dim2::square(10));
        for i in 0..10 {
            d.push(i, i, 1.0);
        }
        let s = MatrixStats::from_data(&d);
        assert_eq!(s.n, 10);
        assert_eq!(s.nnz, 10);
        assert_eq!(s.avg_row, 1.0);
        assert_eq!(s.max_row, 1);
        assert_eq!(s.row_cv, 0.0);
        assert_eq!(s.bandwidth_frac, 0.0);
    }

    #[test]
    fn stats_detect_irregularity() {
        let mut d = MatrixData::<f64>::new(Dim2::square(10));
        for j in 0..10 {
            d.push(0, j, 1.0); // one dense row
        }
        let s = MatrixStats::from_data(&d);
        assert!(s.row_cv > 1.0);
        assert!(s.bandwidth_frac > 0.1);
    }
}
