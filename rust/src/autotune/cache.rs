//! Persistent tuning cache.
//!
//! Tuning costs real SpMV applies, so decisions are persisted across
//! runs in a small hand-rolled JSON file (the offline vendor set has no
//! serde). The file is versioned; a missing, corrupt or
//! version-mismatched file degrades to an empty cache — re-tuning is
//! always correct, only slower. Keys combine the structural feature
//! fingerprint with the executor name, modeled device and precision, so
//! a cache is shared safely between programs tuning different matrices
//! on different backends.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::core::error::Result;
use crate::core::types::Precision;
use crate::perfmodel::Device;

use super::prior::FormatChoice;

/// Cache file format version; bump when the entry schema changes.
pub const CACHE_VERSION: u32 = 1;

/// One cached tuning decision.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheEntry {
    /// The winning format.
    pub format: FormatChoice,
    /// Its measured (or predicted, if measurement was disabled)
    /// per-apply time, microseconds.
    pub us_per_apply: f64,
}

/// Build the cache key for one (matrix, backend, device, precision).
pub fn cache_key(fingerprint: u64, exec_name: &str, device: Device, p: Precision) -> String {
    format!(
        "{fingerprint:016x}/{exec_name}/{}/{}",
        device.spec().name,
        p.name()
    )
}

/// The on-disk tuning cache.
#[derive(Debug, Default)]
pub struct TuneCache {
    path: Option<PathBuf>,
    entries: HashMap<String, CacheEntry>,
}

impl TuneCache {
    /// A cache that never touches disk (tests, one-shot programs).
    pub fn in_memory() -> Self {
        Self::default()
    }

    /// Load from `path`; missing, unreadable, corrupt or
    /// version-mismatched files yield an empty cache bound to the same
    /// path (the next `save` rewrites it wholesale).
    pub fn load(path: impl AsRef<Path>) -> Self {
        let path = path.as_ref().to_path_buf();
        let entries = std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| parse_cache_json(&text))
            .unwrap_or_default();
        Self {
            path: Some(path),
            entries,
        }
    }

    /// Default cache location: `$SPARKLE_TUNE_CACHE` or
    /// `.sparkle_tune.json` in the working directory.
    pub fn default_path() -> PathBuf {
        std::env::var_os("SPARKLE_TUNE_CACHE")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from(".sparkle_tune.json"))
    }

    /// Look up a decision.
    pub fn get(&self, key: &str) -> Option<&CacheEntry> {
        self.entries.get(key)
    }

    /// Record a decision (in memory; call [`TuneCache::save`] to persist).
    pub fn put(&mut self, key: String, entry: CacheEntry) {
        self.entries.insert(key, entry);
    }

    /// Number of cached decisions.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the cache holds no decisions.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Write the cache back to its path (no-op for in-memory caches).
    pub fn save(&self) -> Result<()> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        let mut keys: Vec<&String> = self.entries.keys().collect();
        keys.sort(); // deterministic file content
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"version\": {CACHE_VERSION},\n"));
        out.push_str("  \"entries\": [\n");
        for (i, key) in keys.iter().enumerate() {
            let e = &self.entries[*key];
            out.push_str(&format!(
                "    {{\"key\": \"{}\", \"format\": \"{}\", \"us\": {}}}{}\n",
                escape_json(key),
                e.format.name(),
                e.us_per_apply,
                if i + 1 < keys.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write(path, out)?;
        Ok(())
    }
}

fn escape_json(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Parse the cache JSON. Returns `None` on any structural anomaly —
/// the caller treats that as an empty cache.
fn parse_cache_json(text: &str) -> Option<HashMap<String, CacheEntry>> {
    let version = json_u32_field(text, "version")?;
    if version != CACHE_VERSION {
        return None;
    }
    let start = text.find("\"entries\"")?;
    let open = text[start..].find('[')? + start;
    let close = matching_bracket(text, open, '[', ']')?;
    let body = &text[open + 1..close];
    let mut entries = HashMap::new();
    let mut pos = 0;
    while let Some(rel) = body[pos..].find('{') {
        let obj_open = pos + rel;
        let obj_close = matching_bracket(body, obj_open, '{', '}')?;
        let obj = &body[obj_open..=obj_close];
        let key = json_str_field(obj, "key")?;
        let format = FormatChoice::parse(&json_str_field(obj, "format")?)?;
        let us = json_f64_field(obj, "us")?;
        if !us.is_finite() || us < 0.0 {
            return None;
        }
        entries.insert(
            key,
            CacheEntry {
                format,
                us_per_apply: us,
            },
        );
        pos = obj_close + 1;
    }
    Some(entries)
}

/// Index of the bracket matching `text[open]` (which must be `ob`),
/// ignoring brackets inside string literals.
fn matching_bracket(text: &str, open: usize, ob: char, cb: char) -> Option<usize> {
    let bytes = text.as_bytes();
    if bytes.get(open) != Some(&(ob as u8)) {
        return None;
    }
    let mut depth = 0i32;
    let mut in_str = false;
    let mut escaped = false;
    for (i, &b) in bytes.iter().enumerate().skip(open) {
        let c = b as char;
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        if c == '"' {
            in_str = true;
        } else if c == ob {
            depth += 1;
        } else if c == cb {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
    }
    None
}

/// Value of `"name": "..."` inside `obj` (unescapes \" \\ \uXXXX).
fn json_str_field(obj: &str, name: &str) -> Option<String> {
    let tail = field_tail(obj, name)?;
    let tail = tail.strip_prefix('"')?;
    let mut out = String::new();
    let mut chars = tail.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => return Some(out),
            '\\' => match chars.next()? {
                '"' => out.push('"'),
                '\\' => out.push('\\'),
                'u' => {
                    let hex: String = chars.by_ref().take(4).collect();
                    let code = u32::from_str_radix(&hex, 16).ok()?;
                    out.push(char::from_u32(code)?);
                }
                other => out.push(other),
            },
            c => out.push(c),
        }
    }
    None
}

fn json_f64_field(obj: &str, name: &str) -> Option<f64> {
    let tail = field_tail(obj, name)?;
    let end = tail
        .find(|c: char| !matches!(c, '0'..='9' | '.' | '-' | '+' | 'e' | 'E'))
        .unwrap_or(tail.len());
    tail[..end].parse().ok()
}

fn json_u32_field(obj: &str, name: &str) -> Option<u32> {
    json_f64_field(obj, name).and_then(|v| {
        if v >= 0.0 && v.fract() == 0.0 {
            Some(v as u32)
        } else {
            None
        }
    })
}

/// Slice of `obj` immediately after `"name":` with whitespace skipped.
fn field_tail<'a>(obj: &'a str, name: &str) -> Option<&'a str> {
    let pat = format!("\"{name}\"");
    let at = obj.find(&pat)? + pat.len();
    let rest = obj[at..].trim_start();
    let rest = rest.strip_prefix(':')?;
    Some(rest.trim_start())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("sparkle_cache_test_{}_{tag}.json", std::process::id()))
    }

    fn sample_entry() -> CacheEntry {
        CacheEntry {
            format: FormatChoice::Ell,
            us_per_apply: 12.75,
        }
    }

    #[test]
    fn round_trips_through_disk() {
        let path = tmp_path("round_trip");
        let mut c = TuneCache::load(&path);
        assert!(c.is_empty());
        c.put("abc/par/GEN12/f64".into(), sample_entry());
        c.put(
            "def/reference/GEN9/f32".into(),
            CacheEntry {
                format: FormatChoice::Csr,
                us_per_apply: 0.5,
            },
        );
        c.save().unwrap();
        let r = TuneCache::load(&path);
        assert_eq!(r.len(), 2);
        assert_eq!(r.get("abc/par/GEN12/f64"), Some(&sample_entry()));
        assert_eq!(
            r.get("def/reference/GEN9/f32").unwrap().format,
            FormatChoice::Csr
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_and_missing_files_degrade_to_empty() {
        let missing = TuneCache::load(tmp_path("missing_never_written"));
        assert!(missing.is_empty());
        let path = tmp_path("corrupt");
        std::fs::write(&path, "{\"version\": 1, \"entries\": [{\"key\": \"trunc").unwrap();
        assert!(TuneCache::load(&path).is_empty());
        std::fs::write(&path, "not json at all").unwrap();
        assert!(TuneCache::load(&path).is_empty());
        // wrong version: ignored wholesale
        std::fs::write(
            &path,
            "{\"version\": 99, \"entries\": [{\"key\": \"k\", \"format\": \"csr\", \"us\": 1}]}",
        )
        .unwrap();
        assert!(TuneCache::load(&path).is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_unknown_format_and_bad_numbers() {
        assert!(parse_cache_json(
            "{\"version\": 1, \"entries\": [{\"key\": \"k\", \"format\": \"bsr\", \"us\": 1}]}"
        )
        .is_none());
        assert!(parse_cache_json(
            "{\"version\": 1, \"entries\": [{\"key\": \"k\", \"format\": \"csr\", \"us\": -3}]}"
        )
        .is_none());
    }

    #[test]
    fn keys_with_escapes_survive() {
        let path = tmp_path("escapes");
        let mut c = TuneCache::load(&path);
        c.put("weird\"key\\with/stuff".into(), sample_entry());
        c.save().unwrap();
        let r = TuneCache::load(&path);
        assert_eq!(r.get("weird\"key\\with/stuff"), Some(&sample_entry()));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cache_key_is_stable_and_discriminating() {
        let a = cache_key(0xABCD, "par", Device::Gen12, Precision::Double);
        let b = cache_key(0xABCD, "par", Device::Gen12, Precision::Single);
        let c = cache_key(0xABCD, "reference", Device::Gen12, Precision::Double);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert!(a.starts_with("000000000000abcd/par/"));
    }

    #[test]
    fn in_memory_save_is_noop() {
        let mut c = TuneCache::in_memory();
        c.put("k".into(), sample_entry());
        c.save().unwrap();
        assert_eq!(c.len(), 1);
    }
}
