//! Model-based format ranking (no kernel is run).
//!
//! Each candidate format is scored with the calibrated roofline/traffic
//! model from `perfmodel`: predicted bytes moved and flops give a
//! predicted time, and candidates are ranked ascending. The prior's job
//! is not to be exactly right — it is to put the true winner inside the
//! top-k that [`crate::autotune::measure`] then times for real, and to
//! exclude formats that are structurally hopeless (ELL on a power-law
//! matrix) before they allocate.

use crate::core::executor::Executor;
use crate::core::types::Precision;
use crate::perfmodel::{project_spmv, Device, SpmvKernelKind};
use crate::perfmodel::project::Implementation;

use super::features::Features;

/// The five storage formats the library can select between.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FormatChoice {
    Csr,
    Coo,
    Ell,
    SellP,
    Hybrid,
}

impl FormatChoice {
    /// Every format, in selection-priority order for ties.
    pub const ALL: [FormatChoice; 5] = [
        FormatChoice::Csr,
        FormatChoice::Coo,
        FormatChoice::Ell,
        FormatChoice::SellP,
        FormatChoice::Hybrid,
    ];

    /// Stable lowercase name (used by the cache serialization).
    pub fn name(self) -> &'static str {
        match self {
            FormatChoice::Csr => "csr",
            FormatChoice::Coo => "coo",
            FormatChoice::Ell => "ell",
            FormatChoice::SellP => "sellp",
            FormatChoice::Hybrid => "hybrid",
        }
    }

    /// Inverse of [`FormatChoice::name`].
    pub fn parse(s: &str) -> Option<FormatChoice> {
        FormatChoice::ALL.iter().copied().find(|f| f.name() == s)
    }
}

impl std::fmt::Display for FormatChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One ranked candidate.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub format: FormatChoice,
    /// Model-predicted time for one SpMV, microseconds.
    pub predicted_us: f64,
    /// Model-predicted throughput.
    pub predicted_gflops: f64,
}

/// ELL storage blow-up cap: beyond this padding ratio (or an absolute
/// padded-entry count) the format is excluded outright, matching the
/// guards the format benches use.
const ELL_MAX_PADDING: f64 = 8.0;
const ELL_MAX_STORED: usize = 64_000_000;

/// Whether the executor can apply the format at all.
pub fn supported_on(exec: &Executor, format: FormatChoice) -> bool {
    match (exec, format) {
        // no SELL-P artifact on the ported backend (kernels::spmv)
        (Executor::Xla(_), FormatChoice::SellP) => false,
        _ => true,
    }
}

/// Whether ELL storage is even worth constructing for this structure.
pub fn ell_is_viable(feats: &Features) -> bool {
    feats.ell_padding_ratio <= ELL_MAX_PADDING
        && feats.rows.saturating_mul(feats.max_row) <= ELL_MAX_STORED
}

/// Rank all candidate formats for `feats` on `exec`, modeled on
/// `device`, best (lowest predicted time) first. Never empty: CSR is
/// always a candidate.
pub fn rank(
    feats: &Features,
    exec: &Executor,
    device: Device,
    p: Precision,
) -> Vec<Candidate> {
    let stats = feats.to_stats();
    let mut out: Vec<Candidate> = Vec::with_capacity(FormatChoice::ALL.len());

    let project = |kind: SpmvKernelKind, stats: &crate::matgen::MatrixStats| {
        project_spmv(device, Implementation::Sparkle, kind, stats, p)
    };

    for format in FormatChoice::ALL {
        if !supported_on(exec, format) {
            continue;
        }
        let (predicted_us, predicted_gflops) = match format {
            FormatChoice::Csr => {
                let pr = project(SpmvKernelKind::Csr, &stats);
                (pr.time_us, pr.gflops)
            }
            FormatChoice::Coo => {
                let pr = project(SpmvKernelKind::Coo, &stats);
                (pr.time_us, pr.gflops)
            }
            FormatChoice::SellP => {
                let pr = project(SpmvKernelKind::SellP, &stats);
                (pr.time_us, pr.gflops)
            }
            FormatChoice::Ell => {
                if !ell_is_viable(feats) {
                    continue;
                }
                let pr = project(SpmvKernelKind::Ell, &stats);
                (pr.time_us, pr.gflops)
            }
            FormatChoice::Hybrid => {
                // Split model: the ELL part holds the regular core at
                // width ≈ avg_row with near-zero padding; the COO part
                // absorbs the imbalanced spill. Spill mass grows with
                // row-length skew (cv); for regular matrices it vanishes
                // and hybrid degenerates to ELL + an extra launch.
                let spill_frac =
                    (0.5 * feats.row_cv / (1.0 + feats.row_cv)).clamp(0.0, 0.5);
                let w = feats.avg_row.ceil().max(1.0) as usize;
                let ell_nnz =
                    ((feats.nnz as f64) * (1.0 - spill_frac)).round() as usize;
                let coo_nnz = feats.nnz - ell_nnz.min(feats.nnz);
                let mut ell_stats = stats.clone();
                ell_stats.max_row = w;
                ell_stats.nnz = ell_nnz.max(1);
                ell_stats.avg_row = ell_stats.nnz as f64 / feats.rows.max(1) as f64;
                ell_stats.row_cv = 0.0;
                let pe = project(SpmvKernelKind::Ell, &ell_stats);
                let mut t_us = pe.time_us;
                let mut flops = 2.0 * ell_stats.nnz as f64;
                if coo_nnz > 0 {
                    let mut coo_stats = stats.clone();
                    coo_stats.nnz = coo_nnz;
                    coo_stats.avg_row = coo_nnz as f64 / feats.rows.max(1) as f64;
                    let pc = project(SpmvKernelKind::Coo, &coo_stats);
                    t_us += pc.time_us;
                    flops += 2.0 * coo_nnz as f64;
                }
                (t_us, flops / (t_us * 1e3))
            }
        };
        out.push(Candidate {
            format,
            predicted_us,
            predicted_gflops,
        });
    }

    out.sort_by(|a, b| {
        a.predicted_us
            .partial_cmp(&b.predicted_us)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::dim::Dim2;
    use crate::core::matrix_data::MatrixData;

    fn feats_of(d: &MatrixData<f64>) -> Features {
        Features::from_data(d)
    }

    #[test]
    fn ell_excluded_on_power_law_rows() {
        let n = 64;
        let mut d = MatrixData::<f64>::new(Dim2::square(n));
        for j in 0..n {
            d.push(0, j as i32, 1.0);
        }
        for i in 1..n {
            d.push(i as i32, i as i32, 2.0);
        }
        d.normalize();
        let f = feats_of(&d);
        assert!(!ell_is_viable(&f), "padding ratio {}", f.ell_padding_ratio);
        let ranked = rank(&f, &Executor::par(), Device::Gen12, Precision::Double);
        assert!(ranked.iter().all(|c| c.format != FormatChoice::Ell));
        assert!(ranked.iter().any(|c| c.format == FormatChoice::Csr));
    }

    #[test]
    fn regular_matrix_ranks_simd_formats_high() {
        // 5-point-stencil-like regular structure
        let n = 1024;
        let mut d = MatrixData::<f64>::new(Dim2::square(n));
        for i in 0..n as i32 {
            for dj in [-1i32, 0, 1] {
                let j = i + dj;
                if (0..n as i32).contains(&j) {
                    d.push(i, j, 1.0);
                }
            }
        }
        d.normalize();
        let f = feats_of(&d);
        let ranked = rank(&f, &Executor::par(), Device::Gen12, Precision::Double);
        assert!(!ranked.is_empty());
        assert!(ranked.windows(2).all(|w| w[0].predicted_us <= w[1].predicted_us));
        // ELL must be viable and competitive on a near-regular structure
        assert!(ranked
            .iter()
            .take(3)
            .any(|c| matches!(c.format, FormatChoice::Ell | FormatChoice::SellP)));
    }

    #[test]
    fn xla_executor_excludes_sellp() {
        let mut d = MatrixData::<f64>::new(Dim2::square(8));
        for i in 0..8 {
            d.push(i, i, 1.0);
        }
        d.normalize();
        let f = feats_of(&d);
        // artifacts dir may be absent; Executor::xla still constructs
        let exec = Executor::xla("artifacts_nonexistent_for_test").unwrap();
        let ranked = rank(&f, &exec, Device::Gen9, Precision::Single);
        assert!(ranked.iter().all(|c| c.format != FormatChoice::SellP));
    }
}
