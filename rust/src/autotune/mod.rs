//! Automatic sparse-format selection (the library picks, not the user).
//!
//! The paper's central empirical result is that no single format wins
//! across matrices and devices — SpMV on GEN9/GEN12 swings by large
//! factors between CSR, COO, ELL and hybrid depending on sparsity
//! structure (§6.3), which is why Ginkgo ships a format zoo at all.
//! This subsystem closes the loop the paper leaves to the user:
//!
//! 1. [`features`] extracts cheap structural statistics from assembly
//!    data (row-length moments, imbalance, locality, padding ratio);
//! 2. [`prior`] ranks the candidate formats with the calibrated
//!    roofline/traffic model from `perfmodel` — no kernel is run;
//! 3. [`measure`] refines the top of the ranking by timing real SpMV
//!    applies through `bench_util`'s timer;
//! 4. [`cache`] persists the decision on disk keyed by a feature
//!    fingerprint, so repeated runs skip re-tuning entirely;
//! 5. [`auto`] wraps the winner in [`AutoMatrix`], a drop-in [`LinOp`]
//!    for every solver in `solver/`.
//!
//! [`LinOp`]: crate::core::linop::LinOp

pub mod auto;
pub mod cache;
pub mod features;
pub mod measure;
pub mod prior;

pub use auto::{AutoConfig, AutoMatrix, AutoReport, ChoiceSource};
pub use cache::{cache_key, CacheEntry, TuneCache};
pub use features::Features;
pub use measure::{measure_formats, Measurement, MeasurePolicy};
pub use prior::{rank, Candidate, FormatChoice};
