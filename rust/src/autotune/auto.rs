//! [`AutoMatrix`]: the self-selecting sparse operator.
//!
//! Construction runs the full selection pipeline — features → cached
//! decision? → roofline prior → top-k measurement — and wraps the
//! winning format behind [`LinOp`], so every solver and every call site
//! that takes an operator works unchanged. The [`AutoReport`] records
//! what was decided and why, including how many measurement applies
//! were spent (zero on a warm cache — the property the cache exists
//! to provide).

use std::path::PathBuf;
use std::sync::Arc;

use crate::core::dim::Dim2;
use crate::core::error::Result;
use crate::core::executor::Executor;
use crate::core::linop::LinOp;
use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;
use crate::matrix::{Coo, Csr, Dense, Ell, Hybrid, SellP};
use crate::perfmodel::Device;

use super::cache::{cache_key, CacheEntry, TuneCache};
use super::features::Features;
use super::measure::{measure_formats, MeasurePolicy, Measurement};
use super::prior::{self, Candidate, FormatChoice};

/// How the selection was made.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChoiceSource {
    /// Warm tuning cache — no model query, no measurement.
    Cache,
    /// Empirical top-k measurement refined the prior.
    Measured,
    /// Roofline prior alone (measurement disabled or impossible).
    Prior,
}

impl ChoiceSource {
    /// Lowercase tag used in telemetry events and reports.
    pub fn name(self) -> &'static str {
        match self {
            ChoiceSource::Cache => "cache",
            ChoiceSource::Measured => "measured",
            ChoiceSource::Prior => "prior",
        }
    }
}

/// Selection configuration.
#[derive(Debug, Clone)]
pub struct AutoConfig {
    /// Device whose roofline model ranks the candidates. GEN12 is the
    /// paper's newest Intel part and the repo's primary target.
    pub device: Device,
    /// Run the empirical refinement pass (false = trust the prior).
    pub measure: bool,
    /// Measurement warmup/reps/top-k.
    pub policy: MeasurePolicy,
    /// Tuning-cache file; `None` disables persistence.
    pub cache_path: Option<PathBuf>,
}

impl Default for AutoConfig {
    fn default() -> Self {
        Self {
            device: Device::Gen12,
            measure: true,
            policy: MeasurePolicy::default(),
            cache_path: None,
        }
    }
}

impl AutoConfig {
    /// Default config persisting to [`TuneCache::default_path`].
    pub fn cached() -> Self {
        Self {
            cache_path: Some(TuneCache::default_path()),
            ..Self::default()
        }
    }
}

/// What the tuner decided and why.
#[derive(Debug, Clone)]
pub struct AutoReport {
    /// Extracted structural features.
    pub features: Features,
    /// Prior ranking, best-first (empty on a cache hit).
    pub candidates: Vec<Candidate>,
    /// Empirical measurements, fastest-first (empty unless measured).
    pub measurements: Vec<Measurement>,
    /// The winner.
    pub chosen: FormatChoice,
    /// How it won.
    pub source: ChoiceSource,
    /// Total SpMV applies spent measuring (0 on a cache hit or a
    /// prior-only decision).
    pub measure_applies: usize,
}

enum Inner<T> {
    Csr(Csr<T>),
    Coo(Coo<T>),
    Ell(Ell<T>),
    SellP(SellP<T>),
    Hybrid(Hybrid<T>),
}

/// A sparse operator that picked its own storage format.
pub struct AutoMatrix<T> {
    exec: Arc<Executor>,
    dim: Dim2,
    inner: Inner<T>,
    report: AutoReport,
}

impl<T: Value> AutoMatrix<T> {
    /// Select with the default configuration (measured, GEN12 prior,
    /// no persistence).
    pub fn from_data(exec: Arc<Executor>, data: &MatrixData<T>) -> Result<Self> {
        Self::with_config(exec, data, &AutoConfig::default())
    }

    /// Select with persistence at the default cache path.
    pub fn from_data_cached(exec: Arc<Executor>, data: &MatrixData<T>) -> Result<Self> {
        Self::with_config(exec, data, &AutoConfig::cached())
    }

    /// Full selection pipeline under an explicit configuration.
    pub fn with_config(
        exec: Arc<Executor>,
        data: &MatrixData<T>,
        cfg: &AutoConfig,
    ) -> Result<Self> {
        data.validate()?;
        let features = Features::from_data(data);
        let key = cache_key(
            features.fingerprint(),
            exec.name(),
            cfg.device,
            T::PRECISION,
        );
        let mut cache = match &cfg.cache_path {
            Some(p) => TuneCache::load(p),
            None => TuneCache::in_memory(),
        };

        if let Some(hit) = cache.get(&key) {
            if prior::supported_on(&exec, hit.format) {
                let (fmt, us) = (hit.format, hit.us_per_apply);
                crate::observe::emit(|| crate::observe::Event::AutotuneDecision {
                    format: fmt.name().to_string(),
                    source: ChoiceSource::Cache.name().to_string(),
                    predicted_us: us,
                });
                let inner = build_inner(exec.clone(), data, hit.format)?;
                let report = AutoReport {
                    features,
                    candidates: Vec::new(),
                    measurements: Vec::new(),
                    chosen: hit.format,
                    source: ChoiceSource::Cache,
                    measure_applies: 0,
                };
                return Ok(Self {
                    exec,
                    dim: data.dim,
                    inner,
                    report,
                });
            }
        }

        let candidates = prior::rank(&features, &exec, cfg.device, T::PRECISION);
        debug_assert!(!candidates.is_empty(), "CSR is always a candidate");

        let (chosen, source, measurements, us) = if cfg.measure && candidates.len() > 1 {
            let top: Vec<FormatChoice> = candidates
                .iter()
                .take(cfg.policy.top_k.max(1))
                .map(|c| c.format)
                .collect();
            let ms = measure_formats(&exec, data, &top, cfg.policy);
            match ms.first() {
                Some(best) => {
                    let us = best.median_us();
                    (best.format, ChoiceSource::Measured, ms, us)
                }
                // nothing could apply (ported backend without
                // artifacts): fall back to the prior so construction
                // still succeeds and apply reports the real error
                None => (
                    candidates[0].format,
                    ChoiceSource::Prior,
                    ms,
                    candidates[0].predicted_us,
                ),
            }
        } else {
            (
                candidates[0].format,
                ChoiceSource::Prior,
                Vec::new(),
                candidates[0].predicted_us,
            )
        };

        crate::observe::emit(|| crate::observe::Event::AutotuneDecision {
            format: chosen.name().to_string(),
            source: source.name().to_string(),
            predicted_us: us,
        });

        if source == ChoiceSource::Measured {
            cache.put(
                key,
                CacheEntry {
                    format: chosen,
                    us_per_apply: us,
                },
            );
            // persistence is best-effort: an unwritable cache directory
            // must not fail matrix construction
            let _ = cache.save();
        }

        let measure_applies = measurements.iter().map(|m| m.applies).sum();
        let inner = build_inner(exec.clone(), data, chosen)?;
        Ok(Self {
            exec,
            dim: data.dim,
            inner,
            report: AutoReport {
                features,
                candidates,
                measurements,
                chosen,
                source,
                measure_applies,
            },
        })
    }

    /// The selected format.
    pub fn chosen_format(&self) -> FormatChoice {
        self.report.chosen
    }

    /// Full selection report.
    pub fn report(&self) -> &AutoReport {
        &self.report
    }

    /// Stored nonzeros of the wrapped format.
    pub fn nnz(&self) -> usize {
        match &self.inner {
            Inner::Csr(m) => m.nnz(),
            Inner::Coo(m) => m.nnz(),
            Inner::Ell(m) => m.nnz(),
            Inner::SellP(m) => m.nnz(),
            Inner::Hybrid(m) => m.nnz(),
        }
    }

    fn as_linop(&self) -> &dyn LinOp<T> {
        match &self.inner {
            Inner::Csr(m) => m,
            Inner::Coo(m) => m,
            Inner::Ell(m) => m,
            Inner::SellP(m) => m,
            Inner::Hybrid(m) => m,
        }
    }
}

fn build_inner<T: Value>(
    exec: Arc<Executor>,
    data: &MatrixData<T>,
    format: FormatChoice,
) -> Result<Inner<T>> {
    Ok(match format {
        FormatChoice::Csr => Inner::Csr(Csr::from_data(exec, data)?),
        FormatChoice::Coo => Inner::Coo(Coo::from_data(exec, data)?),
        FormatChoice::Ell => Inner::Ell(Ell::from_data(exec, data)?),
        FormatChoice::SellP => Inner::SellP(SellP::from_data(exec, data)?),
        FormatChoice::Hybrid => Inner::Hybrid(Hybrid::from_data(exec, data)?),
    })
}

impl<T: Value> LinOp<T> for AutoMatrix<T> {
    fn shape(&self) -> Dim2 {
        self.dim
    }

    fn executor(&self) -> &Arc<Executor> {
        &self.exec
    }

    fn apply(&self, b: &Dense<T>, x: &mut Dense<T>) -> Result<()> {
        self.as_linop().apply(b, x)
    }

    fn apply_advanced(&self, alpha: T, b: &Dense<T>, beta: T, x: &mut Dense<T>) -> Result<()> {
        self.as_linop().apply_advanced(alpha, b, beta, x)
    }

    fn apply_dot(&self, b: &Dense<T>, x: &mut Dense<T>, w: &Dense<T>) -> Result<(T, T)> {
        self.as_linop().apply_dot(b, x, w)
    }

    fn op_name(&self) -> &'static str {
        "auto"
    }
}

impl<T: Value> std::fmt::Debug for AutoMatrix<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "AutoMatrix<{}>({}, chosen={}, source={:?})",
            T::PRECISION,
            self.dim,
            self.report.chosen,
            self.report.source
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prng::Prng;
    use crate::testing::prop::{assert_close, gen_sparse, gen_vec};

    #[test]
    fn auto_matches_csr_numerics() {
        let mut rng = Prng::new(21);
        let exec = Executor::par_with_threads(2);
        for _ in 0..3 {
            let n = 50 + rng.below(50);
            let data = gen_sparse::<f64>(&mut rng, n, n, 4);
            let bv = gen_vec::<f64>(&mut rng, n);
            let auto = AutoMatrix::from_data(exec.clone(), &data).unwrap();
            let csr = Csr::from_data(exec.clone(), &data).unwrap();
            let b = Dense::vector(exec.clone(), &bv);
            let mut xa = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            let mut xc = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            auto.apply(&b, &mut xa).unwrap();
            csr.apply(&b, &mut xc).unwrap();
            assert_close(xa.as_slice(), xc.as_slice(), 1e-12, "auto apply");

            auto.apply_advanced(1.5, &b, -0.5, &mut xa).unwrap();
            csr.apply_advanced(1.5, &b, -0.5, &mut xc).unwrap();
            assert_close(xa.as_slice(), xc.as_slice(), 1e-12, "auto advanced");
        }
    }

    #[test]
    fn prior_only_config_runs_zero_applies() {
        let mut rng = Prng::new(22);
        let data = gen_sparse::<f64>(&mut rng, 40, 40, 4);
        let cfg = AutoConfig {
            measure: false,
            ..AutoConfig::default()
        };
        let auto = AutoMatrix::with_config(Executor::reference(), &data, &cfg).unwrap();
        assert_eq!(auto.report().source, ChoiceSource::Prior);
        assert_eq!(auto.report().measure_applies, 0);
        assert!(auto.report().candidates.len() > 1);
    }

    #[test]
    fn measured_config_reports_applies() {
        let mut rng = Prng::new(23);
        let data = gen_sparse::<f64>(&mut rng, 40, 40, 4);
        let auto = AutoMatrix::from_data(Executor::reference(), &data).unwrap();
        assert_eq!(auto.report().source, ChoiceSource::Measured);
        assert!(auto.report().measure_applies > 0);
        assert_eq!(
            auto.chosen_format(),
            auto.report().measurements[0].format
        );
    }
}
