//! Sparsity-feature extraction.
//!
//! Everything the selection prior conditions on is derived from the
//! row-length distribution and the column-access locality — the two
//! structural axes the paper's format study varies (§6.3). Extraction
//! is a single pass over the entries, cheap enough to run at every
//! matrix construction.

use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;
use crate::matgen::MatrixStats;
use crate::matrix::csr::Csr;

/// Structural features of a sparse matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Features {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Stored nonzeros (after duplicate summation).
    pub nnz: usize,
    /// Mean nonzeros per row.
    pub avg_row: f64,
    /// Longest row.
    pub max_row: usize,
    /// Variance of row lengths.
    pub row_var: f64,
    /// Coefficient of variation of row lengths (0 = perfectly regular).
    pub row_cv: f64,
    /// Rows with no stored entry (spoilers for row-parallel kernels).
    pub empty_rows: usize,
    /// Mean |col - row| normalized by n — gather-locality proxy,
    /// matching [`MatrixStats::bandwidth_frac`].
    pub bandwidth_frac: f64,
    /// `rows * max_row / nnz`: the storage blow-up ELL would pay
    /// (1.0 = perfectly regular; large = ELL is hopeless).
    pub ell_padding_ratio: f64,
}

impl Features {
    /// Extract from assembly data. Unnormalized data (duplicates,
    /// unsorted) is normalized on a copy first so `nnz` and row lengths
    /// describe what a format would actually store.
    pub fn from_data<T: Value>(data: &MatrixData<T>) -> Self {
        if data.is_normalized() {
            Self::from_normalized(data)
        } else {
            let mut d = data.clone();
            d.normalize();
            Self::from_normalized(&d)
        }
    }

    fn from_normalized<T: Value>(data: &MatrixData<T>) -> Self {
        let lens = data.row_lengths();
        let dist_sum: f64 = data
            .entries
            .iter()
            .map(|e| (e.row - e.col).abs() as f64)
            .sum();
        Self::from_parts(data.dim.rows, data.dim.cols, &lens, dist_sum)
    }

    /// Extract from an already-built CSR matrix (no assembly data
    /// round-trip; used when tuning an existing operator).
    pub fn from_csr<T: Value>(a: &Csr<T>) -> Self {
        let rows = a.shape().rows;
        let lens: Vec<usize> = (0..rows).map(|i| a.row_len(i)).collect();
        let mut dist_sum = 0.0;
        for i in 0..rows {
            let lo = a.row_ptrs()[i] as usize;
            let hi = a.row_ptrs()[i + 1] as usize;
            for &c in &a.col_idxs()[lo..hi] {
                dist_sum += (c as i64 - i as i64).abs() as f64;
            }
        }
        Self::from_parts(rows, a.shape().cols, &lens, dist_sum)
    }

    fn from_parts(rows: usize, cols: usize, lens: &[usize], dist_sum: f64) -> Self {
        let nnz: usize = lens.iter().sum();
        let n = rows.max(1);
        let avg = nnz as f64 / n as f64;
        let var = lens
            .iter()
            .map(|&l| (l as f64 - avg) * (l as f64 - avg))
            .sum::<f64>()
            / n as f64;
        let max_row = lens.iter().copied().max().unwrap_or(0);
        let empty_rows = lens.iter().filter(|&&l| l == 0).count();
        Self {
            rows,
            cols,
            nnz,
            avg_row: avg,
            max_row,
            row_var: var,
            row_cv: if avg > 0.0 { var.sqrt() / avg } else { 0.0 },
            empty_rows,
            bandwidth_frac: if nnz == 0 {
                0.0
            } else {
                dist_sum / nnz as f64 / n as f64
            },
            ell_padding_ratio: if nnz == 0 {
                1.0
            } else {
                (rows * max_row) as f64 / nnz as f64
            },
        }
    }

    /// Bridge to the perf model's statistics type.
    pub fn to_stats(&self) -> MatrixStats {
        MatrixStats {
            n: self.rows,
            nnz: self.nnz,
            avg_row: self.avg_row,
            max_row: self.max_row,
            row_cv: self.row_cv,
            bandwidth_frac: self.bandwidth_frac,
        }
    }

    /// Deterministic fingerprint for the tuning cache. Continuous
    /// features are quantized (1e-3) so numerically-identical rebuilds
    /// of the same structure hash equal, while different structures
    /// collide no more often than the mixer allows.
    pub fn fingerprint(&self) -> u64 {
        let q = |v: f64| (v * 1e3).round() as i64 as u64;
        let mut h = 0xcbf29ce484222325u64;
        for field in [
            self.rows as u64,
            self.cols as u64,
            self.nnz as u64,
            self.max_row as u64,
            self.empty_rows as u64,
            q(self.avg_row),
            q(self.row_cv),
            q(self.bandwidth_frac),
            q(self.ell_padding_ratio),
        ] {
            h ^= field;
            // splitmix64 finalizer as the mixing round
            h = h.wrapping_add(0x9E3779B97F4A7C15);
            h = (h ^ (h >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            h = (h ^ (h >> 27)).wrapping_mul(0x94D049BB133111EB);
            h ^= h >> 31;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::dim::Dim2;
    use crate::core::executor::Executor;

    #[test]
    fn diagonal_matrix_is_perfectly_regular() {
        let n = 16;
        let mut d = MatrixData::<f64>::new(Dim2::square(n));
        for i in 0..n {
            d.push(i as i32, i as i32, 1.0 + i as f64);
        }
        d.normalize();
        let f = Features::from_data(&d);
        assert_eq!((f.rows, f.cols, f.nnz), (n, n, n));
        assert_eq!(f.max_row, 1);
        assert_eq!(f.empty_rows, 0);
        assert_eq!(f.row_cv, 0.0);
        assert_eq!(f.bandwidth_frac, 0.0);
        assert_eq!(f.ell_padding_ratio, 1.0);
    }

    #[test]
    fn empty_rows_counted() {
        // entries only in rows 0 and 3 of a 6-row matrix
        let mut d = MatrixData::<f64>::new(Dim2::new(6, 6));
        d.push(0, 1, 1.0);
        d.push(0, 2, 1.0);
        d.push(3, 0, 1.0);
        d.normalize();
        let f = Features::from_data(&d);
        assert_eq!(f.nnz, 3);
        assert_eq!(f.empty_rows, 4);
        assert_eq!(f.max_row, 2);
        assert!(f.row_cv > 0.0);
    }

    #[test]
    fn single_dense_row_blows_up_padding() {
        // one full row, everyone else diagonal: ELL pads n*n slots
        let n = 32;
        let mut d = MatrixData::<f64>::new(Dim2::square(n));
        for j in 0..n {
            d.push(0, j as i32, 1.0);
        }
        for i in 1..n {
            d.push(i as i32, i as i32, 2.0);
        }
        d.normalize();
        let f = Features::from_data(&d);
        assert_eq!(f.max_row, n);
        assert_eq!(f.nnz, 2 * n - 1);
        let expect = (n * n) as f64 / (2 * n - 1) as f64;
        assert!((f.ell_padding_ratio - expect).abs() < 1e-12);
        assert!(f.row_cv > 1.0, "skew must register, cv={}", f.row_cv);
    }

    #[test]
    fn wholly_empty_matrix_is_finite() {
        let d = MatrixData::<f64>::new(Dim2::new(8, 8));
        let f = Features::from_data(&d);
        assert_eq!(f.nnz, 0);
        assert_eq!(f.empty_rows, 8);
        assert_eq!(f.avg_row, 0.0);
        assert_eq!(f.row_cv, 0.0);
        assert_eq!(f.ell_padding_ratio, 1.0);
        assert!(f.fingerprint() != 0);
    }

    #[test]
    fn csr_and_data_paths_agree() {
        let mut rng = crate::testing::prng::Prng::new(17);
        let d = crate::testing::prop::gen_sparse::<f64>(&mut rng, 60, 60, 6);
        let csr = Csr::from_data(Executor::reference(), &d).unwrap();
        let fa = Features::from_data(&d);
        let fb = Features::from_csr(&csr);
        assert_eq!(fa, fb);
        assert_eq!(fa.fingerprint(), fb.fingerprint());
    }

    #[test]
    fn fingerprint_distinguishes_structures() {
        let mut a = MatrixData::<f64>::new(Dim2::square(10));
        let mut b = MatrixData::<f64>::new(Dim2::square(10));
        for i in 0..10 {
            a.push(i, i, 1.0);
            b.push(i, (9 - i) as i32, 1.0);
        }
        a.normalize();
        b.normalize();
        let (fa, fb) = (Features::from_data(&a), Features::from_data(&b));
        // same row stats, different locality -> different fingerprint
        assert_ne!(fa.fingerprint(), fb.fingerprint());
    }
}
