//! Empirical refinement: time real SpMV applies for the top-ranked
//! candidates.
//!
//! The roofline prior is device-model accurate but host-reality
//! approximate, so the final call is made by the wall clock: each
//! candidate is converted for real and timed with the bench harness's
//! warmup/repetition policy, taking the median as the outlier-robust
//! statistic (`bench_util::stats`). The policy is deliberately lighter
//! than the paper's benchmark setting (§6.3: 2+10) — tuning overhead is
//! paid at matrix construction, not in a bench loop.

use std::sync::Arc;
use std::time::Duration;

use crate::bench_util::{time_secs, Stats};
use crate::core::dim::Dim2;
use crate::core::error::Result;
use crate::core::executor::Executor;
use crate::core::linop::LinOp;
use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;
use crate::matrix::{Coo, Csr, Ell, Hybrid, SellP};
use crate::solver::workspace as ws;

use super::prior::FormatChoice;

/// Warmup/repetition policy for the measurement pass.
#[derive(Debug, Clone, Copy)]
pub struct MeasurePolicy {
    /// Untimed warmup applies per candidate.
    pub warmup: usize,
    /// Timed applies per candidate.
    pub reps: usize,
    /// How many of the prior's top candidates to measure.
    pub top_k: usize,
    /// Hard cap on applies per candidate (probe + warmup + timed);
    /// `0` means unlimited. Guards against a pathological candidate
    /// eating the tuning budget.
    pub max_applies: usize,
    /// Wall-clock budget per candidate. Once exceeded, remaining
    /// warmup/timed applies are skipped — but at least one timed
    /// sample is always taken so the candidate stays rankable.
    pub time_budget: Duration,
}

impl Default for MeasurePolicy {
    fn default() -> Self {
        Self {
            warmup: 1,
            reps: 5,
            top_k: 3,
            max_applies: 64,
            time_budget: Duration::from_secs(2),
        }
    }
}

/// Timing result for one candidate format.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub format: FormatChoice,
    /// Per-apply timing statistics, seconds.
    pub seconds: Stats,
    /// Applies performed for this candidate (warmup + timed + probe).
    pub applies: usize,
}

impl Measurement {
    /// The robust per-apply time used for ranking, microseconds.
    pub fn median_us(&self) -> f64 {
        self.seconds.median * 1e6
    }
}

/// Build one concrete format from assembly data as a boxed operator.
pub fn build_format<T: Value>(
    exec: Arc<Executor>,
    data: &MatrixData<T>,
    format: FormatChoice,
) -> Result<Box<dyn LinOp<T>>> {
    Ok(match format {
        FormatChoice::Csr => Box::new(Csr::from_data(exec, data)?),
        FormatChoice::Coo => Box::new(Coo::from_data(exec, data)?),
        FormatChoice::Ell => Box::new(Ell::from_data(exec, data)?),
        FormatChoice::SellP => Box::new(SellP::from_data(exec, data)?),
        FormatChoice::Hybrid => Box::new(Hybrid::from_data(exec, data)?),
    })
}

/// Convert and time each candidate format; returns measurements sorted
/// fastest-first. Candidates whose conversion fails, whose applies
/// error (e.g. an executor without the needed kernel artifacts — even
/// mid-measurement, after a successful probe), or whose output is
/// non-finite are *disqualified*, never panicked on; the result may
/// therefore be shorter than `formats` — empty when nothing on this
/// executor can apply at all.
pub fn measure_formats<T: Value>(
    exec: &Arc<Executor>,
    data: &MatrixData<T>,
    formats: &[FormatChoice],
    policy: MeasurePolicy,
) -> Vec<Measurement> {
    let dim = data.dim;
    // trial operands come from the solver workspace pool: once a shape
    // has warmed the pool, re-tunes perform zero Dense allocations, so
    // no candidate's timing is skewed by a cold allocation
    let mut b = ws::take_zeroed::<T>(exec, Dim2::new(dim.cols, 1));
    b.fill(T::one());
    let mut x = ws::take_zeroed::<T>(exec, Dim2::new(dim.rows, 1));
    let mut out = Vec::with_capacity(formats.len());
    'candidates: for &format in formats {
        let Ok(op) = build_format(exec.clone(), data, format) else {
            continue;
        };
        // fresh output per candidate so a poisoned result from a prior
        // candidate can never leak into this one's finiteness check
        x.fill(T::zero());
        let mut spent = 0.0f64;
        let budget = policy.time_budget.as_secs_f64();
        let over = |applies: usize, spent: f64| {
            (policy.max_applies > 0 && applies >= policy.max_applies)
                || (budget > 0.0 && spent >= budget)
        };
        // probe once: an executor may construct the format but lack the
        // kernel (ported backend without artifacts) — skip, don't panic
        let mut failed = false;
        spent += time_secs(|| failed = op.apply(&b, &mut x).is_err());
        if failed {
            continue;
        }
        let mut applies = 1usize;
        if !x.as_slice().iter().all(|v| v.as_f64().is_finite()) {
            continue; // wrong answers are worse than slow answers
        }
        for _ in 0..policy.warmup {
            if over(applies, spent) {
                break;
            }
            let mut failed = false;
            spent += time_secs(|| failed = op.apply(&b, &mut x).is_err());
            applies += 1;
            if failed {
                continue 'candidates;
            }
        }
        let mut samples = Vec::with_capacity(policy.reps.max(1));
        for i in 0..policy.reps.max(1) {
            // always take at least one timed sample so the candidate
            // stays rankable even when the probe ate the whole budget
            if i > 0 && over(applies, spent) {
                break;
            }
            let mut failed = false;
            let s = time_secs(|| failed = op.apply(&b, &mut x).is_err());
            applies += 1;
            if failed {
                continue 'candidates;
            }
            spent += s;
            samples.push(s);
        }
        if !x.as_slice().iter().all(|v| v.as_f64().is_finite()) {
            continue;
        }
        let m = Measurement {
            format,
            seconds: Stats::from_samples(&samples),
            applies,
        };
        crate::observe::emit(|| crate::observe::Event::AutotuneCandidate {
            format: format.name().to_string(),
            median_us: m.median_us(),
            applies: m.applies,
        });
        out.push(m);
    }
    out.sort_by(|a, b| {
        a.seconds
            .median
            .partial_cmp(&b.seconds.median)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prng::Prng;
    use crate::testing::prop::gen_sparse;

    #[test]
    fn measures_and_sorts_all_host_formats() {
        let mut rng = Prng::new(5);
        let data = gen_sparse::<f64>(&mut rng, 80, 80, 5);
        let exec = Executor::par_with_threads(2);
        let ms = measure_formats(&exec, &data, &FormatChoice::ALL, MeasurePolicy::default());
        assert_eq!(ms.len(), FormatChoice::ALL.len());
        assert!(ms.windows(2).all(|w| w[0].seconds.median <= w[1].seconds.median));
        for m in &ms {
            assert_eq!(m.applies, 1 + 1 + 5);
            assert!(m.seconds.min >= 0.0);
        }
    }

    #[test]
    fn apply_counts_respect_policy() {
        let mut rng = Prng::new(6);
        let data = gen_sparse::<f64>(&mut rng, 30, 30, 3);
        let exec = Executor::reference();
        let policy = MeasurePolicy {
            warmup: 0,
            reps: 2,
            top_k: 1,
            ..Default::default()
        };
        let ms = measure_formats(&exec, &data, &[FormatChoice::Csr], policy);
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].applies, 3); // probe + 2 timed
        assert_eq!(ms[0].format, FormatChoice::Csr);
    }

    /// A candidate whose apply produces non-finite output must be
    /// disqualified, not ranked (and certainly not panicked on).
    #[test]
    fn nan_matrix_disqualifies_all_candidates() {
        let mut rng = Prng::new(9);
        let mut data = gen_sparse::<f64>(&mut rng, 30, 30, 3);
        data.entries[0].val = f64::NAN;
        let exec = Executor::reference();
        let ms = measure_formats(&exec, &data, &FormatChoice::ALL, MeasurePolicy::default());
        assert!(ms.is_empty(), "NaN output must disqualify, got {ms:?}");
    }

    /// The per-candidate apply cap bounds work even with a huge reps
    /// setting, while still producing at least one timed sample.
    #[test]
    fn apply_cap_bounds_measurement() {
        let mut rng = Prng::new(10);
        let data = gen_sparse::<f64>(&mut rng, 30, 30, 3);
        let exec = Executor::reference();
        let policy = MeasurePolicy {
            warmup: 100,
            reps: 100,
            max_applies: 4,
            ..Default::default()
        };
        let ms = measure_formats(&exec, &data, &[FormatChoice::Csr], policy);
        assert_eq!(ms.len(), 1);
        assert!(ms[0].applies <= 5, "cap 4 + guaranteed sample, got {}", ms[0].applies);
        assert!(ms[0].seconds.median >= 0.0);
    }
}
