//! Empirical refinement: time real SpMV applies for the top-ranked
//! candidates.
//!
//! The roofline prior is device-model accurate but host-reality
//! approximate, so the final call is made by the wall clock: each
//! candidate is converted for real and timed with the bench harness's
//! warmup/repetition policy, taking the median as the outlier-robust
//! statistic (`bench_util::stats`). The policy is deliberately lighter
//! than the paper's benchmark setting (§6.3: 2+10) — tuning overhead is
//! paid at matrix construction, not in a bench loop.

use std::sync::Arc;

use crate::bench_util::{Stats, Timer};
use crate::core::dim::Dim2;
use crate::core::error::Result;
use crate::core::executor::Executor;
use crate::core::linop::LinOp;
use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;
use crate::matrix::{Coo, Csr, Ell, Hybrid, SellP};

use super::prior::FormatChoice;

/// Warmup/repetition policy for the measurement pass.
#[derive(Debug, Clone, Copy)]
pub struct MeasurePolicy {
    /// Untimed warmup applies per candidate.
    pub warmup: usize,
    /// Timed applies per candidate.
    pub reps: usize,
    /// How many of the prior's top candidates to measure.
    pub top_k: usize,
}

impl Default for MeasurePolicy {
    fn default() -> Self {
        Self {
            warmup: 1,
            reps: 5,
            top_k: 3,
        }
    }
}

/// Timing result for one candidate format.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub format: FormatChoice,
    /// Per-apply timing statistics, seconds.
    pub seconds: Stats,
    /// Applies performed for this candidate (warmup + timed + probe).
    pub applies: usize,
}

impl Measurement {
    /// The robust per-apply time used for ranking, microseconds.
    pub fn median_us(&self) -> f64 {
        self.seconds.median * 1e6
    }
}

/// Build one concrete format from assembly data as a boxed operator.
pub fn build_format<T: Value>(
    exec: Arc<Executor>,
    data: &MatrixData<T>,
    format: FormatChoice,
) -> Result<Box<dyn LinOp<T>>> {
    Ok(match format {
        FormatChoice::Csr => Box::new(Csr::from_data(exec, data)?),
        FormatChoice::Coo => Box::new(Coo::from_data(exec, data)?),
        FormatChoice::Ell => Box::new(Ell::from_data(exec, data)?),
        FormatChoice::SellP => Box::new(SellP::from_data(exec, data)?),
        FormatChoice::Hybrid => Box::new(Hybrid::from_data(exec, data)?),
    })
}

/// Convert and time each candidate format; returns measurements sorted
/// fastest-first. Candidates whose conversion or probe apply fails
/// (e.g. an executor without the needed kernel artifacts) are skipped;
/// the result may therefore be shorter than `formats` — empty when
/// nothing on this executor can apply at all.
pub fn measure_formats<T: Value>(
    exec: &Arc<Executor>,
    data: &MatrixData<T>,
    formats: &[FormatChoice],
    policy: MeasurePolicy,
) -> Vec<Measurement> {
    let dim = data.dim;
    let b = crate::matrix::Dense::filled(exec.clone(), Dim2::new(dim.cols, 1), T::one());
    let mut x = crate::matrix::Dense::zeros(exec.clone(), Dim2::new(dim.rows, 1));
    let timer = Timer::new(policy.warmup, policy.reps.max(1));
    let mut out = Vec::with_capacity(formats.len());
    for &format in formats {
        let Ok(op) = build_format(exec.clone(), data, format) else {
            continue;
        };
        // probe once: an executor may construct the format but lack the
        // kernel (ported backend without artifacts) — skip, don't panic
        if op.apply(&b, &mut x).is_err() {
            continue;
        }
        let seconds = timer.run(|| {
            op.apply(&b, &mut x).expect("probed apply cannot fail");
        });
        out.push(Measurement {
            format,
            seconds,
            applies: 1 + policy.warmup + policy.reps.max(1),
        });
    }
    out.sort_by(|a, b| {
        a.seconds
            .median
            .partial_cmp(&b.seconds.median)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prng::Prng;
    use crate::testing::prop::gen_sparse;

    #[test]
    fn measures_and_sorts_all_host_formats() {
        let mut rng = Prng::new(5);
        let data = gen_sparse::<f64>(&mut rng, 80, 80, 5);
        let exec = Executor::par_with_threads(2);
        let ms = measure_formats(&exec, &data, &FormatChoice::ALL, MeasurePolicy::default());
        assert_eq!(ms.len(), FormatChoice::ALL.len());
        assert!(ms.windows(2).all(|w| w[0].seconds.median <= w[1].seconds.median));
        for m in &ms {
            assert_eq!(m.applies, 1 + 1 + 5);
            assert!(m.seconds.min >= 0.0);
        }
    }

    #[test]
    fn apply_counts_respect_policy() {
        let mut rng = Prng::new(6);
        let data = gen_sparse::<f64>(&mut rng, 30, 30, 3);
        let exec = Executor::reference();
        let policy = MeasurePolicy {
            warmup: 0,
            reps: 2,
            top_k: 1,
        };
        let ms = measure_formats(&exec, &data, &[FormatChoice::Csr], policy);
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].applies, 3); // probe + 2 timed
        assert_eq!(ms[0].format, FormatChoice::Csr);
    }
}
