//! BiCGSTAB [van der Vorst 1992] — short-recurrence solver for general
//! (nonsymmetric) systems; two SpMVs per iteration.

use crate::core::error::Result;
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::kernels::blas;
use crate::matrix::dense::Dense;
use crate::solver::{diverged, workspace as ws, SolveResult, Solver, SolverConfig};
use crate::stop::StopStatus;

/// BiCGSTAB solver.
pub struct BiCgStab {
    config: SolverConfig,
}

impl BiCgStab {
    /// New solver with the given config.
    pub fn new(config: SolverConfig) -> Self {
        Self { config }
    }
}

impl<T: Value> Solver<T> for BiCgStab {
    fn solve(
        &self,
        a: &dyn LinOp<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveResult> {
        a.check_conformant(b, x)?;
        let exec = x.executor().clone();
        let dim = x.shape();
        let crit = self.config.criterion.started();
        let crit = &crit;
        let mut det = self.config.breakdown.detector();

        let mut r = ws::take_copy(b);
        a.apply_advanced(-T::one(), x, T::one(), &mut r)?;
        let rhat = ws::take_copy(&r);
        let mut p = ws::take_zeroed(&exec, dim);
        let mut v = ws::take_zeroed(&exec, dim);
        let mut s = ws::take_zeroed(&exec, dim);
        let mut t = ws::take_zeroed(&exec, dim);
        let mut rho = T::one();
        let mut alpha = T::one();
        let mut omega = T::one();

        let bnorm = blas::norm2(&exec, b)?.as_f64();
        let mut resnorm = blas::norm2(&exec, &r)?.as_f64();
        let mut history = Vec::new();
        if self.config.record_history {
            history.push(resnorm);
        }

        let mut iters = 0;
        loop {
            match crit.check(iters, resnorm, bnorm) {
                StopStatus::Continue => {}
                status => {
                    return Ok(SolveResult {
                        iterations: iters,
                        resnorm,
                        converged: status == StopStatus::Converged,
                        status,
                        history,
                    })
                }
            }
            let rho_new = blas::dot(&exec, &rhat, &r)?;
            // rho -> 0 is the classic Lanczos breakdown: beta and alpha
            // both divide by it next
            if let Some(bd) = det.scalar("rho", rho_new.as_f64()) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
            let beta = (rho_new / rho) * (alpha / omega);
            rho = rho_new;
            // fused: p = r + beta * (p - omega * v), one sweep
            blas::update_p(&exec, &r, beta, omega, &v, &mut p)?;
            // fused SpMV: v = A p and rhat·v in one pass
            let (rv, _) = a.apply_dot(&p, &mut v, &rhat)?;
            if let Some(bd) = det.scalar("rhat·v", rv.as_f64()) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
            alpha = rho / rv;
            // fused: s = r - alpha v
            blas::add_scaled(&exec, &r, -alpha, &v, &mut s)?;
            // fused SpMV: t = A s with s·t and t·t in one pass
            let (ts, tt) = a.apply_dot(&s, &mut t, &s)?;
            omega = if tt.is_zero() { T::zero() } else { ts / tt };
            // omega -> 0 stalls stabilization and divides beta next iter
            if let Some(bd) = det.scalar("omega", omega.as_f64()) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
            // fused: x += alpha p + omega s
            blas::axpy2(&exec, alpha, &p, omega, &s, x)?;
            // fused: r = s - omega t; rr = ||r||²
            let rr = blas::sub_scaled_norm2(&exec, &s, omega, &t, &mut r)?;
            resnorm = rr.sqrt().as_f64();
            iters += 1;
            crate::observe::solver_iteration("bicgstab", iters, resnorm);
            if self.config.record_history {
                history.push(resnorm);
            }
            if let Some(bd) = det.residual(resnorm) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
        }
    }

    fn name(&self) -> &'static str {
        "bicgstab"
    }

    fn flops_per_iter(&self, nnz: usize, n: usize) -> u64 {
        // 2 SpMV + 5 dot-like + 6 axpy-like
        4 * nnz as u64 + (5 * 2 + 6 * 2) * n as u64
    }

    fn bytes_per_iter(&self, nnz: usize, n: usize, elem: usize) -> u64 {
        // Fused: 2 spmv_dot (+1n each) + rhat·r dot (2n) + update_p (4n)
        // + add_scaled (3n) + axpy2 (4n) + sub_scaled_norm2 (3n);
        // was 28n composed.
        (2 * (nnz * (elem + 8) + 2 * n * elem) + (2 + 2 + 4 + 3 + 4 + 3) * n * elem) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::executor::Executor;
    use crate::matrix::Csr;
    use crate::stop::Criterion;
    use crate::testing::prng::Prng;
    use crate::testing::prop::{gen_sparse, gen_vec};
    use crate::Dim2;

    #[test]
    fn converges_on_nonsymmetric_system() {
        let mut rng = Prng::new(21);
        let n = 250;
        let data = gen_sparse::<f64>(&mut rng, n, n, 4); // nonsym, diag-dominant
        let bv = gen_vec::<f64>(&mut rng, n);
        for exec in [Executor::reference(), Executor::par_with_threads(4)] {
            let a = Csr::from_data(exec.clone(), &data).unwrap();
            let b = Dense::vector(exec.clone(), &bv);
            let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            let solver =
                BiCgStab::new(SolverConfig::with_criterion(Criterion::residual(1e-10, 500)));
            let result = solver.solve(&a, &b, &mut x).unwrap();
            assert!(result.converged, "{}: {result:?}", exec.name());
            let mut r = b.clone();
            a.apply_advanced(-1.0, &x, 1.0, &mut r).unwrap();
            assert!(r.norm2_host() < 1e-7 * b.norm2_host());
        }
    }

    #[test]
    fn works_single_precision() {
        let mut rng = Prng::new(23);
        let n = 120;
        let data = gen_sparse::<f32>(&mut rng, n, n, 3);
        let bv = gen_vec::<f32>(&mut rng, n);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let solver =
            BiCgStab::new(SolverConfig::with_criterion(Criterion::residual(1e-5, 300)));
        let result = solver.solve(&a, &b, &mut x).unwrap();
        assert!(result.converged, "{result:?}");
    }
}
