//! FCG — flexible Conjugate Gradient (Ginkgo ships it alongside CG).
//!
//! Uses the Polak–Ribière beta `<r_{k+1} - r_k, z_{k+1}> / <r_k, z_k>`,
//! which keeps convergence when the preconditioner varies per iteration.

use std::sync::Arc;

use crate::core::error::Result;
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::kernels::blas;
use crate::matrix::dense::Dense;
use crate::solver::{diverged, SolveResult, Solver, SolverConfig};
use crate::stop::StopStatus;

/// Flexible CG solver.
pub struct Fcg<T: Value> {
    config: SolverConfig,
    precond: Option<Arc<dyn LinOp<T>>>,
}

impl<T: Value> Fcg<T> {
    /// Unpreconditioned FCG.
    pub fn new(config: SolverConfig) -> Self {
        Self {
            config,
            precond: None,
        }
    }

    /// Attach a (possibly varying) preconditioner.
    pub fn with_preconditioner(mut self, m: Arc<dyn LinOp<T>>) -> Self {
        self.precond = Some(m);
        self
    }
}

impl<T: Value> Solver<T> for Fcg<T> {
    fn solve(
        &self,
        a: &dyn LinOp<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveResult> {
        a.check_conformant(b, x)?;
        let exec = x.executor().clone();
        let dim = x.shape();
        let crit = self.config.criterion.started();
        let crit = &crit;
        let mut det = self.config.breakdown.detector();

        let mut r = b.clone();
        a.apply_advanced(-T::one(), x, T::one(), &mut r)?;
        let mut z = Dense::zeros(exec.clone(), dim);
        match &self.precond {
            Some(m) => m.apply(&r, &mut z)?,
            None => z.copy_from(&r)?,
        }
        let mut p = z.clone();
        let mut q = Dense::zeros(exec.clone(), dim);
        let mut r_old = r.clone();
        let mut rz = blas::dot(&exec, &r, &z)?;

        let bnorm = blas::norm2(&exec, b)?.as_f64();
        let mut resnorm = blas::norm2(&exec, &r)?.as_f64();
        let mut history = Vec::new();
        if self.config.record_history {
            history.push(resnorm);
        }

        let mut iters = 0;
        loop {
            match crit.check(iters, resnorm, bnorm) {
                StopStatus::Continue => {}
                status => {
                    return Ok(SolveResult {
                        iterations: iters,
                        resnorm,
                        converged: status == StopStatus::Converged,
                        status,
                        history,
                    })
                }
            }
            a.apply(&p, &mut q)?;
            let pq = blas::dot(&exec, &p, &q)?;
            if let Some(bd) = det.scalar("p·Ap", pq.as_f64()) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
            let alpha = rz / pq;
            blas::axpy(&exec, alpha, &p, x)?;
            r_old.copy_from(&r)?;
            blas::axpy(&exec, -alpha, &q, &mut r)?;
            match &self.precond {
                Some(m) => m.apply(&r, &mut z)?,
                None => z.copy_from(&r)?,
            }
            // Polak-Ribière: beta = <r - r_old, z> / rz_old
            let rz_new = blas::dot(&exec, &r, &z)?;
            if let Some(bd) = det.scalar("rho", rz_new.as_f64()) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
            let r_old_z = blas::dot(&exec, &r_old, &z)?;
            let beta = (rz_new - r_old_z) / rz;
            rz = rz_new;
            blas::axpby(&exec, T::one(), &z, beta, &mut p)?;
            resnorm = blas::norm2(&exec, &r)?.as_f64();
            iters += 1;
            crate::observe::solver_iteration("fcg", iters, resnorm);
            if self.config.record_history {
                history.push(resnorm);
            }
            if let Some(bd) = det.residual(resnorm) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
        }
    }

    fn name(&self) -> &'static str {
        "fcg"
    }

    fn flops_per_iter(&self, nnz: usize, n: usize) -> u64 {
        // 1 SpMV + 4 dot-like + 4 axpy-like
        2 * nnz as u64 + (4 * 2 + 4 * 2) * n as u64
    }

    fn bytes_per_iter(&self, nnz: usize, n: usize, elem: usize) -> u64 {
        ((nnz * (elem + 8) + 2 * n * elem) + 4 * 3 * n * elem + 4 * 2 * n * elem) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::executor::Executor;
    use crate::matrix::Csr;
    use crate::stop::Criterion;
    use crate::testing::prng::Prng;
    use crate::testing::prop::{gen_sparse, gen_vec};
    use crate::Dim2;

    #[test]
    fn converges_like_cg_on_spd() {
        let mut rng = Prng::new(41);
        let n = 180;
        let mut data = gen_sparse::<f64>(&mut rng, n, n, 3);
        data.symmetrize();
        data.shift_diagonal(1.0);
        let bv = gen_vec::<f64>(&mut rng, n);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let result = Fcg::new(SolverConfig::with_criterion(Criterion::residual(1e-10, 400)))
            .solve(&a, &b, &mut x)
            .unwrap();
        assert!(result.converged, "{result:?}");
        let mut r = b.clone();
        a.apply_advanced(-1.0, &x, 1.0, &mut r).unwrap();
        assert!(r.norm2_host() < 1e-8 * b.norm2_host());
    }
}
