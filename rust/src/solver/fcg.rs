//! FCG — flexible Conjugate Gradient (Ginkgo ships it alongside CG).
//!
//! Uses the Polak–Ribière beta `<r_{k+1} - r_k, z_{k+1}> / <r_k, z_k>`,
//! which keeps convergence when the preconditioner varies per iteration.

use std::sync::Arc;

use crate::core::error::Result;
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::kernels::blas;
use crate::matrix::dense::Dense;
use crate::solver::{diverged, workspace as ws, SolveResult, Solver, SolverConfig};
use crate::stop::StopStatus;

/// Flexible CG solver.
pub struct Fcg<T: Value> {
    config: SolverConfig,
    precond: Option<Arc<dyn LinOp<T>>>,
}

impl<T: Value> Fcg<T> {
    /// Unpreconditioned FCG.
    pub fn new(config: SolverConfig) -> Self {
        Self {
            config,
            precond: None,
        }
    }

    /// Attach a (possibly varying) preconditioner.
    pub fn with_preconditioner(mut self, m: Arc<dyn LinOp<T>>) -> Self {
        self.precond = Some(m);
        self
    }
}

impl<T: Value> Solver<T> for Fcg<T> {
    fn solve(
        &self,
        a: &dyn LinOp<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveResult> {
        a.check_conformant(b, x)?;
        let exec = x.executor().clone();
        let dim = x.shape();
        let crit = self.config.criterion.started();
        let crit = &crit;
        let mut det = self.config.breakdown.detector();

        let mut r = ws::take_copy(b);
        a.apply_advanced(-T::one(), x, T::one(), &mut r)?;
        // z only materialized when preconditioned (else z aliases r)
        let mut z: Option<ws::WsDense<T>> = match &self.precond {
            Some(m) => {
                let mut z = ws::take_zeroed(&exec, dim);
                m.apply(&r, &mut z)?;
                Some(z)
            }
            None => None,
        };
        let mut p = match &z {
            Some(z) => ws::take_copy(z),
            None => ws::take_copy(&r),
        };
        let mut q = ws::take_zeroed(&exec, dim);
        let mut r_old = ws::take_copy(&r);
        // fused sweep: rz = z·r and ||r||² together
        let (mut rz, rr0) = match &z {
            Some(z) => blas::dot_norm2(&exec, z, &r)?,
            None => blas::dot_norm2(&exec, &r, &r)?,
        };

        let bnorm = blas::norm2(&exec, b)?.as_f64();
        let mut resnorm = rr0.sqrt().as_f64();
        let mut history = Vec::new();
        if self.config.record_history {
            history.push(resnorm);
        }

        let mut iters = 0;
        loop {
            match crit.check(iters, resnorm, bnorm) {
                StopStatus::Continue => {}
                status => {
                    return Ok(SolveResult {
                        iterations: iters,
                        resnorm,
                        converged: status == StopStatus::Converged,
                        status,
                        history,
                    })
                }
            }
            // fused SpMV: q = A p and p·q in one pass
            let (pq, _) = a.apply_dot(&p, &mut q, &p)?;
            if let Some(bd) = det.scalar("p·Ap", pq.as_f64()) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
            let alpha = rz / pq;
            r_old.copy_from(&r)?;
            // fused: x += alpha p; r -= alpha q; rr = ||r||²
            let rr = blas::axpy_sub_norm2(&exec, alpha, &p, &q, x, &mut r)?;
            // Polak-Ribière: beta = <r - r_old, z> / rz_old
            let (rz_new, r_old_z) = if let (Some(m), Some(z)) = (&self.precond, &mut z) {
                m.apply(&r, z)?;
                (blas::dot(&exec, &r, &**z)?, blas::dot(&exec, &r_old, &**z)?)
            } else {
                (rr, blas::dot(&exec, &r_old, &r)?)
            };
            if let Some(bd) = det.scalar("rho", rz_new.as_f64()) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
            let beta = (rz_new - r_old_z) / rz;
            rz = rz_new;
            {
                let zref: &Dense<T> = match &z {
                    Some(z) => z,
                    None => &r,
                };
                blas::axpby(&exec, T::one(), zref, beta, &mut p)?;
            }
            resnorm = rr.sqrt().as_f64();
            iters += 1;
            crate::observe::solver_iteration("fcg", iters, resnorm);
            if self.config.record_history {
                history.push(resnorm);
            }
            if let Some(bd) = det.residual(resnorm) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
        }
    }

    fn name(&self) -> &'static str {
        "fcg"
    }

    fn flops_per_iter(&self, nnz: usize, n: usize) -> u64 {
        // 1 SpMV + 4 dot-like + 4 axpy-like
        2 * nnz as u64 + (4 * 2 + 4 * 2) * n as u64
    }

    fn bytes_per_iter(&self, nnz: usize, n: usize, elem: usize) -> u64 {
        // Fused: spmv_dot (+1n) + r_old copy (2n) + axpy_sub_norm2 (6n)
        // + r_old·z dot (2n) + axpby (3n); was 20n composed.
        ((nnz * (elem + 8) + 2 * n * elem) + (1 + 2 + 6 + 2 + 3) * n * elem) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::executor::Executor;
    use crate::matrix::Csr;
    use crate::stop::Criterion;
    use crate::testing::prng::Prng;
    use crate::testing::prop::{gen_sparse, gen_vec};
    use crate::Dim2;

    #[test]
    fn converges_like_cg_on_spd() {
        let mut rng = Prng::new(41);
        let n = 180;
        let mut data = gen_sparse::<f64>(&mut rng, n, n, 3);
        data.symmetrize();
        data.shift_diagonal(1.0);
        let bv = gen_vec::<f64>(&mut rng, n);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let result = Fcg::new(SolverConfig::with_criterion(Criterion::residual(1e-10, 400)))
            .solve(&a, &b, &mut x)
            .unwrap();
        assert!(result.converged, "{result:?}");
        let mut r = b.clone();
        a.apply_advanced(-1.0, &x, 1.0, &mut r).unwrap();
        assert!(r.norm2_host() < 1e-8 * b.norm2_host());
    }
}
