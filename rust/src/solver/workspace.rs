//! Solver workspace: a thread-local arena for the Dense temporaries
//! (r, z, p, q, Krylov basis) every Krylov driver allocates per solve.
//!
//! Buffers are pooled keyed by `(element type, element count)`; a
//! driver *takes* a vector at iteration-zero and the [`WsDense`] guard
//! *returns* the underlying allocation on drop. After the first solve
//! of a given shape warms the pool, repeated `SolverBuilder` solves
//! perform zero Dense allocations in the hot loop — the acceptance
//! criterion tracked by `stats()` (hits, misses) and asserted by the
//! repeated-solve benchmark.
//!
//! The pool is thread-local because operators are not `Send` (see
//! `core::linop`): a solve runs on one thread, so no locking is needed
//! and buffers never migrate.

use std::any::{Any, TypeId};
use std::cell::RefCell;
use std::collections::HashMap;
use std::ops::{Deref, DerefMut};
use std::sync::Arc;

use crate::core::dim::Dim2;
use crate::core::executor::Executor;
use crate::core::types::Value;
use crate::matrix::dense::Dense;

struct Pool {
    buffers: HashMap<(TypeId, usize), Vec<Box<dyn Any>>>,
    hits: u64,
    misses: u64,
}

thread_local! {
    static POOL: RefCell<Pool> = RefCell::new(Pool {
        buffers: HashMap::new(),
        hits: 0,
        misses: 0,
    });
}

fn take_buffer<T: Value>(count: usize) -> Option<Vec<T>> {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        let buf = p
            .buffers
            .get_mut(&(TypeId::of::<T>(), count))
            .and_then(|v| v.pop());
        match buf {
            Some(b) => {
                p.hits += 1;
                Some(*b.downcast::<Vec<T>>().expect("workspace key mismatch"))
            }
            None => {
                p.misses += 1;
                None
            }
        }
    })
}

fn put_buffer<T: Value>(buf: Vec<T>) {
    POOL.with(|p| {
        p.borrow_mut()
            .buffers
            .entry((TypeId::of::<T>(), buf.len()))
            .or_default()
            .push(Box::new(buf));
    });
}

/// A pooled Dense temporary. Derefs to [`Dense`]; the underlying buffer
/// returns to the thread-local pool on drop.
pub struct WsDense<T: Value>(Option<Dense<T>>);

impl<T: Value> Deref for WsDense<T> {
    type Target = Dense<T>;

    fn deref(&self) -> &Dense<T> {
        self.0.as_ref().expect("workspace buffer already returned")
    }
}

impl<T: Value> DerefMut for WsDense<T> {
    fn deref_mut(&mut self) -> &mut Dense<T> {
        self.0.as_mut().expect("workspace buffer already returned")
    }
}

impl<T: Value> Drop for WsDense<T> {
    fn drop(&mut self) {
        if let Some(d) = self.0.take() {
            put_buffer(d.into_vec());
        }
    }
}

/// Take a zero-filled `dim` workspace vector (pool hit avoids the
/// allocation, not the zeroing — drivers rely on a clean buffer).
pub fn take_zeroed<T: Value>(exec: &Arc<Executor>, dim: Dim2) -> WsDense<T> {
    let count = dim.count();
    let values = match take_buffer::<T>(count) {
        Some(mut v) => {
            v.fill(T::zero());
            v
        }
        None => vec![T::zero(); count],
    };
    let dense = Dense::from_vec(exec.clone(), dim, values).expect("pooled buffer matches dim");
    WsDense(Some(dense))
}

/// Take a workspace copy of `src` (same shape and executor).
pub fn take_copy<T: Value>(src: &Dense<T>) -> WsDense<T> {
    let count = src.shape().count();
    let values = match take_buffer::<T>(count) {
        Some(mut v) => {
            v.copy_from_slice(src.as_slice());
            v
        }
        None => src.as_slice().to_vec(),
    };
    let dense = Dense::from_vec(src.executor().clone(), src.shape(), values)
        .expect("pooled buffer matches src shape");
    WsDense(Some(dense))
}

/// (hits, misses) of this thread's pool since the last `reset_stats`.
/// `misses == 0` over a window means every temporary was recycled.
pub fn stats() -> (u64, u64) {
    POOL.with(|p| {
        let p = p.borrow();
        (p.hits, p.misses)
    })
}

/// Zero the hit/miss counters (the pooled buffers stay).
pub fn reset_stats() {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        p.hits = 0;
        p.misses = 0;
    });
}

/// Drop every pooled buffer and zero the counters.
pub fn clear() {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        p.buffers.clear();
        p.hits = 0;
        p.misses = 0;
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_recycles_by_shape_and_type() {
        clear();
        let exec = Executor::reference();
        {
            let a = take_zeroed::<f64>(&exec, Dim2::new(10, 1));
            assert_eq!(a.as_slice(), &[0.0; 10]);
        } // returned
        let (h, m) = stats();
        assert_eq!((h, m), (0, 1));

        {
            let mut b = take_zeroed::<f64>(&exec, Dim2::new(10, 1));
            b.as_mut_slice()[3] = 7.0; // dirty it, must be re-zeroed next take
        }
        let (h, _) = stats();
        assert_eq!(h, 1, "second same-shape take must hit");

        let c = take_zeroed::<f64>(&exec, Dim2::new(10, 1));
        assert_eq!(c.as_slice(), &[0.0; 10], "pool hit must still be zeroed");

        // different length and different type are separate slots
        let _d = take_zeroed::<f64>(&exec, Dim2::new(11, 1));
        let _e = take_zeroed::<f32>(&exec, Dim2::new(10, 1));
        let (_, m) = stats();
        assert_eq!(m, 3);
        clear();
    }

    #[test]
    fn take_copy_matches_source() {
        clear();
        let exec = Executor::reference();
        let src = Dense::vector(exec.clone(), &[1.0f64, -2.0, 3.5]);
        let c = take_copy(&src);
        assert_eq!(c.as_slice(), src.as_slice());
        assert_eq!(c.shape(), src.shape());
        drop(c);
        let c2 = take_copy(&src);
        assert_eq!(c2.as_slice(), src.as_slice());
        let (h, m) = stats();
        assert_eq!((h, m), (1, 1));
        clear();
    }
}
