//! CGS (Conjugate Gradient Squared) [Sonneveld 1989] — short-recurrence
//! transpose-free solver for general systems; two SpMVs per iteration.

use crate::core::error::Result;
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::kernels::blas;
use crate::matrix::dense::Dense;
use crate::solver::{diverged, workspace as ws, SolveResult, Solver, SolverConfig};
use crate::stop::StopStatus;

/// CGS solver.
pub struct Cgs {
    config: SolverConfig,
}

impl Cgs {
    /// New solver with the given config.
    pub fn new(config: SolverConfig) -> Self {
        Self { config }
    }
}

impl<T: Value> Solver<T> for Cgs {
    fn solve(
        &self,
        a: &dyn LinOp<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveResult> {
        a.check_conformant(b, x)?;
        let exec = x.executor().clone();
        let dim = x.shape();
        let crit = self.config.criterion.started();
        let crit = &crit;
        let mut det = self.config.breakdown.detector();

        let mut r = ws::take_copy(b);
        a.apply_advanced(-T::one(), x, T::one(), &mut r)?;
        let rhat = ws::take_copy(&r);
        let mut p = ws::take_zeroed(&exec, dim);
        let mut q = ws::take_zeroed(&exec, dim);
        let mut u = ws::take_zeroed(&exec, dim);
        let mut vhat = ws::take_zeroed(&exec, dim);
        let mut uq = ws::take_zeroed(&exec, dim);
        let mut auq = ws::take_zeroed(&exec, dim);
        let mut rho = T::one();

        let bnorm = blas::norm2(&exec, b)?.as_f64();
        let mut resnorm = blas::norm2(&exec, &r)?.as_f64();
        let mut history = Vec::new();
        if self.config.record_history {
            history.push(resnorm);
        }

        let mut iters = 0;
        loop {
            match crit.check(iters, resnorm, bnorm) {
                StopStatus::Continue => {}
                status => {
                    return Ok(SolveResult {
                        iterations: iters,
                        resnorm,
                        converged: status == StopStatus::Converged,
                        status,
                        history,
                    })
                }
            }
            let rho_new = blas::dot(&exec, &rhat, &r)?;
            // rho -> 0: alpha = rho/sigma degenerates next
            if let Some(bd) = det.scalar("rho", rho_new.as_f64()) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
            let beta = rho_new / rho;
            rho = rho_new;
            // fused: u = r + beta q
            blas::add_scaled(&exec, &r, beta, &q, &mut u)?;
            // fused: p = u + beta (q + beta p), one sweep
            blas::update_p_cgs(&exec, &u, beta, &q, &mut p)?;
            // fused SpMV: vhat = A p and rhat·vhat in one pass
            let (sigma, _) = a.apply_dot(&p, &mut vhat, &rhat)?;
            if let Some(bd) = det.scalar("sigma", sigma.as_f64()) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
            let alpha = rho / sigma;
            // fused: q = u - alpha vhat
            blas::add_scaled(&exec, &u, -alpha, &vhat, &mut q)?;
            // fused: uq = u + q
            blas::add_scaled(&exec, &u, T::one(), &q, &mut uq)?;
            // x += alpha uq ; r -= alpha A uq ; rr = ||r||² (one sweep)
            a.apply(&uq, &mut auq)?;
            let rr = blas::axpy_sub_norm2(&exec, alpha, &uq, &auq, x, &mut r)?;
            resnorm = rr.sqrt().as_f64();
            iters += 1;
            crate::observe::solver_iteration("cgs", iters, resnorm);
            if self.config.record_history {
                history.push(resnorm);
            }
            if let Some(bd) = det.residual(resnorm) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
        }
    }

    fn name(&self) -> &'static str {
        "cgs"
    }

    fn flops_per_iter(&self, nnz: usize, n: usize) -> u64 {
        // 2 SpMV + 3 dot-like + 7 axpy-like
        4 * nnz as u64 + (3 * 2 + 7 * 2) * n as u64
    }

    fn bytes_per_iter(&self, nnz: usize, n: usize, elem: usize) -> u64 {
        // Fused: spmv_dot (+1n) + rhat·r dot (2n) + 3 add_scaled (9n)
        // + update_p_cgs (4n) + axpy_sub_norm2 (6n); was 27n composed.
        (2 * (nnz * (elem + 8) + 2 * n * elem) + (1 + 2 + 9 + 4 + 6) * n * elem) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::executor::Executor;
    use crate::matrix::Csr;
    use crate::stop::Criterion;
    use crate::testing::prng::Prng;
    use crate::testing::prop::{gen_sparse, gen_vec};
    use crate::Dim2;

    #[test]
    fn converges_on_nonsymmetric_system() {
        let mut rng = Prng::new(31);
        let n = 220;
        let data = gen_sparse::<f64>(&mut rng, n, n, 4);
        let bv = gen_vec::<f64>(&mut rng, n);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let solver = Cgs::new(SolverConfig::with_criterion(Criterion::residual(1e-10, 500)));
        let result = solver.solve(&a, &b, &mut x).unwrap();
        assert!(result.converged, "{result:?}");
        let mut r = b.clone();
        a.apply_advanced(-1.0, &x, 1.0, &mut r).unwrap();
        assert!(r.norm2_host() < 1e-7 * b.norm2_host());
    }
}
