//! One entry point for every way to run a solve.
//!
//! Before this builder existed there were three divergent entry
//! points: `Solver::solve` (operator in hand), `Solver::solve_data`
//! (autotuned format selection) and `ResilientSolver::solve`
//! (checkpointed recovery), each configured differently. The builder
//! attaches criterion, preconditioner, breakdown policy, resilience
//! config and an [`observe::Logger`](crate::observe::Logger) in one
//! place and routes to the right driver; the old methods remain as
//! thin wrappers so existing code compiles unchanged.
//!
//! ```ignore
//! let result = SolverBuilder::cg()
//!     .with_criterion(Criterion::residual(1e-10, 500))
//!     .with_logger(record.clone())
//!     .solve(&a, &b, &mut x)?;
//! ```

use std::sync::Arc;

use super::{Cg, Fcg, Richardson, SolveResult, Solver, SolverConfig};
use crate::autotune::AutoMatrix;
use crate::core::error::Result;
use crate::core::executor::Executor;
use crate::core::linop::LinOp;
use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;
use crate::matrix::dense::Dense;
use crate::observe::{self, Logger};
use crate::resilience::{BreakdownPolicy, RecoveryPolicy, ResilientSolver, SolverKind};
use crate::stop::Criterion;

/// Builder-style front door for the solver stack.
pub struct SolverBuilder<T: Value> {
    kind: SolverKind,
    criterion: Criterion,
    record_history: bool,
    breakdown: BreakdownPolicy,
    precond: Option<Arc<dyn LinOp<T>>>,
    resilient: bool,
    chain: Option<Vec<SolverKind>>,
    recovery: Option<RecoveryPolicy>,
    logger: Option<Arc<dyn Logger>>,
}

impl<T: Value> SolverBuilder<T> {
    /// Start from an explicit solver kind.
    pub fn new(kind: SolverKind) -> Self {
        Self {
            kind,
            criterion: Criterion::default(),
            record_history: false,
            breakdown: BreakdownPolicy::default(),
            precond: None,
            resilient: false,
            chain: None,
            recovery: None,
            logger: None,
        }
    }

    /// Conjugate Gradient (SPD systems).
    pub fn cg() -> Self {
        Self::new(SolverKind::Cg)
    }

    /// Flexible CG.
    pub fn fcg() -> Self {
        Self::new(SolverKind::Fcg)
    }

    /// BiCGSTAB (general systems).
    pub fn bicgstab() -> Self {
        Self::new(SolverKind::BiCgStab)
    }

    /// CGS (general systems).
    pub fn cgs() -> Self {
        Self::new(SolverKind::Cgs)
    }

    /// GMRES(m) with the given restart length.
    pub fn gmres(restart: usize) -> Self {
        Self::new(SolverKind::Gmres { restart })
    }

    /// Richardson with relaxation factor omega.
    pub fn richardson(omega: f64) -> Self {
        Self::new(SolverKind::Richardson { omega })
    }

    /// Stopping criterion.
    pub fn with_criterion(mut self, criterion: Criterion) -> Self {
        self.criterion = criterion;
        self
    }

    /// Record the per-iteration residual history.
    pub fn with_history(mut self, record: bool) -> Self {
        self.record_history = record;
        self
    }

    /// Breakdown-detection thresholds for the driver(s).
    pub fn with_breakdown(mut self, breakdown: BreakdownPolicy) -> Self {
        self.breakdown = breakdown;
        self
    }

    /// Attach a preconditioner. Honored by the CG, FCG and Richardson
    /// drivers (the ones whose iteration takes one); ignored by the
    /// others and by the resilient path, which rebuilds plain drivers
    /// per recovery segment.
    pub fn with_preconditioner(mut self, m: Arc<dyn LinOp<T>>) -> Self {
        self.precond = Some(m);
        self
    }

    /// Route through [`ResilientSolver`]: checkpoint/restart recovery
    /// with true-residual verification, starting from this builder's
    /// solver kind and falling back through the default chain.
    pub fn resilient(mut self) -> Self {
        self.resilient = true;
        self
    }

    /// Resilient solve with an explicit fallback chain (implies
    /// [`resilient`](Self::resilient)).
    pub fn with_fallback_chain(mut self, chain: Vec<SolverKind>) -> Self {
        self.resilient = true;
        self.chain = Some(chain);
        self
    }

    /// Resilient solve with an explicit recovery policy (implies
    /// [`resilient`](Self::resilient)).
    pub fn with_recovery(mut self, policy: RecoveryPolicy) -> Self {
        self.resilient = true;
        self.recovery = Some(policy);
        self
    }

    /// Install this logger (globally, scoped to each solve call) so
    /// kernel, iteration, recovery and autotune events from the solve
    /// land in it.
    pub fn with_logger(mut self, logger: Arc<dyn Logger>) -> Self {
        self.logger = Some(logger);
        self
    }

    fn config(&self) -> SolverConfig {
        SolverConfig {
            criterion: self.criterion.clone(),
            record_history: self.record_history,
            breakdown: self.breakdown,
        }
    }

    /// Instantiate the configured driver.
    pub fn build(&self) -> Box<dyn Solver<T>> {
        if self.resilient {
            let mut rs =
                ResilientSolver::new(self.criterion.clone()).with_breakdown(self.breakdown);
            if let Some(policy) = self.recovery {
                rs = rs.with_policy(policy);
            }
            let chain = match &self.chain {
                Some(chain) => chain.clone(),
                None => {
                    // this builder's kind first, then the default
                    // escalation (skipping a duplicate of the head)
                    let mut chain = vec![self.kind];
                    for fallback in [SolverKind::BiCgStab, SolverKind::Gmres { restart: 30 }] {
                        if fallback.name() != self.kind.name() {
                            chain.push(fallback);
                        }
                    }
                    chain
                }
            };
            return Box::new(rs.with_chain(chain));
        }
        let config = self.config();
        match (&self.kind, &self.precond) {
            (SolverKind::Cg, Some(m)) => Box::new(Cg::new(config).with_preconditioner(m.clone())),
            (SolverKind::Fcg, Some(m)) => {
                Box::new(Fcg::new(config).with_preconditioner(m.clone()))
            }
            (SolverKind::Richardson { omega }, Some(m)) => Box::new(
                Richardson::new(config, T::from_f64(*omega)).with_preconditioner(m.clone()),
            ),
            _ => self.kind.build(config),
        }
    }

    /// Solve `A x = b` with the configured driver, logger scoped to
    /// the call.
    pub fn solve(&self, a: &dyn LinOp<T>, b: &Dense<T>, x: &mut Dense<T>) -> Result<SolveResult> {
        let _scope = self.scope();
        self.solve_inner(a, b, x)
    }

    /// Solve directly from assembly data: the autotuner picks the
    /// storage format ([`AutoMatrix`]), and because the logger is
    /// installed before selection runs, its candidate/decision events
    /// are captured too.
    pub fn solve_data(
        &self,
        exec: &Arc<Executor>,
        data: &MatrixData<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveResult> {
        let _scope = self.scope();
        let a = AutoMatrix::from_data(exec.clone(), data)?;
        self.solve_inner(&a, b, x)
    }

    fn scope(&self) -> Option<observe::ScopedLogger> {
        self.logger.clone().map(observe::install_scoped)
    }

    fn solve_inner(
        &self,
        a: &dyn LinOp<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveResult> {
        observe::emit(|| observe::Event::SolverStart {
            solver: self.kind.name().to_string(),
            rows: a.shape().rows,
        });
        let result = self.build().solve(a, b, x);
        match &result {
            Ok(r) => observe::emit(|| observe::Event::SolverDone {
                solver: self.kind.name().to_string(),
                iterations: r.iterations,
                converged: r.converged,
                resnorm: r.resnorm,
            }),
            Err(_) => observe::emit(|| observe::Event::SolverDone {
                solver: self.kind.name().to_string(),
                iterations: 0,
                converged: false,
                resnorm: f64::NAN,
            }),
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::dim::Dim2;
    use crate::matgen::stencil::laplace_2d;

    fn poisson_setup(
        exec: &Arc<Executor>,
    ) -> (crate::matrix::Csr<f64>, Dense<f64>, Dense<f64>) {
        let data = laplace_2d::<f64>(12, 12);
        let n = data.dim.rows;
        let a = crate::matrix::Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
        let x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        (a, b, x)
    }

    #[test]
    fn builder_cg_matches_plain_driver() {
        let exec = Executor::reference();
        let (a, b, mut x) = poisson_setup(&exec);
        let crit = Criterion::residual(1e-10, 500);
        let r = SolverBuilder::cg()
            .with_criterion(crit.clone())
            .solve(&a, &b, &mut x)
            .unwrap();
        assert!(r.converged, "{r:?}");

        let (_, _, mut x2) = poisson_setup(&exec);
        let r2 = Cg::new(SolverConfig::with_criterion(crit))
            .solve(&a, &b, &mut x2)
            .unwrap();
        assert_eq!(r.iterations, r2.iterations);
        assert_eq!(x.as_slice(), x2.as_slice());
    }

    #[test]
    fn builder_resilient_path_converges() {
        let exec = Executor::reference();
        let (a, b, mut x) = poisson_setup(&exec);
        let r = SolverBuilder::cg()
            .with_criterion(Criterion::residual(1e-10, 500))
            .resilient()
            .solve(&a, &b, &mut x)
            .unwrap();
        assert!(r.converged, "{r:?}");
    }

    #[test]
    fn builder_solve_data_uses_autotuner() {
        let exec = Executor::reference();
        let data = laplace_2d::<f64>(10, 10);
        let n = data.dim.rows;
        let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let r = SolverBuilder::cg()
            .with_criterion(Criterion::residual(1e-10, 500))
            .solve_data(&exec, &data, &b, &mut x)
            .unwrap();
        assert!(r.converged, "{r:?}");
    }
}
