//! GMRES(m) with restarts [Saad & Schultz 1986].
//!
//! Long recurrence: each new Krylov direction is orthogonalized against
//! the whole basis (modified Gram-Schmidt), the small Hessenberg least-
//! squares problem is solved with Givens rotations on the host. The
//! paper (§6.4) observes GMRES maps worst onto the ported backend
//! because that growing-basis orthogonalization is a chain of
//! memory-bound BLAS-1 sweeps. On the host backends the chain now runs
//! through the batched fused kernels: `blas::mgs_project` pipelines the
//! projection with the previous subtraction (one sweep of `w` per basis
//! vector instead of two, the norm reduction riding the last stage) and
//! `blas::mgs_update` folds the Krylov correction with a single sweep of
//! `x`. Both are bit-identical to the composed `dot`/`axpy` sequence and
//! toggled by `kernels::set_fused_enabled` for the ablation baseline; on
//! the xla executor the composed fallback is used.

use crate::core::error::Result;
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::kernels::blas;
use crate::matrix::dense::Dense;
use crate::solver::{diverged, workspace as ws, SolveResult, Solver, SolverConfig};
use crate::stop::{Breakdown, StopStatus};

/// GMRES solver with restart length `m`.
pub struct Gmres {
    config: SolverConfig,
    restart: usize,
}

impl Gmres {
    /// GMRES with the default restart length 30.
    pub fn new(config: SolverConfig) -> Self {
        Self {
            config,
            restart: 30,
        }
    }

    /// Explicit restart length.
    pub fn with_restart(mut self, m: usize) -> Self {
        assert!(m > 0, "restart must be positive");
        self.restart = m;
        self
    }
}

impl<T: Value> Solver<T> for Gmres {
    fn solve(
        &self,
        a: &dyn LinOp<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveResult> {
        a.check_conformant(b, x)?;
        let exec = x.executor().clone();
        let dim = x.shape();
        let m = self.restart;
        let crit = self.config.criterion.started();
        let crit = &crit;
        let mut det = self.config.breakdown.detector();

        let bnorm = blas::norm2(&exec, b)?.as_f64();
        let mut history = Vec::new();
        let mut total_iters = 0usize;
        let mut resnorm;

        // Krylov basis kept as individual pooled vectors (host memory);
        // clearing it per restart returns every buffer to the workspace.
        let mut basis: Vec<ws::WsDense<T>> = Vec::with_capacity(m + 1);
        // Hessenberg in column-major: h[j] has j+2 entries.
        let mut w = ws::take_zeroed(&exec, dim);

        loop {
            // r = b - A x
            let mut r = ws::take_copy(b);
            a.apply_advanced(-T::one(), x, T::one(), &mut r)?;
            resnorm = blas::norm2(&exec, &r)?.as_f64();
            if self.config.record_history && history.is_empty() {
                history.push(resnorm);
            }
            match crit.check(total_iters, resnorm, bnorm) {
                StopStatus::Continue => {}
                status => {
                    return Ok(SolveResult {
                        iterations: total_iters,
                        resnorm,
                        converged: status == StopStatus::Converged,
                        status,
                        history,
                    })
                }
            }

            let beta = T::from_f64(resnorm);
            basis.clear();
            // fused: v0 = r / beta without a copy-then-scale pass
            let mut v0 = ws::take_zeroed(&exec, dim);
            blas::scal_into(&exec, T::one() / beta, &r, &mut v0)?;
            basis.push(v0);

            // Givens rotation state + rhs of the LSQ problem
            let mut cs = vec![T::zero(); m];
            let mut sn = vec![T::zero(); m];
            let mut g = vec![T::zero(); m + 1];
            g[0] = beta;
            let mut h_cols: Vec<Vec<T>> = Vec::with_capacity(m);
            let mut inner = 0usize;

            for j in 0..m {
                // w = A v_j
                a.apply(&basis[j], &mut w)?;
                // modified Gram-Schmidt against the whole basis: one
                // batched sweep yields the projection coefficients and
                // ‖w‖² of the remainder
                let mut h = vec![T::zero(); j + 2];
                let ww = {
                    let vrefs: Vec<&Dense<T>> = basis.iter().map(|v| &**v).collect();
                    blas::mgs_project(&exec, &vrefs, &mut w, &mut h[..j + 1])?
                };
                let wnorm = ww.sqrt();
                h[j + 1] = wnorm;

                // apply accumulated Givens rotations to the new column
                for i in 0..j {
                    let tmp = cs[i] * h[i] + sn[i] * h[i + 1];
                    h[i + 1] = -sn[i] * h[i] + cs[i] * h[i + 1];
                    h[i] = tmp;
                }
                // new rotation to zero h[j+1]
                let denom = (h[j] * h[j] + h[j + 1] * h[j + 1]).sqrt();
                if denom.is_zero() {
                    cs[j] = T::one();
                    sn[j] = T::zero();
                } else {
                    cs[j] = h[j] / denom;
                    sn[j] = h[j + 1] / denom;
                }
                h[j] = cs[j] * h[j] + sn[j] * h[j + 1];
                h[j + 1] = T::zero();
                g[j + 1] = -sn[j] * g[j];
                g[j] = cs[j] * g[j];
                h_cols.push(h);

                inner = j + 1;
                total_iters += 1;
                resnorm = g[j + 1].as_f64().abs();
                crate::observe::solver_iteration("gmres", total_iters, resnorm);
                if self.config.record_history {
                    history.push(resnorm);
                }
                let status = crit.check(total_iters, resnorm, bnorm);
                if let StopStatus::Diverged(bd) = status {
                    // the Hessenberg column is poisoned; folding the
                    // correction into x would corrupt the iterate —
                    // return with x untouched so a checkpoint restart
                    // can resume from it
                    return Ok(diverged(total_iters, resnorm, history, bd));
                }
                if let Some(bd) = det.residual(resnorm) {
                    // stagnation: the iterate is finite, so fold the
                    // best correction so far before reporting (unless
                    // the triangular solve itself breaks down — then x
                    // stays untouched and that breakdown wins)
                    let bd = update_solution(&exec, x, &basis, &h_cols, &g, inner)?.unwrap_or(bd);
                    return Ok(diverged(total_iters, resnorm, history, bd));
                }
                if status != StopStatus::Continue || wnorm.is_zero() {
                    // solve the j+1 upper-triangular system, update x
                    if let Some(bd) = update_solution(&exec, x, &basis, &h_cols, &g, inner)? {
                        return Ok(diverged(total_iters, resnorm, history, bd));
                    }
                    // happy breakdown (wnorm == 0) only means the Krylov
                    // space cannot grow — convergence is whatever
                    // `crit.check` actually reported, never implied
                    let status = if status == StopStatus::Continue {
                        StopStatus::Diverged(Breakdown::ZeroDenominator { what: "wnorm" })
                    } else {
                        status
                    };
                    return Ok(SolveResult {
                        iterations: total_iters,
                        resnorm,
                        converged: status == StopStatus::Converged,
                        status,
                        history,
                    });
                }
                // next basis vector: vnext = w / wnorm, one fused sweep
                let mut vnext = ws::take_zeroed(&exec, dim);
                blas::scal_into(&exec, T::one() / wnorm, &w, &mut vnext)?;
                basis.push(vnext);
            }
            // restart: fold the Krylov correction into x and re-enter
            // the outer loop (its head recomputes the true residual and
            // re-checks the criterion, including the iteration budget)
            if let Some(bd) = update_solution(&exec, x, &basis, &h_cols, &g, inner)? {
                return Ok(diverged(total_iters, resnorm, history, bd));
            }
        }
    }

    fn name(&self) -> &'static str {
        "gmres"
    }

    fn flops_per_iter(&self, nnz: usize, n: usize) -> u64 {
        // 1 SpMV + the batched MGS sweep at the average basis size
        // (restart/2 + 1): 4 flops per element and basis vector
        // (projection dot + subtraction), plus the trailing ‖w‖² and
        // the basis normalization (see perfmodel::traffic::mgs_*)
        let avg_basis = (self.restart / 2 + 1) as u64;
        2 * nnz as u64 + (4 * avg_basis + 2) * n as u64 + n as u64
    }

    fn bytes_per_iter(&self, nnz: usize, n: usize, elem: usize) -> u64 {
        // SpMV footprint + the fused MGS traffic: one pipelined 4-stream
        // sweep of w per basis vector (the composed chain pays 5) plus
        // the finishing and normalization passes
        let avg_basis = (self.restart / 2 + 1) as u64;
        ((nnz * (elem + 8) + 2 * n * elem) as u64)
            + (4 * avg_basis + 1) * (n * elem) as u64
            + (2 * n * elem) as u64
    }
}

/// `x += V_k y` where `R y = g` is the Givens-reduced triangular system.
///
/// The back substitution is guarded: a zero or non-finite diagonal
/// `R[i][i]` (degenerate Hessenberg column, e.g. after a breakdown with
/// a spurious zero residual) would fold Inf/NaN into `x`. In that case
/// the structured breakdown is returned and `x` stays untouched — the
/// whole correction is computed before any of it is applied.
fn update_solution<T: Value>(
    exec: &std::sync::Arc<crate::core::executor::Executor>,
    x: &mut Dense<T>,
    basis: &[ws::WsDense<T>],
    h_cols: &[Vec<T>],
    g: &[T],
    k: usize,
) -> Result<Option<Breakdown>> {
    // back substitution on the k x k triangular system (host, tiny)
    let mut y = vec![T::zero(); k];
    for i in (0..k).rev() {
        let diag = h_cols[i][i];
        if diag.is_zero() {
            return Ok(Some(Breakdown::ZeroDenominator {
                what: "hessenberg diagonal",
            }));
        }
        if !diag.as_f64().is_finite() {
            return Ok(Some(Breakdown::NanOperand {
                what: "hessenberg diagonal",
            }));
        }
        let mut acc = g[i];
        for j in i + 1..k {
            acc -= h_cols[j][i] * y[j];
        }
        y[i] = acc / diag;
        if !y[i].as_f64().is_finite() {
            return Ok(Some(Breakdown::NanOperand {
                what: "triangular solve",
            }));
        }
    }
    // fold the correction with one batched sweep of x (bit-identical to
    // the per-column axpy sequence)
    let vrefs: Vec<&Dense<T>> = basis[..k].iter().map(|v| &**v).collect();
    blas::mgs_update(exec, &vrefs, &y, x)?;
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::executor::Executor;
    use crate::core::matrix_data::MatrixData;
    use crate::matrix::Csr;
    use crate::stop::Criterion;
    use crate::testing::prng::Prng;
    use crate::testing::prop::{gen_sparse, gen_vec};
    use crate::Dim2;

    #[test]
    fn converges_without_restart() {
        let mut rng = Prng::new(51);
        let n = 150;
        let data = gen_sparse::<f64>(&mut rng, n, n, 4);
        let bv = gen_vec::<f64>(&mut rng, n);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let result = Gmres::new(SolverConfig::with_criterion(Criterion::residual(1e-10, 200)))
            .with_restart(200)
            .solve(&a, &b, &mut x)
            .unwrap();
        assert!(result.converged, "{result:?}");
        let mut r = b.clone();
        a.apply_advanced(-1.0, &x, 1.0, &mut r).unwrap();
        assert!(r.norm2_host() < 1e-7 * b.norm2_host());
    }

    #[test]
    fn converges_with_short_restart() {
        let mut rng = Prng::new(53);
        let n = 150;
        let data = gen_sparse::<f64>(&mut rng, n, n, 4);
        let bv = gen_vec::<f64>(&mut rng, n);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let result = Gmres::new(SolverConfig::with_criterion(Criterion::residual(1e-8, 2000)))
            .with_restart(10)
            .solve(&a, &b, &mut x)
            .unwrap();
        assert!(result.converged, "{result:?}");
        let mut r = b.clone();
        a.apply_advanced(-1.0, &x, 1.0, &mut r).unwrap();
        assert!(r.norm2_host() < 1e-6 * b.norm2_host());
    }

    #[test]
    fn happy_breakdown_above_tolerance_is_not_converged() {
        // identity system, b = 2·e_0: the Krylov space is exhausted at
        // j = 0 (wnorm == 0, exactly — every arithmetic step is a power
        // of two), but an iteration-only criterion can never report
        // Converged. The old driver still claimed `converged: true`.
        let exec = Executor::reference();
        let n = 4;
        let mut data = MatrixData::<f64>::new(Dim2::square(n));
        for i in 0..n {
            data.push(i as i32, i as i32, 1.0);
        }
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let mut bv = vec![0.0f64; n];
        bv[0] = 2.0;
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let result = Gmres::new(SolverConfig::with_criterion(Criterion::iterations(10)))
            .solve(&a, &b, &mut x)
            .unwrap();
        assert!(!result.converged, "{result:?}");
        assert_eq!(
            result.status,
            StopStatus::Diverged(Breakdown::ZeroDenominator { what: "wnorm" })
        );
        // the best correction was still folded: x solves the system
        assert_eq!(x.as_slice(), b.as_slice());
    }

    #[test]
    fn zero_hessenberg_diagonal_reports_breakdown_not_convergence() {
        // A = 0: w = A v_0 = 0 leaves a degenerate Givens column whose
        // rotation reports a spurious zero residual (so a relative
        // criterion says Converged) while the Hessenberg diagonal is 0.
        // The old back substitution divided by it and returned
        // `converged: true` with x poisoned by Inf/NaN.
        let exec = Executor::reference();
        let n = 4;
        let mut data = MatrixData::<f64>::new(Dim2::square(n));
        for i in 0..n {
            data.push(i as i32, i as i32, 0.0);
        }
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let mut bv = vec![0.0f64; n];
        bv[0] = 2.0;
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let result = Gmres::new(SolverConfig::with_criterion(Criterion::residual(1e-10, 50)))
            .solve(&a, &b, &mut x)
            .unwrap();
        assert!(!result.converged, "{result:?}");
        assert_eq!(
            result.status,
            StopStatus::Diverged(Breakdown::ZeroDenominator {
                what: "hessenberg diagonal"
            })
        );
        // x must stay untouched — no Inf/NaN folded in
        assert!(x.as_slice().iter().all(|v| *v == 0.0));
    }

    #[test]
    fn budget_exhaustion_reports_not_converged() {
        let mut rng = Prng::new(57);
        let n = 100;
        let data = gen_sparse::<f64>(&mut rng, n, n, 4);
        let bv = gen_vec::<f64>(&mut rng, n);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let result = Gmres::new(SolverConfig::with_criterion(Criterion::residual(1e-30, 5)))
            .solve(&a, &b, &mut x)
            .unwrap();
        assert!(!result.converged);
        assert_eq!(result.iterations, 5);
    }
}
