//! Mixed-precision iterative refinement (IR).
//!
//! §2 of the paper lists "cutting-edge mixed precision methods" among
//! Ginkgo's features [Flegar et al. 2021]; this is the canonical one:
//! the residual equation `A d = r` is solved by an inner solver in
//! *single* precision (fast on GEN12-class hardware where fp32 is 275×
//! the emulated fp64 rate — Fig. 7), while the outer residual and
//! solution updates stay in double precision, recovering full accuracy.

use crate::core::dim::Dim2;
use crate::core::error::Result;
use crate::core::linop::LinOp;
use crate::kernels::blas;
use crate::matrix::csr::Csr;
use crate::matrix::dense::Dense;
use crate::solver::{Cg, SolveResult, Solver, SolverConfig};
use crate::stop::{Criterion, StopStatus};

/// Mixed-precision iterative refinement: f64 outer loop around an f32
/// inner CG solve of the residual equation.
pub struct MixedIr {
    config: SolverConfig,
    /// Relative tolerance of each inner (f32) solve.
    inner_tol: f64,
    /// Iteration budget of each inner solve.
    inner_iters: usize,
}

impl MixedIr {
    /// IR with the given outer criterion; inner solves run at 1e-4
    /// relative tolerance (≈ single-precision limit) by default.
    pub fn new(config: SolverConfig) -> Self {
        Self {
            config,
            inner_tol: 1e-4,
            inner_iters: 200,
        }
    }

    /// Tune the inner solve.
    pub fn with_inner(mut self, tol: f64, iters: usize) -> Self {
        self.inner_tol = tol;
        self.inner_iters = iters;
        self
    }

    /// Solve `A x = b` (A in f64 CSR; SPD assumed for the inner CG).
    ///
    /// Not a `Solver<f64>` impl: IR needs the concrete matrix to build
    /// its single-precision copy, not just a `LinOp`.
    pub fn solve(
        &self,
        a: &Csr<f64>,
        b: &Dense<f64>,
        x: &mut Dense<f64>,
    ) -> Result<SolveResult> {
        a.check_conformant(b, x)?;
        let exec = x.executor().clone();
        let n = x.shape().rows;
        let crit = self.config.criterion.started();
        let crit = &crit;

        // one-time f32 copy of the operator (the "generate" phase)
        let a32 = Csr::<f32>::from_data(exec.clone(), &a.to_data().convert::<f32>())?;
        let inner = Cg::new(SolverConfig::with_criterion(Criterion::residual(
            self.inner_tol,
            self.inner_iters,
        )));

        let bnorm = blas::norm2(&exec, b)?;
        let mut r = b.clone();
        a.apply_advanced(-1.0, x, 1.0, &mut r)?;
        let mut resnorm = blas::norm2(&exec, &r)?;
        let mut history = Vec::new();
        if self.config.record_history {
            history.push(resnorm);
        }

        let mut outer = 0usize;
        loop {
            match crit.check(outer, resnorm, bnorm) {
                StopStatus::Continue => {}
                status => {
                    return Ok(SolveResult {
                        iterations: outer,
                        resnorm,
                        converged: status == StopStatus::Converged,
                        status,
                        history,
                    })
                }
            }
            // inner: solve A d = r in f32
            let r32: Dense<f32> = r.convert();
            let mut d32 = Dense::<f32>::zeros(exec.clone(), Dim2::new(n, 1));
            inner.solve(&a32, &r32, &mut d32)?;
            // outer: x += d ; r = b - A x (recomputed in f64)
            let d: Dense<f64> = d32.convert();
            blas::axpy(&exec, 1.0, &d, x)?;
            r.copy_from(b)?;
            a.apply_advanced(-1.0, x, 1.0, &mut r)?;
            resnorm = blas::norm2(&exec, &r)?;
            outer += 1;
            if self.config.record_history {
                history.push(resnorm);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::executor::Executor;
    use crate::testing::prng::Prng;
    use crate::testing::prop::{gen_sparse, gen_vec};

    fn spd_system(seed: u64, n: usize) -> (crate::MatrixData<f64>, Vec<f64>) {
        let mut rng = Prng::new(seed);
        let mut data = gen_sparse::<f64>(&mut rng, n, n, 3);
        data.symmetrize();
        data.shift_diagonal(1.0);
        let b = gen_vec::<f64>(&mut rng, n);
        (data, b)
    }

    #[test]
    fn reaches_double_precision_accuracy() {
        let (data, bv) = spd_system(88, 250);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(250, 1));
        let r = MixedIr::new(SolverConfig::with_criterion(Criterion::residual(1e-12, 50)))
            .solve(&a, &b, &mut x)
            .unwrap();
        // f32 alone bottoms out around 1e-6 relative; IR must go beyond
        assert!(r.converged, "{r:?}");
        let mut resid = b.clone();
        a.apply_advanced(-1.0, &x, 1.0, &mut resid).unwrap();
        assert!(
            resid.norm2_host() < 1e-10 * b.norm2_host(),
            "true residual {} not at double accuracy",
            resid.norm2_host() / b.norm2_host()
        );
    }

    #[test]
    fn outer_iterations_are_few() {
        // each outer step gains ~the inner tolerance factor: reaching
        // 1e-12 from 1e0 at 1e-4/step needs ~3-5 outer iterations
        let (data, bv) = spd_system(89, 200);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(200, 1));
        let r = MixedIr::new(SolverConfig::with_criterion(Criterion::residual(1e-12, 50)))
            .solve(&a, &b, &mut x)
            .unwrap();
        assert!(r.converged);
        assert!(r.iterations <= 8, "took {} outer iterations", r.iterations);
    }

    #[test]
    fn history_tracks_outer_residuals() {
        let (data, bv) = spd_system(90, 150);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(150, 1));
        let mut cfg = SolverConfig::with_criterion(Criterion::residual(1e-11, 30));
        cfg.record_history = true;
        let r = MixedIr::new(cfg).solve(&a, &b, &mut x).unwrap();
        assert_eq!(r.history.len(), r.iterations + 1);
        // strictly decreasing by large factors (mixed-precision gain)
        for w in r.history.windows(2) {
            assert!(w[1] < w[0] * 0.5, "weak refinement step: {w:?}");
        }
    }
}
