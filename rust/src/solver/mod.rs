//! Iterative Krylov solvers (the paper's §5 / §6.4 workload).
//!
//! All four solvers of the paper's evaluation — CG, BiCGSTAB, CGS,
//! GMRES — plus FCG and Richardson from Ginkgo's wider solver set. Every
//! solver is generic over precision and executor and applies any
//! [`LinOp`] operator, so the same driver runs on `reference`, `par` and
//! the ported `xla` backend.
//!
//! `fused` contains the XLA-only fused-iteration drivers that dispatch
//! one `*_step` artifact per iteration (L2 graphs from
//! `python/compile/model.py`) — the ablation benches compare them with
//! the composed drivers here.

mod bicgstab;
mod builder;
mod cg;
mod cgs;
mod fcg;
pub mod fused;
mod gmres;
mod ir;
mod richardson;
pub mod workspace;

pub use bicgstab::BiCgStab;
pub use builder::SolverBuilder;
pub use cg::Cg;
pub use cgs::Cgs;
pub use fcg::Fcg;
pub use gmres::Gmres;
pub use ir::MixedIr;
pub use richardson::Richardson;

use crate::core::error::Result;
use crate::core::types::Value;
use crate::matrix::dense::Dense;
use crate::resilience::BreakdownPolicy;
use crate::stop::{Breakdown, Criterion, StopStatus};

/// Outcome of a solve.
#[derive(Debug, Clone)]
pub struct SolveResult {
    /// Completed iterations.
    pub iterations: usize,
    /// Final (recurrence) residual norm.
    pub resnorm: f64,
    /// Whether the stopping criterion was met by residual.
    pub converged: bool,
    /// Why the solver stopped — [`StopStatus::Diverged`] carries the
    /// structured breakdown reason.
    pub status: StopStatus,
    /// Per-iteration residual norms (only if `record_history`).
    pub history: Vec<f64>,
}

impl SolveResult {
    /// The breakdown reason, if the solve diverged.
    pub fn breakdown(&self) -> Option<Breakdown> {
        match self.status {
            StopStatus::Diverged(bd) => Some(bd),
            _ => None,
        }
    }
}

/// Construct the result for a detected breakdown (drivers return this
/// the moment their iteration becomes unsalvageable).
pub(crate) fn diverged(
    iterations: usize,
    resnorm: f64,
    history: Vec<f64>,
    breakdown: Breakdown,
) -> SolveResult {
    SolveResult {
        iterations,
        resnorm,
        converged: false,
        status: StopStatus::Diverged(breakdown),
        history,
    }
}

/// Configuration shared by all solvers.
#[derive(Debug, Clone)]
pub struct SolverConfig {
    /// Stopping criterion.
    pub criterion: Criterion,
    /// Record the residual-norm history (costs one Vec push per iter).
    pub record_history: bool,
    /// Breakdown-detection thresholds (NaN/Inf residuals are always
    /// reported regardless of this policy).
    pub breakdown: BreakdownPolicy,
}

impl Default for SolverConfig {
    fn default() -> Self {
        Self {
            criterion: Criterion::default(),
            record_history: false,
            breakdown: BreakdownPolicy::default(),
        }
    }
}

impl SolverConfig {
    /// Config with the given criterion.
    pub fn with_criterion(criterion: Criterion) -> Self {
        Self {
            criterion,
            ..Self::default()
        }
    }
}

/// Common interface implemented by every solver.
pub trait Solver<T: Value> {
    /// Solve `A x = b`, starting from the initial guess in `x`.
    fn solve(
        &self,
        a: &dyn crate::core::linop::LinOp<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveResult>;

    /// Solve directly from assembly data, letting the autotuner pick
    /// the storage format ([`crate::autotune::AutoMatrix`]). The
    /// operator is built, tuned and dropped within the call — use
    /// [`AutoMatrix`](crate::autotune::AutoMatrix) directly to reuse it
    /// across solves.
    fn solve_data(
        &self,
        exec: &std::sync::Arc<crate::core::executor::Executor>,
        data: &crate::core::matrix_data::MatrixData<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveResult>
    where
        Self: Sized,
    {
        let a = crate::autotune::AutoMatrix::from_data(exec.clone(), data)?;
        self.solve(&a, b, x)
    }

    /// Solver name for logs and benches.
    fn name(&self) -> &'static str;

    /// FLOPs per iteration given matrix nnz and size n (used by the
    /// perf model; counts SpMV + BLAS-1 work of one iteration).
    fn flops_per_iter(&self, nnz: usize, n: usize) -> u64;

    /// Bytes moved per iteration for a given value size (perf model).
    fn bytes_per_iter(&self, nnz: usize, n: usize, elem: usize) -> u64;
}
