//! Fused-iteration solver drivers for the XLA executor.
//!
//! Where the composed drivers in this module's siblings issue ~10 PJRT
//! dispatches per iteration (one per BLAS-1/SpMV call), these drivers run
//! one `*_step` artifact per iteration: the whole iteration body was
//! fused at L2 (`python/compile/model.py`) and lowered AOT. This is the
//! L2 optimization the perf pass measures (`ablation_fused_step` bench):
//! dispatch overhead amortizes from ~10 crossings to 1 per iteration.
//!
//! The matrix must fit one ELL bucket (no width-chunking inside a fused
//! step); `FusedCg::supported` reports whether the fused path applies.

use crate::core::error::{Result, SparkleError};
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::matrix::dense::Dense;
use crate::matrix::ell::Ell;
use crate::runtime::bucket::pad_to;
use crate::runtime::XlaRuntime;
use crate::solver::{SolveResult, SolverConfig};
use crate::stop::StopStatus;

/// CG driver running one fused `cg_step` artifact per iteration.
pub struct FusedCg {
    config: SolverConfig,
}

impl FusedCg {
    /// New fused CG with the given config.
    pub fn new(config: SolverConfig) -> Self {
        Self { config }
    }

    /// Whether the fused path covers this operator on this runtime.
    pub fn supported<T: Value>(rt: &XlaRuntime, a: &Ell<T>) -> bool {
        rt.select(
            "cg_step",
            T::PRECISION,
            a.shape().rows.max(a.shape().cols),
            a.stored_per_row().max(1),
            0,
        )
        .is_ok()
    }

    /// Solve `A x = b` on the XLA executor.
    pub fn solve<T: Value>(
        &self,
        a: &Ell<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveResult> {
        let exec = a.executor().clone();
        let rt = exec.xla_runtime().ok_or(SparkleError::NotSupported {
            op: "fused cg",
            exec: "non-xla",
        })?;
        let n = a.shape().rows;
        let k = a.stored_per_row().max(1);
        let crit = self.config.criterion.started();
        let crit = &crit;
        let meta = rt.select("cg_step", T::PRECISION, n.max(a.shape().cols), k, 0)?;
        let (bn, bk) = (meta.n, meta.k);
        let name = meta.name.clone();

        // pad ELL storage into the bucket once and push it to the device
        // once (§Perf L3 iteration 4: matrix operands are loop-invariant)
        let mut vals = vec![T::zero(); bk * bn];
        let mut cols = vec![0i32; bk * bn];
        for j in 0..k {
            vals[j * bn..j * bn + n].copy_from_slice(&a.values()[j * n..(j + 1) * n]);
            cols[j * bn..j * bn + n].copy_from_slice(&a.col_idxs()[j * n..(j + 1) * n]);
        }
        let vals_b = rt.to_device(&vals, &[bk, bn])?;
        let cols_b = rt.to_device(&cols, &[bk, bn])?;

        // r = b - A x (host-side init via the composed path)
        let mut r = b.clone();
        a.apply_advanced(-T::one(), x, T::one(), &mut r)?;
        let mut xv = pad_to(x.as_slice(), bn, T::zero());
        let mut rv = pad_to(r.as_slice(), bn, T::zero());
        let mut pv = rv.clone();
        let mut rr = crate::kernels::reference::dot(&rv, &rv);

        let bnorm = b.norm2_host();
        let mut resnorm = rr.as_f64().sqrt();
        let mut history = Vec::new();
        if self.config.record_history {
            history.push(resnorm);
        }

        let mut iters = 0;
        loop {
            match crit.check(iters, resnorm, bnorm) {
                StopStatus::Continue => {}
                status => {
                    x.as_mut_slice().copy_from_slice(&xv[..n]);
                    return Ok(SolveResult {
                        iterations: iters,
                        resnorm,
                        converged: status == StopStatus::Converged,
                        status,
                        history,
                    });
                }
            }
            let x_b = rt.to_device(&xv, &[bn])?;
            let r_b = rt.to_device(&rv, &[bn])?;
            let p_b = rt.to_device(&pv, &[bn])?;
            let rr_b = rt.to_device(&[rr], &[])?;
            let out =
                rt.run_buffers::<T>(&name, &[&vals_b, &cols_b, &x_b, &r_b, &p_b, &rr_b])?;
            xv.copy_from_slice(&out[0]);
            rv.copy_from_slice(&out[1]);
            pv.copy_from_slice(&out[2]);
            rr = out[3][0];
            resnorm = rr.as_f64().sqrt();
            iters += 1;
            if self.config.record_history {
                history.push(resnorm);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::executor::Executor;
    use crate::matrix::Ell;
    use crate::stop::Criterion;
    use crate::testing::prng::Prng;
    use crate::testing::prop::{gen_sparse, gen_vec};
    use crate::Dim2;

    #[test]
    fn fused_cg_matches_composed_cg() {
        if !std::path::Path::new("artifacts/manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let exec = Executor::xla("artifacts").unwrap();
        let mut rng = Prng::new(71);
        let n = 300;
        let mut data = gen_sparse::<f64>(&mut rng, n, n, 3);
        data.symmetrize();
        data.shift_diagonal(1.0);
        let bv = gen_vec::<f64>(&mut rng, n);

        let ell = Ell::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let rt = exec.xla_runtime().unwrap();
        assert!(FusedCg::supported(rt, &ell));

        let crit = Criterion::residual(1e-10, 400);
        let mut x_fused = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let fused = FusedCg::new(SolverConfig::with_criterion(crit.clone()))
            .solve(&ell, &b, &mut x_fused)
            .unwrap();
        assert!(fused.converged, "{fused:?}");

        // composed on reference executor
        let reference = Executor::reference();
        let csr = crate::Csr::from_data(reference.clone(), &data).unwrap();
        let br = Dense::vector(reference.clone(), &bv);
        let mut x_ref = Dense::zeros(reference.clone(), Dim2::new(n, 1));
        use crate::solver::{Cg, Solver};
        let composed = Cg::new(SolverConfig::with_criterion(crit))
            .solve(&csr, &br, &mut x_ref)
            .unwrap();
        assert!(composed.converged);
        crate::testing::prop::assert_close(
            x_fused.as_slice(),
            x_ref.as_slice(),
            1e-6,
            "fused vs composed solution",
        );
    }
}
