//! Richardson iteration (optionally preconditioned): the simplest
//! stationary solver, `x += omega * M (b - A x)`. In Ginkgo this is the
//! building block for smoothers; included for solver-set completeness.

use std::sync::Arc;

use crate::core::error::Result;
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::kernels::blas;
use crate::matrix::dense::Dense;
use crate::solver::{diverged, workspace as ws, SolveResult, Solver, SolverConfig};
use crate::stop::StopStatus;

/// Richardson solver with relaxation factor `omega`.
pub struct Richardson<T: Value> {
    config: SolverConfig,
    omega: T,
    precond: Option<Arc<dyn LinOp<T>>>,
}

impl<T: Value> Richardson<T> {
    /// Richardson with relaxation factor.
    pub fn new(config: SolverConfig, omega: T) -> Self {
        Self {
            config,
            omega,
            precond: None,
        }
    }

    /// Attach a preconditioner (e.g. Jacobi — giving damped Jacobi).
    pub fn with_preconditioner(mut self, m: Arc<dyn LinOp<T>>) -> Self {
        self.precond = Some(m);
        self
    }
}

impl<T: Value> Solver<T> for Richardson<T> {
    fn solve(
        &self,
        a: &dyn LinOp<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveResult> {
        a.check_conformant(b, x)?;
        let exec = x.executor().clone();
        let dim = x.shape();
        let crit = self.config.criterion.started();
        let crit = &crit;
        let mut det = self.config.breakdown.detector();

        let mut r = ws::take_zeroed(&exec, dim);
        // z only materialized when preconditioned
        let mut z: Option<ws::WsDense<T>> = match &self.precond {
            Some(_) => Some(ws::take_zeroed(&exec, dim)),
            None => None,
        };
        let bnorm = blas::norm2(&exec, b)?.as_f64();
        let mut history = Vec::new();
        let mut iters = 0;
        loop {
            // r = b - A x (recomputed every iteration — stationary method)
            r.copy_from(b)?;
            a.apply_advanced(-T::one(), x, T::one(), &mut r)?;
            let resnorm = blas::norm2(&exec, &r)?.as_f64();
            if self.config.record_history {
                history.push(resnorm);
            }
            match crit.check(iters, resnorm, bnorm) {
                StopStatus::Continue => {}
                status => {
                    return Ok(SolveResult {
                        iterations: iters,
                        resnorm,
                        converged: status == StopStatus::Converged,
                        status,
                        history,
                    })
                }
            }
            // a stationary method diverges monotonically when omega is
            // wrong for the spectrum — the stagnation window catches it
            if let Some(bd) = det.residual(resnorm) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
            match (&self.precond, &mut z) {
                (Some(m), Some(z)) => {
                    m.apply(&r, z)?;
                    blas::axpy(&exec, self.omega, &**z, x)?;
                }
                _ => blas::axpy(&exec, self.omega, &r, x)?,
            }
            iters += 1;
            crate::observe::solver_iteration("richardson", iters, resnorm);
        }
    }

    fn name(&self) -> &'static str {
        "richardson"
    }

    fn flops_per_iter(&self, nnz: usize, n: usize) -> u64 {
        2 * nnz as u64 + 3 * 2 * n as u64
    }

    fn bytes_per_iter(&self, nnz: usize, n: usize, elem: usize) -> u64 {
        ((nnz * (elem + 8) + 2 * n * elem) + 2 * 3 * n * elem) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::executor::Executor;
    use crate::matrix::Csr;
    use crate::precond::Jacobi;
    use crate::stop::Criterion;
    use crate::testing::prng::Prng;
    use crate::testing::prop::{gen_sparse, gen_vec};
    use crate::Dim2;

    #[test]
    fn damped_jacobi_converges_on_dominant_system() {
        let mut rng = Prng::new(61);
        let n = 100;
        let data = gen_sparse::<f64>(&mut rng, n, n, 3); // strongly dominant
        let bv = gen_vec::<f64>(&mut rng, n);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let jacobi = Jacobi::from_csr(&a).unwrap();
        let solver = Richardson::new(
            SolverConfig::with_criterion(Criterion::residual(1e-10, 2000)),
            0.9,
        )
        .with_preconditioner(std::sync::Arc::new(jacobi));
        let result = solver.solve(&a, &b, &mut x).unwrap();
        assert!(result.converged, "{result:?}");
    }
}
