//! Conjugate Gradient (optionally preconditioned).
//!
//! Textbook PCG [Saad 2003, alg. 9.1]; short recurrence, for SPD
//! operators. The workhorse of the paper's solver study.

use std::sync::Arc;

use crate::core::error::Result;
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::kernels::blas;
use crate::matrix::dense::Dense;
use crate::solver::{diverged, workspace as ws, SolveResult, Solver, SolverConfig};
use crate::stop::StopStatus;

/// CG solver with optional preconditioner.
pub struct Cg<T: Value> {
    config: SolverConfig,
    precond: Option<Arc<dyn LinOp<T>>>,
}

impl<T: Value> Cg<T> {
    /// Unpreconditioned CG.
    pub fn new(config: SolverConfig) -> Self {
        Self {
            config,
            precond: None,
        }
    }

    /// Attach a preconditioner `M ≈ A⁻¹` applied as `z = M r`.
    pub fn with_preconditioner(mut self, m: Arc<dyn LinOp<T>>) -> Self {
        self.precond = Some(m);
        self
    }
}

impl<T: Value> Solver<T> for Cg<T> {
    fn solve(
        &self,
        a: &dyn LinOp<T>,
        b: &Dense<T>,
        x: &mut Dense<T>,
    ) -> Result<SolveResult> {
        a.check_conformant(b, x)?;
        let exec = x.executor().clone();
        let dim = x.shape();
        let crit = self.config.criterion.started();
        let crit = &crit;
        let mut det = self.config.breakdown.detector();

        // r = b - A x (workspace-pooled: repeated solves reuse buffers)
        let mut r = ws::take_copy(b);
        a.apply_advanced(-T::one(), x, T::one(), &mut r)?;
        // z is only materialized when a preconditioner exists; the
        // unpreconditioned path aliases it to r (the textbook z = r).
        let mut z: Option<ws::WsDense<T>> = match &self.precond {
            Some(m) => {
                let mut z = ws::take_zeroed(&exec, dim);
                m.apply(&r, &mut z)?;
                Some(z)
            }
            None => None,
        };
        let mut p = match &z {
            Some(z) => ws::take_copy(z),
            None => ws::take_copy(&r),
        };
        let mut q = ws::take_zeroed(&exec, dim);
        // fused sweep: rz = z·r and ||r||² together
        let (mut rz, rr0) = match &z {
            Some(z) => blas::dot_norm2(&exec, z, &r)?,
            None => blas::dot_norm2(&exec, &r, &r)?,
        };

        let bnorm = blas::norm2(&exec, b)?.as_f64();
        let mut resnorm = rr0.sqrt().as_f64();
        let mut history = Vec::new();
        if self.config.record_history {
            history.push(resnorm);
        }

        let mut iters = 0;
        loop {
            match crit.check(iters, resnorm, bnorm) {
                StopStatus::Continue => {}
                status => {
                    return Ok(SolveResult {
                        iterations: iters,
                        resnorm,
                        converged: status == StopStatus::Converged,
                        status,
                        history,
                    })
                }
            }
            // fused SpMV: q = A p and p·q in one pass
            let (pq, _) = a.apply_dot(&p, &mut q, &p)?;
            if let Some(bd) = det.scalar("p·Ap", pq.as_f64()) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
            let alpha = rz / pq;
            // fused: x += alpha p; r -= alpha q; rr = ||r||²
            let rr = blas::axpy_sub_norm2(&exec, alpha, &p, &q, x, &mut r)?;
            let rz_new = if let (Some(m), Some(z)) = (&self.precond, &mut z) {
                m.apply(&r, z)?;
                blas::dot(&exec, &r, &**z)?
            } else {
                rr
            };
            if let Some(bd) = det.scalar("rho", rz_new.as_f64()) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
            let beta = rz_new / rz;
            rz = rz_new;
            // p = z + beta p
            {
                let zref: &Dense<T> = match &z {
                    Some(z) => z,
                    None => &r,
                };
                blas::axpby(&exec, T::one(), zref, beta, &mut p)?;
            }
            resnorm = rr.sqrt().as_f64();
            iters += 1;
            crate::observe::solver_iteration("cg", iters, resnorm);
            if self.config.record_history {
                history.push(resnorm);
            }
            if let Some(bd) = det.residual(resnorm) {
                return Ok(diverged(iters, resnorm, history, bd));
            }
        }
    }

    fn name(&self) -> &'static str {
        "cg"
    }

    fn flops_per_iter(&self, nnz: usize, n: usize) -> u64 {
        // 1 SpMV + 3 dot-like (pq, rz, ||r||) + 3 axpy-like
        2 * nnz as u64 + (3 * 2 + 3 * 2) * n as u64
    }

    fn bytes_per_iter(&self, nnz: usize, n: usize, elem: usize) -> u64 {
        // Fused driver: SpMV+dot (1 extra read of p) + axpy_sub_norm2
        // (6 streams: p,q read; x,r read+write) + axpby p-update (3
        // streams). Was 15n before fusion — see DESIGN.md.
        ((nnz * (elem + 8) + 2 * n * elem) + (1 + 6 + 3) * n * elem) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::executor::Executor;
    use crate::matrix::Csr;
    use crate::stop::Criterion;
    use crate::testing::prng::Prng;
    use crate::testing::prop::{gen_sparse, gen_vec};
    use crate::Dim2;

    fn spd_system(seed: u64, n: usize) -> (crate::MatrixData<f64>, Vec<f64>) {
        let mut rng = Prng::new(seed);
        let mut data = gen_sparse::<f64>(&mut rng, n, n, 3);
        data.symmetrize();
        data.shift_diagonal(1.0);
        let b = gen_vec::<f64>(&mut rng, n);
        (data, b)
    }

    #[test]
    fn converges_on_spd_system() {
        let (data, bv) = spd_system(11, 200);
        for exec in [Executor::reference(), Executor::par_with_threads(4)] {
            let a = Csr::from_data(exec.clone(), &data).unwrap();
            let b = Dense::vector(exec.clone(), &bv);
            let mut x = Dense::zeros(exec.clone(), Dim2::new(200, 1));
            let solver = Cg::new(SolverConfig::with_criterion(Criterion::residual(1e-10, 500)));
            let result = solver.solve(&a, &b, &mut x).unwrap();
            assert!(result.converged, "{}: {result:?}", exec.name());
            // true residual check
            let mut r = b.clone();
            a.apply_advanced(-1.0, &x, 1.0, &mut r).unwrap();
            assert!(r.norm2_host() < 1e-8 * b.norm2_host());
        }
    }

    #[test]
    fn jacobi_preconditioner_reduces_iterations() {
        // badly scaled diagonal makes plain CG slow; Jacobi fixes scaling
        let n = 150;
        let mut rng = Prng::new(3);
        let mut data = crate::MatrixData::<f64>::new(Dim2::square(n));
        for i in 0..n {
            let scale = 10f64.powi((rng.below(5) as i32) - 2);
            data.push(i as i32, i as i32, 4.0 * scale);
            if i + 1 < n {
                data.push(i as i32, (i + 1) as i32, -1.0 * scale);
                data.push((i + 1) as i32, i as i32, -1.0 * scale);
            }
        }
        data.normalize();
        // symmetrize the scaling: D A D is SPD; here keep A nonsym-scaled
        // but SPD-enough by averaging
        data.symmetrize();
        data.shift_diagonal(0.5);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let bv = gen_vec::<f64>(&mut rng, n);
        let b = Dense::vector(exec.clone(), &bv);
        let crit = Criterion::residual(1e-8, 2000);

        let mut x0 = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let plain = Cg::new(SolverConfig::with_criterion(crit.clone()))
            .solve(&a, &b, &mut x0)
            .unwrap();

        let jacobi = crate::precond::Jacobi::from_csr(&a).unwrap();
        let mut x1 = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let pcg = Cg::new(SolverConfig::with_criterion(crit))
            .with_preconditioner(std::sync::Arc::new(jacobi));
        let precond = pcg.solve(&a, &b, &mut x1).unwrap();

        assert!(precond.converged);
        assert!(
            precond.iterations < plain.iterations,
            "jacobi {} vs plain {}",
            precond.iterations,
            plain.iterations
        );
    }

    #[test]
    fn iteration_budget_reported() {
        let (data, bv) = spd_system(13, 100);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(100, 1));
        let solver = Cg::new(SolverConfig::with_criterion(Criterion::iterations(7)));
        let r = solver.solve(&a, &b, &mut x).unwrap();
        assert_eq!(r.iterations, 7);
        assert!(!r.converged);
    }

    #[test]
    fn history_recorded_and_decreasing() {
        let (data, bv) = spd_system(17, 120);
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(120, 1));
        let mut cfg = SolverConfig::with_criterion(Criterion::residual(1e-10, 300));
        cfg.record_history = true;
        let r = Cg::new(cfg).solve(&a, &b, &mut x).unwrap();
        assert_eq!(r.history.len(), r.iterations + 1);
        assert!(r.history.last().unwrap() < &r.history[0]);
    }
}
