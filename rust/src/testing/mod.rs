//! Test support: deterministic PRNG and a tiny property-testing harness.
//!
//! The offline vendor set ships neither `rand` nor `proptest`, so both are
//! hand-rolled here. Exposed as a normal (non-`cfg(test)`) module because
//! the matrix generators (`matgen`) use the same PRNG and the integration
//! tests / benches need it too.

pub mod prng;
pub mod prop;
