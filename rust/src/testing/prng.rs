//! Deterministic splitmix64-based PRNG (no `rand` in the vendor set).
//!
//! splitmix64 passes BigCrush-level statistical tests for the use here
//! (test-input generation and synthetic matrix sampling) and is seedable
//! and platform-stable, which keeps generators and tests reproducible.

/// Deterministic 64-bit PRNG.
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    /// Seeded construction; equal seeds give equal streams, forever.
    pub fn new(seed: u64) -> Self {
        Self {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    /// Next raw 64-bit value (splitmix64 step).
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.unit() * (hi - lo)
    }

    /// Uniform usize in [0, n). Panics on n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Prng::below(0)");
        // multiply-shift bound; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform choice from a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.unit().max(f64::MIN_POSITIVE);
        let u2 = self.unit();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pareto-distributed value with shape `alpha`, minimum `xm` — used by
    /// the circuit-matrix generators for power-law row degrees.
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        xm / self.unit().max(f64::MIN_POSITIVE).powf(1.0 / alpha)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Prng::new(2);
        assert_ne!(Prng::new(1).next_u64(), c.next_u64());
    }

    #[test]
    fn unit_in_range_and_mixed() {
        let mut rng = Prng::new(42);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = rng.unit();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Prng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Prng::new(99);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn pareto_minimum_respected() {
        let mut rng = Prng::new(3);
        for _ in 0..1000 {
            assert!(rng.pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Prng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
