//! Minimal property-testing harness (no `proptest` in the vendor set).
//!
//! `for_all` runs a property over `cases` generated inputs and reports the
//! seed of the first failing case so it can be replayed; generators for
//! random vectors and sparse matrices live here so every module states
//! its invariants the same way.

use crate::core::dim::Dim2;
use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;
use crate::testing::prng::Prng;

/// Run `prop(rng, case_index)` for `cases` cases; panic with the failing
/// seed on the first violation. Properties signal failure by panicking.
pub fn for_all(seed: u64, cases: usize, prop: impl Fn(&mut Prng, usize)) {
    for case in 0..cases {
        let case_seed = seed ^ ((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Prng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed at case {case} (replay seed {case_seed:#x}): {msg}");
        }
    }
}

/// Random vector in [-1, 1).
pub fn gen_vec<T: Value>(rng: &mut Prng, n: usize) -> Vec<T> {
    (0..n).map(|_| T::from_f64(rng.uniform(-1.0, 1.0))).collect()
}

/// Random sparse matrix with ~`avg_nnz_per_row` entries per row plus a
/// dominant diagonal (keeps iterative solvers convergent).
pub fn gen_sparse<T: Value>(
    rng: &mut Prng,
    rows: usize,
    cols: usize,
    avg_nnz_per_row: usize,
) -> MatrixData<T> {
    let mut data = MatrixData::new(Dim2::new(rows, cols));
    for i in 0..rows {
        let k = rng.below(2 * avg_nnz_per_row + 1);
        for _ in 0..k {
            data.push(
                i as i32,
                rng.below(cols) as i32,
                T::from_f64(rng.uniform(-1.0, 1.0)),
            );
        }
    }
    if rows == cols {
        data.shift_diagonal(T::from_f64(2.0 * (avg_nnz_per_row + 1) as f64));
    }
    data.normalize();
    data
}

/// Assert two slices are element-wise close with mixed abs/rel tolerance.
#[track_caller]
pub fn assert_close<T: Value>(a: &[T], b: &[T], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for i in 0..a.len() {
        let (x, y) = (a[i].as_f64(), b[i].as_f64());
        let scale = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}: mismatch at {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_passes_trivial_property() {
        for_all(1, 20, |rng, _| {
            let v = rng.unit();
            assert!((0.0..1.0).contains(&v));
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn for_all_reports_failing_seed() {
        for_all(1, 20, |rng, _| {
            assert!(rng.unit() < 0.5, "too big");
        });
    }

    #[test]
    fn gen_sparse_is_valid_and_diag_dominant() {
        let mut rng = Prng::new(11);
        let d = gen_sparse::<f64>(&mut rng, 50, 50, 4);
        d.validate().unwrap();
        assert!(d.is_normalized());
        let dense = d.to_dense_vec();
        for i in 0..50 {
            let diag = dense[i * 50 + i].abs();
            let off: f64 = (0..50)
                .filter(|&j| j != i)
                .map(|j| dense[i * 50 + j].abs())
                .sum();
            assert!(diag > off, "row {i} not dominant: {diag} <= {off}");
        }
    }

    #[test]
    fn assert_close_tolerates_and_catches() {
        assert_close(&[1.0f64, 2.0], &[1.0 + 1e-13, 2.0], 1e-12, "ok");
        let r = std::panic::catch_unwind(|| {
            assert_close(&[1.0f64], &[1.1], 1e-12, "bad");
        });
        assert!(r.is_err());
    }
}
