//! BabelStream kernels (Fig. 6 of the paper).
//!
//! The five kernels of the BabelStream benchmark [Deakin et al. 2017]
//! reimplemented on every executor: `copy c=a`, `mul b=s*c`, `add c=a+b`,
//! `triad a=b+s*c`, `dot sum(a*b)`. The bench harness sweeps array sizes
//! and reports achieved bandwidth; the roofline model projects the same
//! kernels onto the paper's GPUs.

use std::sync::Arc;

use crate::core::error::{Result, SparkleError};
use crate::core::executor::{par_for, Executor, ParConfig};
use crate::core::types::Value;
use crate::runtime::bucket::pad_to;
use crate::runtime::{Arg, XlaRuntime};

/// Which BabelStream kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StreamKernel {
    Copy,
    Mul,
    Add,
    Triad,
    Dot,
}

impl StreamKernel {
    /// All kernels in BabelStream order.
    pub const ALL: [StreamKernel; 5] = [
        StreamKernel::Copy,
        StreamKernel::Mul,
        StreamKernel::Add,
        StreamKernel::Triad,
        StreamKernel::Dot,
    ];

    /// Display name matching the BabelStream output.
    pub fn name(self) -> &'static str {
        match self {
            StreamKernel::Copy => "Copy",
            StreamKernel::Mul => "Mul",
            StreamKernel::Add => "Add",
            StreamKernel::Triad => "Triad",
            StreamKernel::Dot => "Dot",
        }
    }

    /// Artifact family name.
    pub fn artifact(self) -> &'static str {
        match self {
            StreamKernel::Copy => "stream_copy",
            StreamKernel::Mul => "stream_mul",
            StreamKernel::Add => "stream_add",
            StreamKernel::Triad => "stream_triad",
            StreamKernel::Dot => "stream_dot",
        }
    }

    /// Bytes moved per element at scalar size `elem` (BabelStream
    /// accounting: reads + writes, no write-allocate).
    pub fn bytes_per_element(self, elem: usize) -> usize {
        match self {
            StreamKernel::Copy => 2 * elem,  // read a, write c
            StreamKernel::Mul => 2 * elem,   // read c, write b
            StreamKernel::Add => 3 * elem,   // read a+b, write c
            StreamKernel::Triad => 3 * elem, // read b+c, write a
            StreamKernel::Dot => 2 * elem,   // read a+b
        }
    }

    /// FLOPs per element.
    pub fn flops_per_element(self) -> usize {
        match self {
            StreamKernel::Copy => 0,
            StreamKernel::Mul => 1,
            StreamKernel::Add => 1,
            StreamKernel::Triad => 2,
            StreamKernel::Dot => 2,
        }
    }
}

/// Working arrays of one BabelStream run.
pub struct StreamArrays<T> {
    pub a: Vec<T>,
    pub b: Vec<T>,
    pub c: Vec<T>,
}

impl<T: Value> StreamArrays<T> {
    /// BabelStream initial values: a=0.1, b=0.2, c=0.0.
    pub fn new(n: usize) -> Self {
        Self {
            a: vec![T::from_f64(0.1); n],
            b: vec![T::from_f64(0.2); n],
            c: vec![T::zero(); n],
        }
    }
}

/// The scalar used by mul/triad, as in BabelStream.
pub const STREAM_SCALAR: f64 = 0.4;

/// Run one kernel once. Returns the dot value for `Dot`, 0 otherwise.
pub fn run<T: Value>(
    exec: &Arc<Executor>,
    kernel: StreamKernel,
    arrays: &mut StreamArrays<T>,
) -> Result<T> {
    match &**exec {
        Executor::Reference => Ok(run_host(&ParConfig { threads: 1, seq_threshold: usize::MAX }, kernel, arrays)),
        Executor::Par(cfg) => Ok(run_host(cfg, kernel, arrays)),
        Executor::Xla(e) => run_xla(&e.runtime, kernel, arrays),
    }
}

fn run_host<T: Value>(cfg: &ParConfig, kernel: StreamKernel, ar: &mut StreamArrays<T>) -> T {
    use crate::kernels::ptr::SlicePtr;
    let s = T::from_f64(STREAM_SCALAR);
    let n = ar.a.len();
    match kernel {
        StreamKernel::Copy => {
            let (a, c) = (&ar.a, SlicePtr(ar.c.as_mut_ptr()));
            par_for(cfg, n, |_, lo, hi| {
                for i in lo..hi {
                    // SAFETY: [lo, hi) disjoint across threads.
                    unsafe { *c.at(i) = a[i] };
                }
            });
            T::zero()
        }
        StreamKernel::Mul => {
            let (c, b) = (&ar.c, SlicePtr(ar.b.as_mut_ptr()));
            par_for(cfg, n, |_, lo, hi| {
                for i in lo..hi {
                    unsafe { *b.at(i) = s * c[i] };
                }
            });
            T::zero()
        }
        StreamKernel::Add => {
            let (a, b, c) = (&ar.a, &ar.b, SlicePtr(ar.c.as_mut_ptr()));
            par_for(cfg, n, |_, lo, hi| {
                for i in lo..hi {
                    unsafe { *c.at(i) = a[i] + b[i] };
                }
            });
            T::zero()
        }
        StreamKernel::Triad => {
            let (b, c, a) = (&ar.b, &ar.c, SlicePtr(ar.a.as_mut_ptr()));
            par_for(cfg, n, |_, lo, hi| {
                for i in lo..hi {
                    unsafe { *a.at(i) = b[i] + s * c[i] };
                }
            });
            T::zero()
        }
        StreamKernel::Dot => crate::kernels::par::dot(cfg, &ar.a, &ar.b),
    }
}

fn run_xla<T: Value>(
    rt: &XlaRuntime,
    kernel: StreamKernel,
    ar: &mut StreamArrays<T>,
) -> Result<T> {
    let n = ar.a.len();
    let meta = rt.select(kernel.artifact(), T::PRECISION, n, 0, 0).map_err(|_| {
        SparkleError::Runtime(format!(
            "no `{}` artifact at {} for n={n}",
            kernel.artifact(),
            T::PRECISION
        ))
    })?;
    let s = T::from_f64(STREAM_SCALAR);
    match kernel {
        StreamKernel::Copy => {
            let ap = pad_to(&ar.a, meta.n, T::zero());
            let out = rt.run::<T>(&meta.name, &[Arg::vec(&ap)])?;
            ar.c.copy_from_slice(&out[0][..n]);
            Ok(T::zero())
        }
        StreamKernel::Mul => {
            let cp = pad_to(&ar.c, meta.n, T::zero());
            let out = rt.run::<T>(&meta.name, &[Arg::Scalar(s), Arg::vec(&cp)])?;
            ar.b.copy_from_slice(&out[0][..n]);
            Ok(T::zero())
        }
        StreamKernel::Add => {
            let ap = pad_to(&ar.a, meta.n, T::zero());
            let bp = pad_to(&ar.b, meta.n, T::zero());
            let out = rt.run::<T>(&meta.name, &[Arg::vec(&ap), Arg::vec(&bp)])?;
            ar.c.copy_from_slice(&out[0][..n]);
            Ok(T::zero())
        }
        StreamKernel::Triad => {
            let bp = pad_to(&ar.b, meta.n, T::zero());
            let cp = pad_to(&ar.c, meta.n, T::zero());
            let out = rt.run::<T>(&meta.name, &[Arg::Scalar(s), Arg::vec(&bp), Arg::vec(&cp)])?;
            ar.a.copy_from_slice(&out[0][..n]);
            Ok(T::zero())
        }
        StreamKernel::Dot => {
            let ap = pad_to(&ar.a, meta.n, T::zero());
            let bp = pad_to(&ar.b, meta.n, T::zero());
            let out = rt.run::<T>(&meta.name, &[Arg::vec(&ap), Arg::vec(&bp)])?;
            Ok(out[0][0])
        }
    }
}

/// Verify array contents after `iters` full Copy→Mul→Add→Triad cycles
/// (BabelStream's self-check). Returns the max relative error.
pub fn verify<T: Value>(arrays: &StreamArrays<T>, iters: usize) -> f64 {
    let (mut ga, mut gb, mut gc) = (0.1f64, 0.2f64, 0.0f64);
    for _ in 0..iters {
        gc = ga;
        gb = STREAM_SCALAR * gc;
        gc = ga + gb;
        ga = gb + STREAM_SCALAR * gc;
    }
    let err = |v: &[T], gold: f64| -> f64 {
        v.iter()
            .map(|x| ((Value::as_f64(*x) - gold) / gold).abs())
            .fold(0.0, f64::max)
    };
    err(&arrays.a, ga).max(err(&arrays.b, gb)).max(err(&arrays.c, gc))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_and_flops_accounting() {
        assert_eq!(StreamKernel::Copy.bytes_per_element(8), 16);
        assert_eq!(StreamKernel::Add.bytes_per_element(4), 12);
        assert_eq!(StreamKernel::Triad.flops_per_element(), 2);
        assert_eq!(StreamKernel::Copy.flops_per_element(), 0);
    }

    #[test]
    fn host_cycle_verifies() {
        for exec in [Executor::reference(), Executor::par_with_threads(2)] {
            let mut ar = StreamArrays::<f64>::new(1000);
            let iters = 3;
            for _ in 0..iters {
                for k in [
                    StreamKernel::Copy,
                    StreamKernel::Mul,
                    StreamKernel::Add,
                    StreamKernel::Triad,
                ] {
                    run(&exec, k, &mut ar).unwrap();
                }
            }
            assert!(verify(&ar, iters) < 1e-12, "exec {}", exec.name());
        }
    }

    #[test]
    fn dot_matches_expected() {
        let exec = Executor::par_with_threads(2);
        let mut ar = StreamArrays::<f64>::new(500);
        let d = run(&exec, StreamKernel::Dot, &mut ar).unwrap();
        assert!((d - 500.0 * 0.1 * 0.2).abs() < 1e-10);
    }
}
