//! Shared-pointer shim for scoped-thread kernels.

/// Wrap a raw mutable pointer so disjoint ranges can be written from
/// scoped threads. Safety rests on the caller handing each thread a
/// disjoint index range.
pub(crate) struct SlicePtr<T>(pub(crate) *mut T);
unsafe impl<T> Send for SlicePtr<T> {}
unsafe impl<T> Sync for SlicePtr<T> {}

impl<T> SlicePtr<T> {
    /// # Safety
    /// `start..start+len` must be in-bounds and disjoint across threads.
    pub(crate) unsafe fn range(&self, start: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }

    /// # Safety
    /// `idx` must be in-bounds and not written by any other thread.
    pub(crate) unsafe fn at(&self, idx: usize) -> &mut T {
        &mut *self.0.add(idx)
    }
}
