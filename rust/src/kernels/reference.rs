//! Sequential reference kernels (Ginkgo's `reference` backend).
//!
//! Deliberately simple: these define the semantics every other backend is
//! validated against. No blocking, no threading, no reordering beyond the
//! storage order — floating-point results are bit-deterministic.

use crate::core::linop::LinOp;
use crate::core::types::{IndexType, Value};
use crate::matrix::coo::Coo;
use crate::matrix::csr::Csr;
use crate::matrix::dense::Dense;
use crate::matrix::ell::Ell;
use crate::matrix::sellp::SellP;

// ---------------------------------------------------------------- BLAS-1

/// y += alpha * x (element-wise over the whole buffer).
pub fn axpy<T: Value>(alpha: T, x: &[T], y: &mut [T]) {
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// y = alpha * x + beta * y. `beta == 0` overwrites (no NaN propagation).
pub fn axpby<T: Value>(alpha: T, x: &[T], beta: T, y: &mut [T]) {
    if beta.is_zero() {
        for i in 0..x.len() {
            y[i] = alpha * x[i];
        }
    } else {
        for i in 0..x.len() {
            y[i] = alpha * x[i] + beta * y[i];
        }
    }
}

/// x *= beta; `beta == 0` fills with zero (Ginkgo semantics).
pub fn scal<T: Value>(beta: T, x: &mut [T]) {
    if beta.is_zero() {
        x.fill(T::zero());
    } else {
        for v in x.iter_mut() {
            *v *= beta;
        }
    }
}

/// Dot product over the whole buffer.
pub fn dot<T: Value>(x: &[T], y: &[T]) -> T {
    let mut acc = T::zero();
    for i in 0..x.len() {
        acc += x[i] * y[i];
    }
    acc
}

/// Euclidean norm.
pub fn norm2<T: Value>(x: &[T]) -> T {
    dot(x, x).sqrt()
}

/// z = x ⊙ y (element-wise product; Jacobi preconditioner apply).
pub fn ew_mul<T: Value>(x: &[T], y: &[T], z: &mut [T]) {
    for i in 0..x.len() {
        z[i] = x[i] * y[i];
    }
}

// ---------------------------------------------------------- fused BLAS-1
//
// Each fused kernel collapses 2-3 full-vector sweeps of the composed
// sequence into one pass, and performs *exactly the same elementary
// operations in the same element order* as the composed calls, so the
// results are bit-identical on this backend. The composed sequence each
// one replaces is stated in its doc comment; `rust/tests/fused_kernels.rs`
// asserts the equivalence property.

/// Fused `(x·y, y·y)` in one pass over both vectors.
///
/// Replaces `dot(x, y)` + `dot(y, y)`.
pub fn dot_norm2<T: Value>(x: &[T], y: &[T]) -> (T, T) {
    let mut xy = T::zero();
    let mut yy = T::zero();
    for i in 0..x.len() {
        xy += x[i] * y[i];
        yy += y[i] * y[i];
    }
    (xy, yy)
}

/// Fused CG tail: `x += alpha·p; r -= alpha·q`, returning `‖r‖²`.
///
/// Replaces `axpy(alpha, p, x)` + `axpy(-alpha, q, r)` + `dot(r, r)`.
pub fn axpy_sub_norm2<T: Value>(alpha: T, p: &[T], q: &[T], x: &mut [T], r: &mut [T]) -> T {
    let mut rr = T::zero();
    for i in 0..p.len() {
        x[i] += alpha * p[i];
        r[i] += -alpha * q[i];
        rr += r[i] * r[i];
    }
    rr
}

/// Fused `out = z + alpha·x`.
///
/// Replaces `out.copy_from(z)` + `axpy(alpha, x, out)`.
pub fn add_scaled<T: Value>(z: &[T], alpha: T, x: &[T], out: &mut [T]) {
    for i in 0..z.len() {
        out[i] = z[i] + alpha * x[i];
    }
}

/// Fused BiCGSTAB direction update: `p = r + beta·(p - omega·v)`.
///
/// Replaces `axpy(-omega, v, p)` + `axpby(1, r, beta, p)`, including the
/// `beta == 0` overwrite semantics of `axpby`.
pub fn update_p<T: Value>(r: &[T], beta: T, omega: T, v: &[T], p: &mut [T]) {
    if beta.is_zero() {
        for i in 0..r.len() {
            p[i] = r[i];
        }
    } else {
        for i in 0..r.len() {
            let t = p[i] + -omega * v[i];
            p[i] = r[i] + beta * t;
        }
    }
}

/// Fused CGS direction update: `p = u + beta·(q + beta·p)`.
///
/// Replaces `axpby(1, q, beta, p)` + `axpby(1, u, beta, p)`, including
/// the `beta == 0` overwrite semantics of `axpby`.
pub fn update_p_cgs<T: Value>(u: &[T], beta: T, q: &[T], p: &mut [T]) {
    if beta.is_zero() {
        for i in 0..u.len() {
            p[i] = u[i];
        }
    } else {
        for i in 0..u.len() {
            let t = q[i] + beta * p[i];
            p[i] = u[i] + beta * t;
        }
    }
}

/// Fused BiCGSTAB residual update: `r = s - omega·t`, returning `‖r‖²`.
///
/// Replaces `r.copy_from(s)` + `axpy(-omega, t, r)` + `dot(r, r)`.
pub fn sub_scaled_norm2<T: Value>(s: &[T], omega: T, t: &[T], r: &mut [T]) -> T {
    let mut rr = T::zero();
    for i in 0..s.len() {
        r[i] = s[i] + -omega * t[i];
        rr += r[i] * r[i];
    }
    rr
}

/// Fused double update: `x += alpha·p + omega·s` (two sequential adds,
/// matching the composed rounding).
///
/// Replaces `axpy(alpha, p, x)` + `axpy(omega, s, x)`.
pub fn axpy2<T: Value>(alpha: T, p: &[T], omega: T, s: &[T], x: &mut [T]) {
    for i in 0..p.len() {
        let t = x[i] + alpha * p[i];
        x[i] = t + omega * s[i];
    }
}

/// Fused `out = beta·x` (GMRES basis normalization).
///
/// Replaces `out.copy_from(x)` + `scal(beta, out)`.
pub fn scal_into<T: Value>(beta: T, x: &[T], out: &mut [T]) {
    if beta.is_zero() {
        out[..x.len()].fill(T::zero());
    } else {
        for i in 0..x.len() {
            out[i] = x[i] * beta;
        }
    }
}

/// Fused MGS projection pair: `h = <w, v>; w -= h·v`, returning `h`.
///
/// Replaces `dot(w, v)` + `axpy(-h, v, w)` — the subtraction runs while
/// `w` and `v` are still cache-hot instead of as a second dispatch.
pub fn dot_axpy<T: Value>(v: &[T], w: &mut [T]) -> T {
    let h = dot(w, v);
    axpy(-h, v, w);
    h
}

/// One pipelined MGS stage: `w -= h_prev·v_prev` and accumulate the next
/// projection `<w, v_next>` in the same sweep. Per element the update
/// and the product are the exact operations the composed
/// `axpy(-h_prev, v_prev, w)` + `dot(w, v_next)` pair performs, in the
/// same order, so the pipelining is bitwise-invisible.
pub fn mgs_step<T: Value>(h_prev: T, v_prev: &[T], v_next: &[T], w: &mut [T]) -> T {
    let mut acc = T::zero();
    for i in 0..w.len() {
        w[i] += -h_prev * v_prev[i];
        acc += w[i] * v_next[i];
    }
    acc
}

/// Final pipelined MGS stage: `w -= h_last·v_last` and accumulate
/// `<w, w>` of the projected remainder in the same sweep.
pub fn mgs_finish<T: Value>(h_last: T, v_last: &[T], w: &mut [T]) -> T {
    let mut acc = T::zero();
    for i in 0..w.len() {
        w[i] += -h_last * v_last[i];
        acc += w[i] * w[i];
    }
    acc
}

/// Full modified-Gram-Schmidt sweep of `w` against the basis block:
/// `h[i] = <w, v_i>; w -= h[i]·v_i` for every column, returning `<w, w>`
/// of the remainder (the caller takes the square root for `‖w‖`).
///
/// Replaces the composed `dot` + `axpy` pair per basis vector plus the
/// trailing `norm2`: each stage subtracts the previous projection while
/// accumulating the next one, so `w` is swept once per basis vector
/// instead of twice — and the norm rides the last subtraction for free.
pub fn mgs_project<T: Value>(basis: &[&[T]], w: &mut [T], h: &mut [T]) -> T {
    let k = basis.len();
    if k == 0 {
        return dot(w, w);
    }
    h[0] = dot(w, basis[0]);
    for i in 1..k {
        h[i] = mgs_step(h[i - 1], basis[i - 1], basis[i], w);
    }
    mgs_finish(h[k - 1], basis[k - 1], w)
}

/// Batched basis update `x += Σ_j y_j·v_j` (gemv-like over the basis
/// block): per element the additions run in basis order, exactly the
/// composed `axpy` sequence, so results are bit-identical while `x` is
/// swept once instead of once per column.
pub fn mgs_update<T: Value>(basis: &[&[T]], y: &[T], x: &mut [T]) {
    for e in 0..x.len() {
        let mut acc = x[e];
        for (v, &c) in basis.iter().zip(y) {
            acc += c * v[e];
        }
        x[e] = acc;
    }
}

// ------------------------------------------------------------------ SpMV

/// CSR SpMV: x = A b (multi-rhs aware).
pub fn csr_spmv<T: Value>(a: &Csr<T>, b: &Dense<T>, x: &mut Dense<T>) {
    csr_spmv_advanced(T::one(), a, T::zero(), b, x);
}

/// CSR SpMV: x = alpha A b + beta x.
pub fn csr_spmv_advanced<T: Value>(alpha: T, a: &Csr<T>, beta: T, b: &Dense<T>, x: &mut Dense<T>) {
    let nrhs = b.shape().cols;
    let row_ptrs = a.row_ptrs();
    let col_idxs = a.col_idxs();
    let values = a.values();
    for i in 0..a.shape().rows {
        for c in 0..nrhs {
            let mut acc = T::zero();
            for k in row_ptrs[i] as usize..row_ptrs[i + 1] as usize {
                acc += values[k] * b.at(col_idxs[k] as usize, c);
            }
            let xv = x.at_mut(i, c);
            *xv = if beta.is_zero() {
                alpha * acc
            } else {
                alpha * acc + beta * *xv
            };
        }
    }
}

/// COO SpMV: x = A b. Requires row-sorted entries.
pub fn coo_spmv<T: Value>(a: &Coo<T>, b: &Dense<T>, x: &mut Dense<T>) {
    x.fill(T::zero());
    coo_spmv_accumulate(T::one(), a, b, x);
}

/// COO SpMV: x = alpha A b + beta x.
pub fn coo_spmv_advanced<T: Value>(alpha: T, a: &Coo<T>, beta: T, b: &Dense<T>, x: &mut Dense<T>) {
    scal(beta, x.as_mut_slice());
    coo_spmv_accumulate(alpha, a, b, x);
}

/// x += alpha A b — the COO accumulation core.
pub fn coo_spmv_accumulate<T: Value>(alpha: T, a: &Coo<T>, b: &Dense<T>, x: &mut Dense<T>) {
    let nrhs = b.shape().cols;
    for idx in 0..a.nnz() {
        let r = a.row_idxs()[idx] as usize;
        let c = a.col_idxs()[idx] as usize;
        let v = alpha * a.values()[idx];
        for j in 0..nrhs {
            *x.at_mut(r, j) += v * b.at(c, j);
        }
    }
}

/// ELL SpMV: x = A b. Column-major storage, zero-padded (col 0 / val 0).
pub fn ell_spmv<T: Value>(a: &Ell<T>, b: &Dense<T>, x: &mut Dense<T>) {
    let n = a.shape().rows;
    let nrhs = b.shape().cols;
    let k = a.stored_per_row();
    let cols = a.col_idxs();
    let vals = a.values();
    for i in 0..n {
        for c in 0..nrhs {
            let mut acc = T::zero();
            for j in 0..k {
                let pos = j * n + i;
                // padding has val == 0, so no branch needed
                acc += vals[pos] * b.at(cols[pos] as usize, c);
            }
            *x.at_mut(i, c) = acc;
        }
    }
}

/// SELL-P SpMV: x = A b.
pub fn sellp_spmv<T: Value>(a: &SellP<T>, b: &Dense<T>, x: &mut Dense<T>) {
    let n = a.shape().rows;
    let nrhs = b.shape().cols;
    let ss = a.slice_size();
    for s in 0..a.num_slices() {
        let width = a.slice_lengths[s];
        let base = a.slice_sets[s];
        for r in 0..ss {
            let i = s * ss + r;
            if i >= n {
                break;
            }
            for c in 0..nrhs {
                let mut acc = T::zero();
                for j in 0..width {
                    let pos = base + j * ss + r;
                    acc += a.values[pos] * b.at(a.col_idxs[pos] as usize, c);
                }
                *x.at_mut(i, c) = acc;
            }
        }
    }
}

// ------------------------------------------------------- fused SpMV+dot
//
// `x = A b` with `(w·x, x·x)` accumulated inside the row sweep: the just-
// written entry of x is consumed for both reductions while it is still
// in register, so the composed follow-up passes over x disappear. The
// accumulation visits x in flattened (row-major) order — exactly the
// order `dot` uses — so the result is bit-identical to
// `*_spmv` + `dot(w, x)` + `dot(x, x)` on this backend.

/// CSR SpMV fused with two reductions: `x = A b`, returns `(w·x, x·x)`.
pub fn csr_spmv_dot<T: Value>(a: &Csr<T>, b: &Dense<T>, x: &mut Dense<T>, w: &Dense<T>) -> (T, T) {
    let nrhs = b.shape().cols;
    let row_ptrs = a.row_ptrs();
    let col_idxs = a.col_idxs();
    let values = a.values();
    let ws = w.as_slice();
    let mut wx = T::zero();
    let mut xx = T::zero();
    for i in 0..a.shape().rows {
        for c in 0..nrhs {
            let mut acc = T::zero();
            for k in row_ptrs[i] as usize..row_ptrs[i + 1] as usize {
                acc += values[k] * b.at(col_idxs[k] as usize, c);
            }
            *x.at_mut(i, c) = acc;
            wx += ws[i * nrhs + c] * acc;
            xx += acc * acc;
        }
    }
    (wx, xx)
}

/// ELL SpMV fused with two reductions: `x = A b`, returns `(w·x, x·x)`.
pub fn ell_spmv_dot<T: Value>(a: &Ell<T>, b: &Dense<T>, x: &mut Dense<T>, w: &Dense<T>) -> (T, T) {
    let n = a.shape().rows;
    let nrhs = b.shape().cols;
    let k = a.stored_per_row();
    let cols = a.col_idxs();
    let vals = a.values();
    let ws = w.as_slice();
    let mut wx = T::zero();
    let mut xx = T::zero();
    for i in 0..n {
        for c in 0..nrhs {
            let mut acc = T::zero();
            for j in 0..k {
                let pos = j * n + i;
                acc += vals[pos] * b.at(cols[pos] as usize, c);
            }
            *x.at_mut(i, c) = acc;
            wx += ws[i * nrhs + c] * acc;
            xx += acc * acc;
        }
    }
    (wx, xx)
}

/// SELL-P SpMV fused with two reductions: `x = A b`, returns
/// `(w·x, x·x)`. SELL-P visits rows slice-by-slice, which is still
/// ascending row order, so the accumulation order matches `dot`.
pub fn sellp_spmv_dot<T: Value>(
    a: &SellP<T>,
    b: &Dense<T>,
    x: &mut Dense<T>,
    w: &Dense<T>,
) -> (T, T) {
    let n = a.shape().rows;
    let nrhs = b.shape().cols;
    let ss = a.slice_size();
    let ws = w.as_slice();
    let mut wx = T::zero();
    let mut xx = T::zero();
    for s in 0..a.num_slices() {
        let width = a.slice_lengths[s];
        let base = a.slice_sets[s];
        for r in 0..ss {
            let i = s * ss + r;
            if i >= n {
                break;
            }
            for c in 0..nrhs {
                let mut acc = T::zero();
                for j in 0..width {
                    let pos = base + j * ss + r;
                    acc += a.values[pos] * b.at(a.col_idxs[pos] as usize, c);
                }
                *x.at_mut(i, c) = acc;
                wx += ws[i * nrhs + c] * acc;
                xx += acc * acc;
            }
        }
    }
    (wx, xx)
}

/// Convert CSR row pointers to explicit row indices (COO expansion).
pub fn row_ptrs_to_idxs(row_ptrs: &[IndexType], nnz: usize) -> Vec<IndexType> {
    let mut rows = vec![0 as IndexType; nnz];
    for i in 0..row_ptrs.len() - 1 {
        for k in row_ptrs[i] as usize..row_ptrs[i + 1] as usize {
            rows[k] = i as IndexType;
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::dim::Dim2;
    use crate::core::executor::Executor;
    use crate::core::matrix_data::MatrixData;

    #[test]
    fn blas1_basics() {
        let x = [1.0f64, 2.0, 3.0];
        let mut y = [1.0f64, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        axpby(1.0, &x, 0.5, &mut y);
        assert_eq!(y, [2.5, 4.5, 6.5]);
        scal(0.0, &mut y);
        assert_eq!(y, [0.0, 0.0, 0.0]);
        assert_eq!(dot(&x, &x), 14.0);
        assert!((norm2(&x) - 14.0f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn axpby_beta_zero_kills_nan() {
        let x = [1.0f64];
        let mut y = [f64::NAN];
        axpby(3.0, &x, 0.0, &mut y);
        assert_eq!(y, [3.0]);
        let mut y = [f64::NAN];
        scal(0.0, &mut y);
        assert_eq!(y, [0.0]);
    }

    #[test]
    fn ew_mul_basics() {
        let mut z = [0.0f32; 3];
        ew_mul(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0], &mut z);
        assert_eq!(z, [4.0, 10.0, 18.0]);
    }

    #[test]
    fn row_ptr_expansion() {
        assert_eq!(row_ptrs_to_idxs(&[0, 2, 3, 5], 5), vec![0, 0, 1, 2, 2]);
        assert_eq!(row_ptrs_to_idxs(&[0, 0, 0, 2], 2), vec![2, 2]);
    }

    #[test]
    fn csr_advanced_beta_zero_kills_nan() {
        let d = MatrixData::from_triplets(Dim2::square(2), &[0, 1], &[0, 1], &[1.0, 1.0])
            .unwrap();
        let a = Csr::from_data(Executor::reference(), &d).unwrap();
        let b = Dense::vector(Executor::reference(), &[2.0, 3.0]);
        let mut x = Dense::vector(Executor::reference(), &[f64::NAN, f64::NAN]);
        csr_spmv_advanced(1.0, &a, 0.0, &b, &mut x);
        assert_eq!(x.as_slice(), &[2.0, 3.0]);
    }

    #[test]
    fn multi_rhs_spmv() {
        // A = [[1, 2], [0, 3]], B = [[1, 10], [2, 20]]
        let d = MatrixData::from_triplets(
            Dim2::square(2),
            &[0, 0, 1],
            &[0, 1, 1],
            &[1.0, 2.0, 3.0],
        )
        .unwrap();
        let a = Csr::from_data(Executor::reference(), &d).unwrap();
        let b = Dense::from_vec(
            Executor::reference(),
            Dim2::new(2, 2),
            vec![1.0, 10.0, 2.0, 20.0],
        )
        .unwrap();
        let mut x = Dense::zeros(Executor::reference(), Dim2::new(2, 2));
        csr_spmv(&a, &b, &mut x);
        assert_eq!(x.as_slice(), &[5.0, 50.0, 6.0, 60.0]);
    }

    #[test]
    fn fused_blas1_match_composed_bitwise() {
        let n = 37;
        let p: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let q: Vec<f64> = (0..n).map(|i| (i as f64 * 0.73).cos()).collect();
        let x0: Vec<f64> = (0..n).map(|i| 1.0 + i as f64 * 1e-3).collect();
        let r0: Vec<f64> = (0..n).map(|i| (i as f64 * 0.11).tan()).collect();

        // dot_norm2 == (dot, dot)
        let (xy, yy) = dot_norm2(&p, &q);
        assert_eq!(xy, dot(&p, &q));
        assert_eq!(yy, dot(&q, &q));

        // axpy_sub_norm2 == axpy + axpy(-a) + dot(r, r)
        let alpha = 0.8125f64;
        let (mut xf, mut rf) = (x0.clone(), r0.clone());
        let rr = axpy_sub_norm2(alpha, &p, &q, &mut xf, &mut rf);
        let (mut xc, mut rc) = (x0.clone(), r0.clone());
        axpy(alpha, &p, &mut xc);
        axpy(-alpha, &q, &mut rc);
        assert_eq!(xf, xc);
        assert_eq!(rf, rc);
        assert_eq!(rr, dot(&rc, &rc));

        // add_scaled == copy + axpy
        let mut of = vec![0.0f64; n];
        add_scaled(&r0, -alpha, &q, &mut of);
        let mut oc = r0.clone();
        axpy(-alpha, &q, &mut oc);
        assert_eq!(of, oc);

        // update_p == axpy(-omega, v, p) then axpby(1, r, beta, p)
        let (beta, omega) = (0.375f64, 1.5f64);
        let mut pf = x0.clone();
        update_p(&r0, beta, omega, &q, &mut pf);
        let mut pc = x0.clone();
        axpy(-omega, &q, &mut pc);
        axpby(1.0, &r0, beta, &mut pc);
        assert_eq!(pf, pc);
        let mut pz = x0.clone();
        update_p(&r0, 0.0, omega, &q, &mut pz);
        assert_eq!(pz, r0);

        // update_p_cgs == scal(beta) ... via t = q + beta p; p = u + beta t
        let mut gf = x0.clone();
        update_p_cgs(&p, beta, &q, &mut gf);
        let gc: Vec<f64> = (0..n)
            .map(|i| p[i] + beta * (q[i] + beta * x0[i]))
            .collect();
        assert_eq!(gf, gc);

        // sub_scaled_norm2 == add_scaled(-omega) + dot(r, r)
        let mut sf = vec![0.0f64; n];
        let srr = sub_scaled_norm2(&p, omega, &q, &mut sf);
        let mut sc = vec![0.0f64; n];
        add_scaled(&p, -omega, &q, &mut sc);
        assert_eq!(sf, sc);
        assert_eq!(srr, dot(&sc, &sc));

        // axpy2 == axpy(alpha, p) + axpy(omega, s)
        let mut af = x0.clone();
        axpy2(alpha, &p, omega, &q, &mut af);
        let mut ac = x0.clone();
        axpy(alpha, &p, &mut ac);
        axpy(omega, &q, &mut ac);
        assert_eq!(af, ac);

        // scal_into == copy + scal, incl. beta == 0 overwrite
        let mut zf = vec![f64::NAN; n];
        scal_into(beta, &p, &mut zf);
        let mut zc = p.clone();
        scal(beta, &mut zc);
        assert_eq!(zf, zc);
        let mut z0 = vec![f64::NAN; n];
        scal_into(0.0, &p, &mut z0);
        assert_eq!(z0, vec![0.0; n]);
    }

    #[test]
    fn fused_mgs_matches_composed_bitwise() {
        let n = 41;
        let basis_data: Vec<Vec<f64>> = (0..4)
            .map(|j| {
                (0..n)
                    .map(|i| (i as f64 * 0.17 + j as f64 * 0.61).sin())
                    .collect()
            })
            .collect();
        let basis: Vec<&[f64]> = basis_data.iter().map(|v| v.as_slice()).collect();
        let w0: Vec<f64> = (0..n).map(|i| (i as f64 * 0.29).cos()).collect();

        // dot_axpy == dot + axpy(-h)
        let mut wf = w0.clone();
        let hf = dot_axpy(basis[0], &mut wf);
        let mut wc = w0.clone();
        let hc = dot(&wc, basis[0]);
        axpy(-hc, basis[0], &mut wc);
        assert_eq!(hf, hc);
        assert_eq!(wf, wc);

        // mgs_project == the composed dot/axpy chain + trailing dot(w, w)
        for k in 0..=basis.len() {
            let mut wf = w0.clone();
            let mut hfv = vec![0.0f64; k];
            let ww = mgs_project(&basis[..k], &mut wf, &mut hfv);
            let mut wc = w0.clone();
            let mut hcv = vec![0.0f64; k];
            for (i, v) in basis[..k].iter().enumerate() {
                hcv[i] = dot(&wc, v);
                axpy(-hcv[i], v, &mut wc);
            }
            assert_eq!(hfv, hcv, "k = {k}");
            assert_eq!(wf, wc, "k = {k}");
            assert_eq!(ww, dot(&wc, &wc), "k = {k}");
        }

        // mgs_update == the composed axpy sequence over the block
        let y = [0.5f64, -1.25, 0.8125, 2.0];
        let mut xf = w0.clone();
        mgs_update(&basis, &y, &mut xf);
        let mut xc = w0.clone();
        for (j, v) in basis.iter().enumerate() {
            axpy(y[j], v, &mut xc);
        }
        assert_eq!(xf, xc);
    }

    #[test]
    fn fused_spmv_dot_matches_composed() {
        let mut d = MatrixData::<f64>::new(Dim2::square(5));
        for i in 0..5i32 {
            d.push(i, i, 4.0 + i as f64);
            if i > 0 {
                d.push(i, i - 1, -1.0 - 0.1 * i as f64);
            }
            if i < 4 {
                d.push(i, i + 1, -0.5);
            }
        }
        let exec = Executor::reference();
        let b = Dense::vector(exec.clone(), &[1.0, -2.0, 3.0, 0.25, -0.75]);
        let w = Dense::vector(exec.clone(), &[0.5, 1.5, -2.5, 3.5, -4.5]);

        let csr = Csr::from_data(exec.clone(), &d).unwrap();
        let mut xc = Dense::zeros(exec.clone(), Dim2::new(5, 1));
        csr.apply(&b, &mut xc).unwrap();
        let want_wx = dot(w.as_slice(), xc.as_slice());
        let want_xx = dot(xc.as_slice(), xc.as_slice());

        let mut xf = Dense::zeros(exec.clone(), Dim2::new(5, 1));
        let (wx, xx) = csr_spmv_dot(&csr, &b, &mut xf, &w);
        assert_eq!(xf.as_slice(), xc.as_slice());
        assert_eq!(wx, want_wx);
        assert_eq!(xx, want_xx);

        let ell = Ell::from_data(exec.clone(), &d).unwrap();
        let mut xe = Dense::zeros(exec.clone(), Dim2::new(5, 1));
        let (ewx, exx) = ell_spmv_dot(&ell, &b, &mut xe, &w);
        assert_eq!(xe.as_slice(), xc.as_slice());
        assert_eq!(ewx, want_wx);
        assert_eq!(exx, want_xx);

        let sellp = SellP::from_data(exec.clone(), &d).unwrap();
        let mut xs = Dense::zeros(exec.clone(), Dim2::new(5, 1));
        let (swx, sxx) = sellp_spmv_dot(&sellp, &b, &mut xs, &w);
        assert_eq!(xs.as_slice(), xc.as_slice());
        assert_eq!(swx, want_wx);
        assert_eq!(sxx, want_xx);
    }
}
