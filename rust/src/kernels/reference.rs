//! Sequential reference kernels (Ginkgo's `reference` backend).
//!
//! Deliberately simple: these define the semantics every other backend is
//! validated against. No blocking, no threading, no reordering beyond the
//! storage order — floating-point results are bit-deterministic.

use crate::core::linop::LinOp;
use crate::core::types::{IndexType, Value};
use crate::matrix::coo::Coo;
use crate::matrix::csr::Csr;
use crate::matrix::dense::Dense;
use crate::matrix::ell::Ell;
use crate::matrix::sellp::SellP;

// ---------------------------------------------------------------- BLAS-1

/// y += alpha * x (element-wise over the whole buffer).
pub fn axpy<T: Value>(alpha: T, x: &[T], y: &mut [T]) {
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// y = alpha * x + beta * y. `beta == 0` overwrites (no NaN propagation).
pub fn axpby<T: Value>(alpha: T, x: &[T], beta: T, y: &mut [T]) {
    if beta.is_zero() {
        for i in 0..x.len() {
            y[i] = alpha * x[i];
        }
    } else {
        for i in 0..x.len() {
            y[i] = alpha * x[i] + beta * y[i];
        }
    }
}

/// x *= beta; `beta == 0` fills with zero (Ginkgo semantics).
pub fn scal<T: Value>(beta: T, x: &mut [T]) {
    if beta.is_zero() {
        x.fill(T::zero());
    } else {
        for v in x.iter_mut() {
            *v *= beta;
        }
    }
}

/// Dot product over the whole buffer.
pub fn dot<T: Value>(x: &[T], y: &[T]) -> T {
    let mut acc = T::zero();
    for i in 0..x.len() {
        acc += x[i] * y[i];
    }
    acc
}

/// Euclidean norm.
pub fn norm2<T: Value>(x: &[T]) -> T {
    dot(x, x).sqrt()
}

/// z = x ⊙ y (element-wise product; Jacobi preconditioner apply).
pub fn ew_mul<T: Value>(x: &[T], y: &[T], z: &mut [T]) {
    for i in 0..x.len() {
        z[i] = x[i] * y[i];
    }
}

// ------------------------------------------------------------------ SpMV

/// CSR SpMV: x = A b (multi-rhs aware).
pub fn csr_spmv<T: Value>(a: &Csr<T>, b: &Dense<T>, x: &mut Dense<T>) {
    csr_spmv_advanced(T::one(), a, T::zero(), b, x);
}

/// CSR SpMV: x = alpha A b + beta x.
pub fn csr_spmv_advanced<T: Value>(alpha: T, a: &Csr<T>, beta: T, b: &Dense<T>, x: &mut Dense<T>) {
    let nrhs = b.shape().cols;
    let row_ptrs = a.row_ptrs();
    let col_idxs = a.col_idxs();
    let values = a.values();
    for i in 0..a.shape().rows {
        for c in 0..nrhs {
            let mut acc = T::zero();
            for k in row_ptrs[i] as usize..row_ptrs[i + 1] as usize {
                acc += values[k] * b.at(col_idxs[k] as usize, c);
            }
            let xv = x.at_mut(i, c);
            *xv = if beta.is_zero() {
                alpha * acc
            } else {
                alpha * acc + beta * *xv
            };
        }
    }
}

/// COO SpMV: x = A b. Requires row-sorted entries.
pub fn coo_spmv<T: Value>(a: &Coo<T>, b: &Dense<T>, x: &mut Dense<T>) {
    x.fill(T::zero());
    coo_spmv_accumulate(T::one(), a, b, x);
}

/// COO SpMV: x = alpha A b + beta x.
pub fn coo_spmv_advanced<T: Value>(alpha: T, a: &Coo<T>, beta: T, b: &Dense<T>, x: &mut Dense<T>) {
    scal(beta, x.as_mut_slice());
    coo_spmv_accumulate(alpha, a, b, x);
}

/// x += alpha A b — the COO accumulation core.
pub fn coo_spmv_accumulate<T: Value>(alpha: T, a: &Coo<T>, b: &Dense<T>, x: &mut Dense<T>) {
    let nrhs = b.shape().cols;
    for idx in 0..a.nnz() {
        let r = a.row_idxs()[idx] as usize;
        let c = a.col_idxs()[idx] as usize;
        let v = alpha * a.values()[idx];
        for j in 0..nrhs {
            *x.at_mut(r, j) += v * b.at(c, j);
        }
    }
}

/// ELL SpMV: x = A b. Column-major storage, zero-padded (col 0 / val 0).
pub fn ell_spmv<T: Value>(a: &Ell<T>, b: &Dense<T>, x: &mut Dense<T>) {
    let n = a.shape().rows;
    let nrhs = b.shape().cols;
    let k = a.stored_per_row();
    let cols = a.col_idxs();
    let vals = a.values();
    for i in 0..n {
        for c in 0..nrhs {
            let mut acc = T::zero();
            for j in 0..k {
                let pos = j * n + i;
                // padding has val == 0, so no branch needed
                acc += vals[pos] * b.at(cols[pos] as usize, c);
            }
            *x.at_mut(i, c) = acc;
        }
    }
}

/// SELL-P SpMV: x = A b.
pub fn sellp_spmv<T: Value>(a: &SellP<T>, b: &Dense<T>, x: &mut Dense<T>) {
    let n = a.shape().rows;
    let nrhs = b.shape().cols;
    let ss = a.slice_size();
    for s in 0..a.num_slices() {
        let width = a.slice_lengths[s];
        let base = a.slice_sets[s];
        for r in 0..ss {
            let i = s * ss + r;
            if i >= n {
                break;
            }
            for c in 0..nrhs {
                let mut acc = T::zero();
                for j in 0..width {
                    let pos = base + j * ss + r;
                    acc += a.values[pos] * b.at(a.col_idxs[pos] as usize, c);
                }
                *x.at_mut(i, c) = acc;
            }
        }
    }
}

/// Convert CSR row pointers to explicit row indices (COO expansion).
pub fn row_ptrs_to_idxs(row_ptrs: &[IndexType], nnz: usize) -> Vec<IndexType> {
    let mut rows = vec![0 as IndexType; nnz];
    for i in 0..row_ptrs.len() - 1 {
        for k in row_ptrs[i] as usize..row_ptrs[i + 1] as usize {
            rows[k] = i as IndexType;
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::dim::Dim2;
    use crate::core::executor::Executor;
    use crate::core::matrix_data::MatrixData;

    #[test]
    fn blas1_basics() {
        let x = [1.0f64, 2.0, 3.0];
        let mut y = [1.0f64, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        axpby(1.0, &x, 0.5, &mut y);
        assert_eq!(y, [2.5, 4.5, 6.5]);
        scal(0.0, &mut y);
        assert_eq!(y, [0.0, 0.0, 0.0]);
        assert_eq!(dot(&x, &x), 14.0);
        assert!((norm2(&x) - 14.0f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn axpby_beta_zero_kills_nan() {
        let x = [1.0f64];
        let mut y = [f64::NAN];
        axpby(3.0, &x, 0.0, &mut y);
        assert_eq!(y, [3.0]);
        let mut y = [f64::NAN];
        scal(0.0, &mut y);
        assert_eq!(y, [0.0]);
    }

    #[test]
    fn ew_mul_basics() {
        let mut z = [0.0f32; 3];
        ew_mul(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0], &mut z);
        assert_eq!(z, [4.0, 10.0, 18.0]);
    }

    #[test]
    fn row_ptr_expansion() {
        assert_eq!(row_ptrs_to_idxs(&[0, 2, 3, 5], 5), vec![0, 0, 1, 2, 2]);
        assert_eq!(row_ptrs_to_idxs(&[0, 0, 0, 2], 2), vec![2, 2]);
    }

    #[test]
    fn csr_advanced_beta_zero_kills_nan() {
        let d = MatrixData::from_triplets(Dim2::square(2), &[0, 1], &[0, 1], &[1.0, 1.0])
            .unwrap();
        let a = Csr::from_data(Executor::reference(), &d).unwrap();
        let b = Dense::vector(Executor::reference(), &[2.0, 3.0]);
        let mut x = Dense::vector(Executor::reference(), &[f64::NAN, f64::NAN]);
        csr_spmv_advanced(1.0, &a, 0.0, &b, &mut x);
        assert_eq!(x.as_slice(), &[2.0, 3.0]);
    }

    #[test]
    fn multi_rhs_spmv() {
        // A = [[1, 2], [0, 3]], B = [[1, 10], [2, 20]]
        let d = MatrixData::from_triplets(
            Dim2::square(2),
            &[0, 0, 1],
            &[0, 1, 1],
            &[1.0, 2.0, 3.0],
        )
        .unwrap();
        let a = Csr::from_data(Executor::reference(), &d).unwrap();
        let b = Dense::from_vec(
            Executor::reference(),
            Dim2::new(2, 2),
            vec![1.0, 10.0, 2.0, 20.0],
        )
        .unwrap();
        let mut x = Dense::zeros(Executor::reference(), Dim2::new(2, 2));
        csr_spmv(&a, &b, &mut x);
        assert_eq!(x.as_slice(), &[5.0, 50.0, 6.0, 60.0]);
    }
}
