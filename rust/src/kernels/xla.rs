//! XLA backend kernels — the "ported backend" of the reproduction.
//!
//! Where the paper ports Ginkgo's CUDA kernels to DPC++, this backend
//! re-expresses them as AOT-compiled JAX/Pallas artifacts executed through
//! PJRT. Shapes are static, so every call pads its operands to the next
//! artifact bucket (see `runtime::bucket`); padding is arithmetic-neutral
//! (zero values, index-0 columns/rows pointing at padded zero data).
//!
//! Oversized operands are *chunked*: COO nonzeros are split across
//! repeated accumulating launches, ELL widths across width-chunks. Vector
//! length is bounded by the largest lowered bucket — matrices larger than
//! that run on the `par` executor (the benches obey this; the perf model
//! covers full-size projections).

use crate::core::error::{Result, SparkleError};
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::matrix::coo::Coo;
use crate::matrix::csr::Csr;
use crate::matrix::dense::Dense;
use crate::matrix::ell::Ell;
use crate::runtime::bucket::pad_to;
use crate::runtime::{Arg, XlaRuntime};

// ---------------------------------------------------------------- BLAS-1

/// y += alpha * x.
pub fn axpy<T: Value>(rt: &XlaRuntime, alpha: T, x: &[T], y: &mut [T]) -> Result<()> {
    run_ew(rt, "axpy", &[Arg::Scalar(alpha)], x, y)
}

/// y = alpha * x + beta * y.
pub fn axpby<T: Value>(rt: &XlaRuntime, alpha: T, x: &[T], beta: T, y: &mut [T]) -> Result<()> {
    run_ew(rt, "axpby", &[Arg::Scalar(alpha), Arg::Scalar(beta)], x, y)
}

/// Shared launcher for element-wise artifacts `f(scalars..., x, y) -> y'`.
/// Chunks inputs longer than the largest bucket.
fn run_ew<T: Value>(
    rt: &XlaRuntime,
    kernel: &str,
    scalars: &[Arg<'_, T>],
    x: &[T],
    y: &mut [T],
) -> Result<()> {
    debug_assert_eq!(x.len(), y.len());
    let family = rt.manifest().family(kernel, T::PRECISION);
    let max_n = family.last().map(|a| a.n).unwrap_or(0);
    if max_n == 0 {
        return Err(SparkleError::Runtime(format!(
            "no `{kernel}` artifacts at {} — run `make artifacts`",
            T::PRECISION
        )));
    }
    let mut off = 0;
    while off < x.len() {
        let len = (x.len() - off).min(max_n);
        let meta = rt.select(kernel, T::PRECISION, len, 0, 0)?;
        let xp = pad_to(&x[off..off + len], meta.n, T::zero());
        let yp = pad_to(&y[off..off + len], meta.n, T::zero());
        let mut args: Vec<Arg<'_, T>> = Vec::with_capacity(scalars.len() + 2);
        for s in scalars {
            args.push(match s {
                Arg::Scalar(v) => Arg::Scalar(*v),
                _ => unreachable!("run_ew scalars must be Arg::Scalar"),
            });
        }
        args.push(Arg::vec(&xp));
        args.push(Arg::vec(&yp));
        let out = rt.run::<T>(&meta.name, &args)?;
        y[off..off + len].copy_from_slice(&out[0][..len]);
        off += len;
    }
    Ok(())
}

/// x *= beta.
pub fn scal<T: Value>(rt: &XlaRuntime, beta: T, x: &mut [T]) -> Result<()> {
    let family = rt.manifest().family("scal", T::PRECISION);
    let max_n = family.last().map(|a| a.n).unwrap_or(0);
    if max_n == 0 {
        return Err(SparkleError::Runtime(
            "no `scal` artifacts — run `make artifacts`".into(),
        ));
    }
    let mut off = 0;
    while off < x.len() {
        let len = (x.len() - off).min(max_n);
        let meta = rt.select("scal", T::PRECISION, len, 0, 0)?;
        let xp = pad_to(&x[off..off + len], meta.n, T::zero());
        let out = rt.run::<T>(&meta.name, &[Arg::Scalar(beta), Arg::vec(&xp)])?;
        x[off..off + len].copy_from_slice(&out[0][..len]);
        off += len;
    }
    Ok(())
}

/// Dot product (chunked accumulation on host across buckets).
pub fn dot<T: Value>(rt: &XlaRuntime, x: &[T], y: &[T]) -> Result<T> {
    debug_assert_eq!(x.len(), y.len());
    let family = rt.manifest().family("dot", T::PRECISION);
    let max_n = family.last().map(|a| a.n).unwrap_or(0);
    if max_n == 0 {
        return Err(SparkleError::Runtime(
            "no `dot` artifacts — run `make artifacts`".into(),
        ));
    }
    let mut acc = T::zero();
    let mut off = 0;
    while off < x.len() {
        let len = (x.len() - off).min(max_n);
        let meta = rt.select("dot", T::PRECISION, len, 0, 0)?;
        let xp = pad_to(&x[off..off + len], meta.n, T::zero());
        let yp = pad_to(&y[off..off + len], meta.n, T::zero());
        let out = rt.run::<T>(&meta.name, &[Arg::vec(&xp), Arg::vec(&yp)])?;
        acc += out[0][0];
        off += len;
    }
    Ok(acc)
}

/// Euclidean norm (dot + host sqrt; zero padding is norm-neutral).
pub fn norm2<T: Value>(rt: &XlaRuntime, x: &[T]) -> Result<T> {
    Ok(dot(rt, x, x)?.sqrt())
}

/// z = x ⊙ y.
pub fn ew_mul<T: Value>(rt: &XlaRuntime, x: &[T], y: &[T], z: &mut [T]) -> Result<()> {
    // reuse axpby-shaped launcher: mul artifact is f(x, y) -> x*y
    debug_assert_eq!(x.len(), z.len());
    let family = rt.manifest().family("ew_mul", T::PRECISION);
    let max_n = family.last().map(|a| a.n).unwrap_or(0);
    if max_n == 0 {
        return Err(SparkleError::Runtime(
            "no `ew_mul` artifacts — run `make artifacts`".into(),
        ));
    }
    let mut off = 0;
    while off < x.len() {
        let len = (x.len() - off).min(max_n);
        let meta = rt.select("ew_mul", T::PRECISION, len, 0, 0)?;
        let xp = pad_to(&x[off..off + len], meta.n, T::zero());
        let yp = pad_to(&y[off..off + len], meta.n, T::zero());
        let out = rt.run::<T>(&meta.name, &[Arg::vec(&xp), Arg::vec(&yp)])?;
        z[off..off + len].copy_from_slice(&out[0][..len]);
        off += len;
    }
    Ok(())
}

// ------------------------------------------------------------------ SpMV

/// ELL SpMV: x = alpha A b + beta x (single rhs).
///
/// The artifact (`ell_adv`) is the Pallas row-slice kernel; storage is
/// column-major `(k, n)` which maps 1:1 onto the kernel's `(k, n)` blocks.
/// Width chunks accumulate via repeated launches when `k` exceeds the
/// largest lowered width bucket.
pub fn ell_spmv_advanced<T: Value>(
    rt: &XlaRuntime,
    alpha: T,
    a: &Ell<T>,
    beta: T,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    if b.shape().cols != 1 {
        return Err(SparkleError::NotSupported {
            op: "xla ell multi-rhs",
            exec: "xla",
        });
    }
    let n = a.shape().rows;
    let ncols = a.shape().cols;
    let k = a.stored_per_row();
    let family = rt.manifest().family("ell_adv", T::PRECISION);
    let max_k = family.iter().map(|m| m.k).max().unwrap_or(0);
    if max_k == 0 {
        return Err(SparkleError::Runtime(
            "no `ell_adv` artifacts — run `make artifacts`".into(),
        ));
    }
    // b is gathered by column index, so the padded b must cover ncols.
    let need_n = n.max(ncols);

    // single-bucket fast path: the padded (k_b, n_b) matrix arrays are
    // built once and cached on the matrix (L3 perf iteration 3 —
    // re-padding ~2 k·n values per apply dominated solver loops)
    if k <= max_k {
        let meta = rt.select("ell_adv", T::PRECISION, need_n, k.max(1), 0)?;
        let (mk, mn) = (meta.k, meta.n);
        let name = meta.name.clone();
        // build the padded matrix operands ON DEVICE, once
        let cache = {
            let cached = a.padded_cache.get();
            match cached {
                Some(c) => c.clone(),
                None => {
                    let mut vals = vec![T::zero(); mk * mn];
                    let mut cols = vec![0i32; mk * mn];
                    for j in 0..k {
                        let src = j * n;
                        vals[j * mn..j * mn + n]
                            .copy_from_slice(&a.values()[src..src + n]);
                        cols[j * mn..j * mn + n]
                            .copy_from_slice(&a.col_idxs()[src..src + n]);
                    }
                    let vbuf = rt.to_device(&vals, &[mk, mn])?;
                    let cbuf = rt.to_device(&cols, &[mk, mn])?;
                    let arc = std::sync::Arc::new((mk, mn, vbuf, cbuf));
                    let _ = a.padded_cache.set(arc.clone());
                    arc
                }
            }
        };
        debug_assert_eq!((cache.0, cache.1), (mk, mn), "bucket selection must be stable");
        let bp = pad_to(&b.as_slice()[..ncols], mn, T::zero());
        let xp = pad_to(&x.as_slice()[..n], mn, T::zero());
        let alpha_b = rt.to_device(&[alpha], &[])?;
        let beta_b = rt.to_device(&[beta], &[])?;
        let b_b = rt.to_device(&bp, &[mn])?;
        let x_b = rt.to_device(&xp, &[mn])?;
        let out = rt.run_buffers::<T>(
            &name,
            &[&alpha_b, &cache.2, &cache.3, &b_b, &beta_b, &x_b],
        )?;
        x.as_mut_slice()[..n].copy_from_slice(&out[0][..n]);
        return Ok(());
    }

    // width-chunked slow path (k exceeds every lowered width bucket)
    let mut j0 = 0;
    let mut beta_eff = beta;
    loop {
        let kchunk = (k - j0).min(max_k).max(1);
        let meta = rt.select("ell_adv", T::PRECISION, need_n, kchunk, 0)?;
        // pad the (kchunk, n) column-major block to (meta.k, meta.n)
        let mut vals = vec![T::zero(); meta.k * meta.n];
        let mut cols = vec![0i32; meta.k * meta.n];
        for j in 0..kchunk {
            let src = (j0 + j) * n;
            vals[j * meta.n..j * meta.n + n].copy_from_slice(&a.values()[src..src + n]);
            cols[j * meta.n..j * meta.n + n].copy_from_slice(&a.col_idxs()[src..src + n]);
        }
        let bp = pad_to(&b.as_slice()[..ncols], meta.n, T::zero());
        let xp = pad_to(&x.as_slice()[..n], meta.n, T::zero());
        let out = rt.run::<T>(
            &meta.name,
            &[
                Arg::Scalar(alpha),
                Arg::mat(&vals, meta.k, meta.n),
                Arg::idx_mat(&cols, meta.k, meta.n),
                Arg::vec(&bp),
                Arg::Scalar(beta_eff),
                Arg::vec(&xp),
            ],
        )?;
        x.as_mut_slice()[..n].copy_from_slice(&out[0][..n]);
        j0 += kchunk;
        if j0 >= k {
            break;
        }
        beta_eff = T::one(); // subsequent width-chunks accumulate
    }
    Ok(())
}

/// COO SpMV: x = alpha A b + beta x (single rhs). Oversized nnz is
/// chunked across accumulating launches (`beta = 1` after the first).
pub fn coo_spmv_advanced<T: Value>(
    rt: &XlaRuntime,
    alpha: T,
    a: &Coo<T>,
    beta: T,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    if b.shape().cols != 1 {
        return Err(SparkleError::NotSupported {
            op: "xla coo multi-rhs",
            exec: "xla",
        });
    }
    // single-bucket fast path with cached padded triplet arrays
    // (L3 perf iteration 3)
    let nrows = a.shape().rows;
    let ncols = a.shape().cols;
    let need_n = nrows.max(ncols);
    if let Ok(meta) = rt.select("coo_adv", T::PRECISION, need_n, 0, a.nnz().max(1)) {
        let (mnnz, mn) = (meta.nnz, meta.n);
        let name = meta.name.clone();
        let cache = match a.padded_cache.get() {
            Some(c) => c.clone(),
            None => {
                let rows_p = pad_to(a.row_idxs(), mnnz, 0i32);
                let cols_p = pad_to(a.col_idxs(), mnnz, 0i32);
                let vals_p = pad_to(a.values(), mnnz, T::zero());
                let arc = std::sync::Arc::new((
                    mnnz,
                    rt.to_device(&rows_p, &[mnnz])?,
                    rt.to_device(&cols_p, &[mnnz])?,
                    rt.to_device(&vals_p, &[mnnz])?,
                ));
                let _ = a.padded_cache.set(arc.clone());
                arc
            }
        };
        debug_assert_eq!(cache.0, mnnz, "bucket selection must be stable");
        let bp = pad_to(&b.as_slice()[..ncols], mn, T::zero());
        let xp = pad_to(&x.as_slice()[..nrows], mn, T::zero());
        let alpha_b = rt.to_device(&[alpha], &[])?;
        let beta_b = rt.to_device(&[beta], &[])?;
        let b_b = rt.to_device(&bp, &[mn])?;
        let x_b = rt.to_device(&xp, &[mn])?;
        let out = rt.run_buffers::<T>(
            &name,
            &[&alpha_b, &cache.3, &cache.1, &cache.2, &b_b, &beta_b, &x_b],
        )?;
        x.as_mut_slice()[..nrows].copy_from_slice(&out[0][..nrows]);
        return Ok(());
    }
    coo_arrays_spmv_advanced(
        rt,
        alpha,
        a.row_idxs(),
        a.col_idxs(),
        a.values(),
        a.shape().rows,
        a.shape().cols,
        beta,
        b,
        x,
    )
}

/// CSR SpMV on the XLA executor: the row pointers are expanded to
/// explicit row indices and dispatched to the COO segment-sum artifact.
/// Numerically identical to row-wise CSR; the perf model accounts true
/// CSR traffic separately (see `perfmodel::traffic`).
pub fn csr_spmv_advanced<T: Value>(
    rt: &XlaRuntime,
    alpha: T,
    a: &Csr<T>,
    beta: T,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    if b.shape().cols != 1 {
        return Err(SparkleError::NotSupported {
            op: "xla csr multi-rhs",
            exec: "xla",
        });
    }
    coo_arrays_spmv_advanced(
        rt,
        alpha,
        a.expanded_rows(),
        a.col_idxs(),
        a.values(),
        a.shape().rows,
        a.shape().cols,
        beta,
        b,
        x,
    )
}

#[allow(clippy::too_many_arguments)]
fn coo_arrays_spmv_advanced<T: Value>(
    rt: &XlaRuntime,
    alpha: T,
    rows: &[i32],
    cols: &[i32],
    vals: &[T],
    nrows: usize,
    ncols: usize,
    beta: T,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    let nnz = vals.len();
    let need_n = nrows.max(ncols);
    let max_meta = rt
        .manifest()
        .max_nnz_at("coo_adv", T::PRECISION, need_n)
        .ok_or_else(|| {
            SparkleError::Runtime(format!(
                "no `coo_adv` artifact covers n={need_n} at {} — run `make artifacts` \
                 or use the par executor for matrices this large",
                T::PRECISION
            ))
        })?;
    let max_nnz = max_meta.nnz;
    let mut off = 0;
    let mut beta_eff = beta;
    loop {
        let chunk = (nnz - off).min(max_nnz);
        let meta = rt.select("coo_adv", T::PRECISION, need_n, 0, chunk.max(1))?;
        let rp = pad_to(&rows[off..off + chunk], meta.nnz, 0i32);
        let cp = pad_to(&cols[off..off + chunk], meta.nnz, 0i32);
        let vp = pad_to(&vals[off..off + chunk], meta.nnz, T::zero());
        let bp = pad_to(&b.as_slice()[..ncols], meta.n, T::zero());
        let xp = pad_to(&x.as_slice()[..nrows], meta.n, T::zero());
        let out = rt.run::<T>(
            &meta.name,
            &[
                Arg::Scalar(alpha),
                Arg::vec(&vp),
                Arg::idx(&rp),
                Arg::idx(&cp),
                Arg::vec(&bp),
                Arg::Scalar(beta_eff),
                Arg::vec(&xp),
            ],
        )?;
        x.as_mut_slice()[..nrows].copy_from_slice(&out[0][..nrows]);
        off += chunk;
        if off >= nnz {
            break;
        }
        beta_eff = T::one();
    }
    Ok(())
}
