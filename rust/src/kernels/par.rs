//! Multithreaded host kernels (Ginkgo's `omp` backend analog).
//!
//! Parallelization strategy mirrors the OpenMP kernels of the paper's
//! library: BLAS-1 splits the index space, row-based SpMV splits output
//! rows (no atomics needed), COO splits the nonzero range on *row
//! boundaries* so each thread owns disjoint output rows.
//!
//! All kernels work on raw slices: matrix/vector structs contain an
//! `Arc<Executor>` (non-`Sync` because of the PJRT client), so the
//! dispatch layer unpacks them before entering scoped threads.

use crate::core::executor::{par_for, ParConfig};
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::kernels::reference;
use crate::matrix::coo::Coo;
use crate::matrix::csr::Csr;
use crate::matrix::dense::Dense;
use crate::matrix::ell::Ell;
use crate::matrix::sellp::SellP;
use crate::vendor_mkl::merge_row_splits;

use crate::kernels::ptr::SlicePtr;

// ------------------------------------------------ deterministic reduce
//
// Reductions accumulate per fixed-size block (REDUCE_BLOCK elements),
// then combine the block partials with a sequential pairwise tree. The
// block boundaries depend only on the vector length — never on the
// thread count — so the same input gives the bit-identical result under
// `threads` = 1, 2 or 64. Threads only race to *fill* disjoint partial
// slots, which is order-independent.

const REDUCE_BLOCK: usize = 4096;

/// Sequential in-place pairwise fold of block partials.
fn tree_fold<T: Value>(v: &mut [T]) -> T {
    let mut len = v.len();
    if len == 0 {
        return T::zero();
    }
    while len > 1 {
        let half = len / 2;
        for i in 0..half {
            v[i] = v[2 * i] + v[2 * i + 1];
        }
        if len % 2 == 1 {
            v[half] = v[len - 1];
            len = half + 1;
        } else {
            len = half;
        }
    }
    v[0]
}

/// Blocked deterministic reduction: `block(s, e)` computes the partial
/// for elements `[s, e)`; partials are combined in fixed tree order.
fn blocked_reduce<T: Value>(
    cfg: &ParConfig,
    n: usize,
    block: impl Fn(usize, usize) -> T + Sync,
) -> T {
    if n == 0 {
        return T::zero();
    }
    let nblocks = n.div_ceil(REDUCE_BLOCK);
    let mut partials = vec![T::zero(); nblocks];
    let fill = |b0: usize, b1: usize, out: &mut [T]| {
        for (slot, bk) in out.iter_mut().zip(b0..b1) {
            let s = bk * REDUCE_BLOCK;
            let e = (s + REDUCE_BLOCK).min(n);
            *slot = block(s, e);
        }
    };
    if cfg.effective_threads() <= 1 || n <= cfg.seq_threshold || nblocks == 1 {
        fill(0, nblocks, &mut partials);
    } else {
        // gate on n (not nblocks) ourselves, then let par_for split blocks
        let inner = ParConfig {
            threads: cfg.effective_threads(),
            seq_threshold: 0,
        };
        let pptr = SlicePtr(partials.as_mut_ptr());
        par_for(&inner, nblocks, |_, b0, b1| {
            // SAFETY: block index ranges are disjoint across threads.
            fill(b0, b1, unsafe { pptr.range(b0, b1 - b0) });
        });
    }
    tree_fold(&mut partials)
}

/// Like [`blocked_reduce`] but for kernels producing two reductions per
/// sweep (e.g. `dot_norm2`). Both results are thread-count independent.
fn blocked_reduce2<T: Value>(
    cfg: &ParConfig,
    n: usize,
    block: impl Fn(usize, usize) -> (T, T) + Sync,
) -> (T, T) {
    if n == 0 {
        return (T::zero(), T::zero());
    }
    let nblocks = n.div_ceil(REDUCE_BLOCK);
    let mut pa = vec![T::zero(); nblocks];
    let mut pb = vec![T::zero(); nblocks];
    let fill = |b0: usize, b1: usize, oa: &mut [T], ob: &mut [T]| {
        for (i, bk) in (b0..b1).enumerate() {
            let s = bk * REDUCE_BLOCK;
            let e = (s + REDUCE_BLOCK).min(n);
            let (u, v) = block(s, e);
            oa[i] = u;
            ob[i] = v;
        }
    };
    if cfg.effective_threads() <= 1 || n <= cfg.seq_threshold || nblocks == 1 {
        fill(0, nblocks, &mut pa, &mut pb);
    } else {
        let inner = ParConfig {
            threads: cfg.effective_threads(),
            seq_threshold: 0,
        };
        let aptr = SlicePtr(pa.as_mut_ptr());
        let bptr = SlicePtr(pb.as_mut_ptr());
        par_for(&inner, nblocks, |_, b0, b1| {
            // SAFETY: block index ranges are disjoint across threads.
            let oa = unsafe { aptr.range(b0, b1 - b0) };
            let ob = unsafe { bptr.range(b0, b1 - b0) };
            fill(b0, b1, oa, ob);
        });
    }
    (tree_fold(&mut pa), tree_fold(&mut pb))
}

// ---------------------------------------------------------------- BLAS-1

/// y += alpha * x, split across threads.
pub fn axpy<T: Value>(cfg: &ParConfig, alpha: T, x: &[T], y: &mut [T]) {
    let ptr = SlicePtr(y.as_mut_ptr());
    par_for(cfg, x.len(), |_, s, e| {
        // SAFETY: [s, e) ranges are disjoint across threads.
        let y = unsafe { ptr.range(s, e - s) };
        reference::axpy(alpha, &x[s..e], y);
    });
}

/// y = alpha * x + beta * y.
pub fn axpby<T: Value>(cfg: &ParConfig, alpha: T, x: &[T], beta: T, y: &mut [T]) {
    let ptr = SlicePtr(y.as_mut_ptr());
    par_for(cfg, x.len(), |_, s, e| {
        let y = unsafe { ptr.range(s, e - s) };
        reference::axpby(alpha, &x[s..e], beta, y);
    });
}

/// x *= beta.
pub fn scal<T: Value>(cfg: &ParConfig, beta: T, x: &mut [T]) {
    let n = x.len();
    let ptr = SlicePtr(x.as_mut_ptr());
    par_for(cfg, n, |_, s, e| {
        let x = unsafe { ptr.range(s, e - s) };
        reference::scal(beta, x);
    });
}

/// Dot product. Partials accumulate per fixed 4096-element block and
/// combine in a sequential pairwise tree, so the result is bit-identical
/// for *any* `ParConfig` thread count (determinism regression-tested).
pub fn dot<T: Value>(cfg: &ParConfig, x: &[T], y: &[T]) -> T {
    blocked_reduce(cfg, x.len(), |s, e| reference::dot(&x[s..e], &y[s..e]))
}

/// Euclidean norm.
pub fn norm2<T: Value>(cfg: &ParConfig, x: &[T]) -> T {
    dot(cfg, x, x).sqrt()
}

/// z = x ⊙ y.
pub fn ew_mul<T: Value>(cfg: &ParConfig, x: &[T], y: &[T], z: &mut [T]) {
    let ptr = SlicePtr(z.as_mut_ptr());
    par_for(cfg, x.len(), |_, s, e| {
        let z = unsafe { ptr.range(s, e - s) };
        reference::ew_mul(&x[s..e], &y[s..e], z);
    });
}

// ---------------------------------------------------------- fused BLAS-1
//
// Same contracts as the `reference` fused kernels; block partials use
// the exact blocks `dot` uses, so fused == composed bitwise on this
// backend too, and every reduction is thread-count independent.

/// `(x·y, y·y)` in one sweep.
pub fn dot_norm2<T: Value>(cfg: &ParConfig, x: &[T], y: &[T]) -> (T, T) {
    blocked_reduce2(cfg, x.len(), |s, e| reference::dot_norm2(&x[s..e], &y[s..e]))
}

/// `x += alpha p; r -= alpha q; return r·r` in one sweep.
pub fn axpy_sub_norm2<T: Value>(
    cfg: &ParConfig,
    alpha: T,
    p: &[T],
    q: &[T],
    x: &mut [T],
    r: &mut [T],
) -> T {
    let xptr = SlicePtr(x.as_mut_ptr());
    let rptr = SlicePtr(r.as_mut_ptr());
    blocked_reduce(cfg, p.len(), |s, e| {
        // SAFETY: reduce blocks are disjoint across threads.
        let xs = unsafe { xptr.range(s, e - s) };
        let rs = unsafe { rptr.range(s, e - s) };
        reference::axpy_sub_norm2(alpha, &p[s..e], &q[s..e], xs, rs)
    })
}

/// `out = z + alpha x` in one sweep.
pub fn add_scaled<T: Value>(cfg: &ParConfig, z: &[T], alpha: T, x: &[T], out: &mut [T]) {
    let ptr = SlicePtr(out.as_mut_ptr());
    par_for(cfg, z.len(), |_, s, e| {
        let o = unsafe { ptr.range(s, e - s) };
        reference::add_scaled(&z[s..e], alpha, &x[s..e], o);
    });
}

/// BiCGSTAB direction update `p = r + beta (p - omega v)` in one sweep.
pub fn update_p<T: Value>(cfg: &ParConfig, r: &[T], beta: T, omega: T, v: &[T], p: &mut [T]) {
    let ptr = SlicePtr(p.as_mut_ptr());
    par_for(cfg, r.len(), |_, s, e| {
        let ps = unsafe { ptr.range(s, e - s) };
        reference::update_p(&r[s..e], beta, omega, &v[s..e], ps);
    });
}

/// CGS direction update `p = u + beta (q + beta p)` in one sweep.
pub fn update_p_cgs<T: Value>(cfg: &ParConfig, u: &[T], beta: T, q: &[T], p: &mut [T]) {
    let ptr = SlicePtr(p.as_mut_ptr());
    par_for(cfg, u.len(), |_, s, e| {
        let ps = unsafe { ptr.range(s, e - s) };
        reference::update_p_cgs(&u[s..e], beta, &q[s..e], ps);
    });
}

/// `r = s - omega t; return r·r` in one sweep.
pub fn sub_scaled_norm2<T: Value>(cfg: &ParConfig, s: &[T], omega: T, t: &[T], r: &mut [T]) -> T {
    let rptr = SlicePtr(r.as_mut_ptr());
    blocked_reduce(cfg, s.len(), |b0, b1| {
        // SAFETY: reduce blocks are disjoint across threads.
        let rs = unsafe { rptr.range(b0, b1 - b0) };
        reference::sub_scaled_norm2(&s[b0..b1], omega, &t[b0..b1], rs)
    })
}

/// Two stacked axpys `x += alpha p; x += omega s` in one sweep.
pub fn axpy2<T: Value>(cfg: &ParConfig, alpha: T, p: &[T], omega: T, s: &[T], x: &mut [T]) {
    let ptr = SlicePtr(x.as_mut_ptr());
    par_for(cfg, p.len(), |_, b0, b1| {
        let xs = unsafe { ptr.range(b0, b1 - b0) };
        reference::axpy2(alpha, &p[b0..b1], omega, &s[b0..b1], xs);
    });
}

/// `out = beta * x` (overwrite; `beta == 0` writes zeros, no NaN leak).
pub fn scal_into<T: Value>(cfg: &ParConfig, beta: T, x: &[T], out: &mut [T]) {
    let ptr = SlicePtr(out.as_mut_ptr());
    par_for(cfg, x.len(), |_, s, e| {
        let o = unsafe { ptr.range(s, e - s) };
        reference::scal_into(beta, &x[s..e], o);
    });
}

/// Fused MGS projection pair `h = <w, v>; w -= h·v` (one blocked
/// reduction plus one split update sweep).
pub fn dot_axpy<T: Value>(cfg: &ParConfig, v: &[T], w: &mut [T]) -> T {
    let h = dot(cfg, w, v);
    axpy(cfg, -h, v, w);
    h
}

/// Full MGS sweep of `w` against the basis block, returning `<w, w>` of
/// the remainder. Each pipelined stage runs as one blocked reduction on
/// the exact blocks `dot` uses: the elementwise subtraction is split-
/// invisible and the partials combine in the fixed tree order, so the
/// result is bit-identical to the composed `dot`/`axpy` chain for any
/// thread count.
pub fn mgs_project<T: Value>(cfg: &ParConfig, basis: &[&[T]], w: &mut [T], h: &mut [T]) -> T {
    let k = basis.len();
    if k == 0 {
        return dot(cfg, w, w);
    }
    h[0] = dot(cfg, w, basis[0]);
    let n = w.len();
    let wptr = SlicePtr(w.as_mut_ptr());
    for i in 1..k {
        let hp = h[i - 1];
        let (vp, vi) = (basis[i - 1], basis[i]);
        h[i] = blocked_reduce(cfg, n, |s, e| {
            // SAFETY: reduce blocks are disjoint across threads.
            let ws = unsafe { wptr.range(s, e - s) };
            reference::mgs_step(hp, &vp[s..e], &vi[s..e], ws)
        });
    }
    let hl = h[k - 1];
    let vl = basis[k - 1];
    blocked_reduce(cfg, n, |s, e| {
        // SAFETY: reduce blocks are disjoint across threads.
        let ws = unsafe { wptr.range(s, e - s) };
        reference::mgs_finish(hl, &vl[s..e], ws)
    })
}

/// Batched basis update `x += Σ_j y_j·v_j`, rows split across threads.
pub fn mgs_update<T: Value>(cfg: &ParConfig, basis: &[&[T]], y: &[T], x: &mut [T]) {
    let ptr = SlicePtr(x.as_mut_ptr());
    par_for(cfg, x.len(), |_, s, e| {
        let xs = unsafe { ptr.range(s, e - s) };
        for (off, xe) in xs.iter_mut().enumerate() {
            let mut acc = *xe;
            for (v, &c) in basis.iter().zip(y) {
                acc += c * v[s + off];
            }
            *xe = acc;
        }
    });
}

// ------------------------------------------------------------------ SpMV

/// CSR SpMV, rows split across threads at merge-grid diagonals so each
/// thread owns roughly equal *work* (rows + nonzeros, whole rows only).
/// A power-law row no longer serializes its neighbors' chunks. Results
/// are bit-identical to the reference kernel for any split.
pub fn csr_spmv_advanced<T: Value>(
    cfg: &ParConfig,
    alpha: T,
    a: &Csr<T>,
    beta: T,
    b: &Dense<T>,
    x: &mut Dense<T>,
) {
    let nrhs = b.shape().cols;
    let nrows = a.shape().rows;
    let nnz = a.nnz();
    let row_ptrs = a.row_ptrs();
    let col_idxs = a.col_idxs();
    let values = a.values();
    let bs = b.as_slice();
    let xptr = SlicePtr(x.as_mut_slice().as_mut_ptr());
    let row_range = |rs: usize, re: usize| {
        for i in rs..re {
            for c in 0..nrhs {
                let mut acc = T::zero();
                for k in row_ptrs[i] as usize..row_ptrs[i + 1] as usize {
                    acc += values[k] * bs[col_idxs[k] as usize * nrhs + c];
                }
                // SAFETY: row ranges are disjoint across threads.
                let xv = unsafe { xptr.at(i * nrhs + c) };
                *xv = if beta.is_zero() {
                    alpha * acc
                } else {
                    alpha * acc + beta * *xv
                };
            }
        }
    };
    let threads = cfg.effective_threads().max(1);
    if threads == 1 || nrows <= cfg.seq_threshold || nnz == 0 {
        row_range(0, nrows);
        return;
    }
    let splits = merge_row_splits(row_ptrs, nnz, threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let (rs, re) = (splits[t], splits[t + 1]);
            if rs >= re {
                continue;
            }
            let row_range = &row_range;
            s.spawn(move || row_range(rs, re));
        }
    });
}

/// COO SpMV (x = alpha A b + beta x), nnz split on row boundaries.
pub fn coo_spmv_advanced<T: Value>(
    cfg: &ParConfig,
    alpha: T,
    a: &Coo<T>,
    beta: T,
    b: &Dense<T>,
    x: &mut Dense<T>,
) {
    scal(cfg, beta, x.as_mut_slice());
    let nnz = a.nnz();
    if nnz == 0 {
        return;
    }
    let nrhs = b.shape().cols;
    let rows = a.row_idxs();
    let cols = a.col_idxs();
    let vals = a.values();
    let bs = b.as_slice();
    let threads = cfg.effective_threads().max(1);
    // Split [0, nnz) into ranges aligned to row boundaries: thread t owns
    // entries [starts[t], starts[t+1]) and therefore disjoint output rows.
    let chunk = nnz.div_ceil(threads);
    let mut starts = Vec::with_capacity(threads + 1);
    starts.push(0usize);
    for t in 1..threads {
        let mut pos = (t * chunk).min(nnz);
        // advance to the first entry of the next row so rows never split
        while pos < nnz && pos > 0 && rows[pos] == rows[pos - 1] {
            pos += 1;
        }
        let pos = pos.max(*starts.last().unwrap());
        starts.push(pos);
    }
    starts.push(nnz);
    let xptr = SlicePtr(x.as_mut_slice().as_mut_ptr());
    std::thread::scope(|s| {
        for t in 0..threads {
            let (lo, hi) = (starts[t], starts[t + 1]);
            if lo >= hi {
                continue;
            }
            let xptr = &xptr;
            s.spawn(move || {
                for idx in lo..hi {
                    let r = rows[idx] as usize;
                    let v = alpha * vals[idx];
                    for j in 0..nrhs {
                        // SAFETY: row ranges are disjoint across threads
                        // (chunk boundaries aligned to row changes).
                        let xv = unsafe { xptr.at(r * nrhs + j) };
                        *xv += v * bs[cols[idx] as usize * nrhs + j];
                    }
                }
            });
        }
    });
}

/// ELL SpMV, rows split across threads.
pub fn ell_spmv<T: Value>(cfg: &ParConfig, a: &Ell<T>, b: &Dense<T>, x: &mut Dense<T>) {
    let n = a.shape().rows;
    let nrhs = b.shape().cols;
    let k = a.stored_per_row();
    let cols = a.col_idxs();
    let vals = a.values();
    let bs = b.as_slice();
    let xptr = SlicePtr(x.as_mut_slice().as_mut_ptr());
    par_for(cfg, n, |_, rs, re| {
        for i in rs..re {
            for c in 0..nrhs {
                let mut acc = T::zero();
                for j in 0..k {
                    let pos = j * n + i;
                    acc += vals[pos] * bs[cols[pos] as usize * nrhs + c];
                }
                let xv = unsafe { xptr.at(i * nrhs + c) };
                *xv = acc;
            }
        }
    });
}

/// SELL-P SpMV, slices split across threads.
pub fn sellp_spmv<T: Value>(cfg: &ParConfig, a: &SellP<T>, b: &Dense<T>, x: &mut Dense<T>) {
    let n = a.shape().rows;
    let nrhs = b.shape().cols;
    let ss = a.slice_size();
    let bs = b.as_slice();
    let slice_lengths = &a.slice_lengths;
    let slice_sets = &a.slice_sets;
    let cols = &a.col_idxs;
    let vals = &a.values;
    let xptr = SlicePtr(x.as_mut_slice().as_mut_ptr());
    par_for(cfg, a.num_slices(), |_, s0, s1| {
        for s in s0..s1 {
            let width = slice_lengths[s];
            let base = slice_sets[s];
            for r in 0..ss {
                let i = s * ss + r;
                if i >= n {
                    break;
                }
                for c in 0..nrhs {
                    let mut acc = T::zero();
                    for j in 0..width {
                        let pos = base + j * ss + r;
                        acc += vals[pos] * bs[cols[pos] as usize * nrhs + c];
                    }
                    let xv = unsafe { xptr.at(i * nrhs + c) };
                    *xv = acc;
                }
            }
        }
    });
}

// ------------------------------------------------------- fused SpMV+dot
//
// `x = A b` followed by a blocked `(w·x, x·x)` sweep. The reductions are
// a separate pass (fusing them into per-thread SpMV chunks would make
// the sum order depend on the split), but the pair still reads x once
// where the composed path reads it twice.

/// CSR SpMV fused with two reductions: `x = A b`, returns `(w·x, x·x)`.
pub fn csr_spmv_dot<T: Value>(
    cfg: &ParConfig,
    a: &Csr<T>,
    b: &Dense<T>,
    x: &mut Dense<T>,
    w: &Dense<T>,
) -> (T, T) {
    csr_spmv_advanced(cfg, T::one(), a, T::zero(), b, x);
    dot_norm2(cfg, w.as_slice(), x.as_slice())
}

/// ELL SpMV fused with two reductions: `x = A b`, returns `(w·x, x·x)`.
pub fn ell_spmv_dot<T: Value>(
    cfg: &ParConfig,
    a: &Ell<T>,
    b: &Dense<T>,
    x: &mut Dense<T>,
    w: &Dense<T>,
) -> (T, T) {
    ell_spmv(cfg, a, b, x);
    dot_norm2(cfg, w.as_slice(), x.as_slice())
}

/// SELL-P SpMV fused with two reductions: `x = A b`, returns `(w·x, x·x)`.
pub fn sellp_spmv_dot<T: Value>(
    cfg: &ParConfig,
    a: &SellP<T>,
    b: &Dense<T>,
    x: &mut Dense<T>,
    w: &Dense<T>,
) -> (T, T) {
    sellp_spmv(cfg, a, b, x);
    dot_norm2(cfg, w.as_slice(), x.as_slice())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::dim::Dim2;
    use crate::core::executor::Executor;
    use crate::core::matrix_data::MatrixData;
    use crate::testing::prng::Prng;

    fn cfg() -> ParConfig {
        ParConfig {
            threads: 4,
            seq_threshold: 8, // force the parallel path in tests
        }
    }

    #[test]
    fn blas1_matches_reference() {
        let mut rng = Prng::new(42);
        let n = 1000;
        let x: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut y1: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut y2 = y1.clone();
        axpy(&cfg(), 0.7, &x, &mut y1);
        reference::axpy(0.7, &x, &mut y2);
        assert_eq!(y1, y2);
        axpby(&cfg(), -0.3, &x, 1.1, &mut y1);
        reference::axpby(-0.3, &x, 1.1, &mut y2);
        assert_eq!(y1, y2);
        let d1 = dot(&cfg(), &x, &y1);
        let d2 = reference::dot(&x, &y2);
        assert!((d1 - d2).abs() < 1e-9 * d2.abs().max(1.0));
        let mut z1 = vec![0.0f64; n];
        let mut z2 = vec![0.0f64; n];
        ew_mul(&cfg(), &x, &y1, &mut z1);
        reference::ew_mul(&x, &y2, &mut z2);
        assert_eq!(z1, z2);
    }

    #[test]
    fn coo_row_boundary_split_correct() {
        // matrix with one huge row to stress boundary alignment
        let mut rng = Prng::new(7);
        let n = 64;
        let mut data = MatrixData::<f64>::new(Dim2::square(n));
        for j in 0..n {
            data.push(3, j as i32, rng.uniform(-1.0, 1.0));
        }
        for i in 0..n {
            data.push(i as i32, i as i32, 1.0);
        }
        data.normalize();
        let a = Coo::from_data(Executor::reference(), &data).unwrap();
        let bv: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b = Dense::vector(Executor::reference(), &bv);
        let mut x1 = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        let mut x2 = x1.clone();
        coo_spmv_advanced(&cfg(), 1.0, &a, 0.0, &b, &mut x1);
        reference::coo_spmv_advanced(1.0, &a, 0.0, &b, &mut x2);
        for i in 0..n {
            assert!(
                (x1.as_slice()[i] - x2.as_slice()[i]).abs() < 1e-12,
                "row {i}"
            );
        }
    }

    #[test]
    fn csr_matches_reference_random() {
        let mut rng = Prng::new(123);
        let n = 200;
        let mut data = MatrixData::<f64>::new(Dim2::square(n));
        for i in 0..n {
            for _ in 0..rng.below(8) {
                data.push(i as i32, rng.below(n) as i32, rng.uniform(-1.0, 1.0));
            }
            data.push(i as i32, i as i32, 2.0);
        }
        data.normalize();
        let a = Csr::from_data(Executor::reference(), &data).unwrap();
        let bv: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b = Dense::vector(Executor::reference(), &bv);
        let mut x1 = Dense::vector(Executor::reference(), &vec![1.0; n]);
        let mut x2 = x1.clone();
        csr_spmv_advanced(&cfg(), 2.0, &a, -0.5, &b, &mut x1);
        reference::csr_spmv_advanced(2.0, &a, -0.5, &b, &mut x2);
        for i in 0..n {
            assert!((x1.as_slice()[i] - x2.as_slice()[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn ell_and_sellp_match_reference() {
        let mut rng = Prng::new(55);
        let n = 150;
        let mut data = MatrixData::<f64>::new(Dim2::square(n));
        for i in 0..n {
            for _ in 0..(1 + rng.below(6)) {
                data.push(i as i32, rng.below(n) as i32, rng.uniform(-1.0, 1.0));
            }
        }
        data.normalize();
        let bv: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b = Dense::vector(Executor::reference(), &bv);

        let ell = Ell::from_data(Executor::reference(), &data).unwrap();
        let mut x1 = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        let mut x2 = x1.clone();
        ell_spmv(&cfg(), &ell, &b, &mut x1);
        reference::ell_spmv(&ell, &b, &mut x2);
        assert_eq!(x1.as_slice(), x2.as_slice());

        let sellp = SellP::from_data_with_slice(Executor::reference(), &data, 16).unwrap();
        let mut x3 = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        sellp_spmv(&cfg(), &sellp, &b, &mut x3);
        reference::sellp_spmv(&sellp, &b, &mut x2);
        assert_eq!(x3.as_slice(), x2.as_slice());
    }

    #[test]
    fn dot_is_thread_count_independent() {
        // n large enough for several 4096-blocks; seq_threshold 0 forces
        // the parallel fill for every thread count > 1
        let mut rng = Prng::new(9);
        let n = 20_000;
        let x: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let c = |t| ParConfig {
            threads: t,
            seq_threshold: 0,
        };
        let d1 = dot(&c(1), &x, &y);
        let d2 = dot(&c(2), &x, &y);
        let d8 = dot(&c(8), &x, &y);
        assert_eq!(d1, d2);
        assert_eq!(d1, d8);
        let (a1, b1) = dot_norm2(&c(1), &x, &y);
        let (a8, b8) = dot_norm2(&c(8), &x, &y);
        assert_eq!((a1, b1), (a8, b8));
        // fused pair agrees with the blocked single-sweep dots exactly
        assert_eq!(a1, d1);
        assert_eq!(b1, dot(&c(3), &y, &y));
    }

    #[test]
    fn fused_blas1_match_composed_bitwise() {
        let mut rng = Prng::new(31);
        let n = 10_000;
        let c = ParConfig {
            threads: 4,
            seq_threshold: 0,
        };
        let p: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let q: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let x0: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let r0: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let (alpha, beta, omega) = (0.8125f64, 0.375f64, 1.5f64);

        let (mut xf, mut rf) = (x0.clone(), r0.clone());
        let rr = axpy_sub_norm2(&c, alpha, &p, &q, &mut xf, &mut rf);
        let (mut xc, mut rc) = (x0.clone(), r0.clone());
        axpy(&c, alpha, &p, &mut xc);
        axpy(&c, -alpha, &q, &mut rc);
        assert_eq!(xf, xc);
        assert_eq!(rf, rc);
        assert_eq!(rr, dot(&c, &rc, &rc));

        let mut of = vec![0.0f64; n];
        add_scaled(&c, &r0, -alpha, &q, &mut of);
        let mut oc = r0.clone();
        axpy(&c, -alpha, &q, &mut oc);
        assert_eq!(of, oc);

        let mut pf = x0.clone();
        update_p(&c, &r0, beta, omega, &q, &mut pf);
        let mut pc = x0.clone();
        reference::update_p(&r0, beta, omega, &q, &mut pc);
        assert_eq!(pf, pc);

        let mut gf = x0.clone();
        update_p_cgs(&c, &p, beta, &q, &mut gf);
        let mut gc = x0.clone();
        reference::update_p_cgs(&p, beta, &q, &mut gc);
        assert_eq!(gf, gc);

        let mut sf = vec![0.0f64; n];
        let srr = sub_scaled_norm2(&c, &p, omega, &q, &mut sf);
        let mut sc = vec![0.0f64; n];
        add_scaled(&c, &p, -omega, &q, &mut sc);
        assert_eq!(sf, sc);
        assert_eq!(srr, dot(&c, &sc, &sc));

        let mut af = x0.clone();
        axpy2(&c, alpha, &p, omega, &q, &mut af);
        let mut ac = x0.clone();
        axpy(&c, alpha, &p, &mut ac);
        axpy(&c, omega, &q, &mut ac);
        assert_eq!(af, ac);

        let mut zf = vec![f64::NAN; n];
        scal_into(&c, beta, &p, &mut zf);
        let mut zc = p.clone();
        scal(&c, beta, &mut zc);
        assert_eq!(zf, zc);
    }

    #[test]
    fn fused_mgs_matches_composed_and_thread_count() {
        // n spans several 4096-blocks so the parallel fill is exercised
        let mut rng = Prng::new(13);
        let n = 10_000;
        let c = ParConfig {
            threads: 4,
            seq_threshold: 0,
        };
        let basis_data: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect())
            .collect();
        let basis: Vec<&[f64]> = basis_data.iter().map(|v| v.as_slice()).collect();
        let w0: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();

        // dot_axpy == dot + axpy(-h)
        let mut wf = w0.clone();
        let hf = dot_axpy(&c, basis[0], &mut wf);
        let mut wc = w0.clone();
        let hc = dot(&c, &wc, basis[0]);
        axpy(&c, -hc, basis[0], &mut wc);
        assert_eq!(hf, hc);
        assert_eq!(wf, wc);

        // mgs_project == composed chain on this backend, bit for bit
        let mut wf = w0.clone();
        let mut hfv = vec![0.0f64; 3];
        let ww = mgs_project(&c, &basis, &mut wf, &mut hfv);
        let mut wc = w0.clone();
        let mut hcv = vec![0.0f64; 3];
        for (i, v) in basis.iter().enumerate() {
            hcv[i] = dot(&c, &wc, v);
            axpy(&c, -hcv[i], v, &mut wc);
        }
        assert_eq!(hfv, hcv);
        assert_eq!(wf, wc);
        assert_eq!(ww, dot(&c, &wc, &wc));

        // thread-count independence of the staged reductions
        for threads in [1, 2, 8] {
            let ct = ParConfig {
                threads,
                seq_threshold: 0,
            };
            let mut wt = w0.clone();
            let mut ht = vec![0.0f64; 3];
            let wwt = mgs_project(&ct, &basis, &mut wt, &mut ht);
            assert_eq!(ht, hfv, "threads {threads}");
            assert_eq!(wt, wf, "threads {threads}");
            assert_eq!(wwt, ww, "threads {threads}");
        }

        // mgs_update == composed axpy sequence
        let y = [0.5f64, -1.25, 2.0];
        let mut xf = w0.clone();
        mgs_update(&c, &basis, &y, &mut xf);
        let mut xc = w0.clone();
        for (j, v) in basis.iter().enumerate() {
            axpy(&c, y[j], v, &mut xc);
        }
        assert_eq!(xf, xc);
    }

    #[test]
    fn csr_nnz_balanced_matches_reference_on_skewed() {
        // power-law-ish: one row holds half the nonzeros
        let mut rng = Prng::new(77);
        let n = 300;
        let mut data = MatrixData::<f64>::new(Dim2::square(n));
        for j in 0..n {
            data.push(17, j as i32, rng.uniform(-1.0, 1.0));
        }
        for i in 0..n {
            data.push(i as i32, i as i32, 2.0);
            if rng.below(3) == 0 {
                data.push(i as i32, rng.below(n) as i32, rng.uniform(-1.0, 1.0));
            }
        }
        data.normalize();
        let a = Csr::from_data(Executor::reference(), &data).unwrap();
        let bv: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b = Dense::vector(Executor::reference(), &bv);
        let mut expect = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        reference::csr_spmv(&a, &b, &mut expect);
        for threads in [1, 2, 3, 8] {
            let c = ParConfig {
                threads,
                seq_threshold: 0,
            };
            let mut x = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
            csr_spmv_advanced(&c, 1.0, &a, 0.0, &b, &mut x);
            // rows are whole per thread and accumulate in storage order,
            // so the split is bitwise-invisible
            assert_eq!(x.as_slice(), expect.as_slice(), "threads {threads}");
        }
    }

    #[test]
    fn fused_spmv_dot_matches_composed() {
        let mut rng = Prng::new(101);
        let n = 220;
        let mut data = MatrixData::<f64>::new(Dim2::square(n));
        for i in 0..n {
            data.push(i as i32, i as i32, 3.0);
            for _ in 0..rng.below(5) {
                data.push(i as i32, rng.below(n) as i32, rng.uniform(-1.0, 1.0));
            }
        }
        data.normalize();
        let c = ParConfig {
            threads: 4,
            seq_threshold: 0,
        };
        let bv: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let wv: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b = Dense::vector(Executor::reference(), &bv);
        let w = Dense::vector(Executor::reference(), &wv);

        let csr = Csr::from_data(Executor::reference(), &data).unwrap();
        let mut xc = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        csr_spmv_advanced(&c, 1.0, &csr, 0.0, &b, &mut xc);
        let want_wx = dot(&c, w.as_slice(), xc.as_slice());
        let want_xx = dot(&c, xc.as_slice(), xc.as_slice());

        let mut xf = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        let (wx, xx) = csr_spmv_dot(&c, &csr, &b, &mut xf, &w);
        assert_eq!(xf.as_slice(), xc.as_slice());
        assert_eq!((wx, xx), (want_wx, want_xx));

        let ell = Ell::from_data(Executor::reference(), &data).unwrap();
        let mut xe = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        let (ewx, exx) = ell_spmv_dot(&c, &ell, &b, &mut xe, &w);
        let mut xe2 = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        ell_spmv(&c, &ell, &b, &mut xe2);
        assert_eq!(xe.as_slice(), xe2.as_slice());
        assert_eq!(ewx, dot(&c, w.as_slice(), xe2.as_slice()));
        assert_eq!(exx, dot(&c, xe2.as_slice(), xe2.as_slice()));

        let sellp = SellP::from_data_with_slice(Executor::reference(), &data, 8).unwrap();
        let mut xs = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        let (swx, sxx) = sellp_spmv_dot(&c, &sellp, &b, &mut xs, &w);
        let mut xs2 = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        sellp_spmv(&c, &sellp, &b, &mut xs2);
        assert_eq!(xs.as_slice(), xs2.as_slice());
        assert_eq!(swx, dot(&c, w.as_slice(), xs2.as_slice()));
        assert_eq!(sxx, dot(&c, xs2.as_slice(), xs2.as_slice()));
    }
}
