//! Multithreaded host kernels (Ginkgo's `omp` backend analog).
//!
//! Parallelization strategy mirrors the OpenMP kernels of the paper's
//! library: BLAS-1 splits the index space, row-based SpMV splits output
//! rows (no atomics needed), COO splits the nonzero range on *row
//! boundaries* so each thread owns disjoint output rows.
//!
//! All kernels work on raw slices: matrix/vector structs contain an
//! `Arc<Executor>` (non-`Sync` because of the PJRT client), so the
//! dispatch layer unpacks them before entering scoped threads.

use crate::core::executor::{par_for, par_reduce, ParConfig};
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::kernels::reference;
use crate::matrix::coo::Coo;
use crate::matrix::csr::Csr;
use crate::matrix::dense::Dense;
use crate::matrix::ell::Ell;
use crate::matrix::sellp::SellP;

use crate::kernels::ptr::SlicePtr;

// ---------------------------------------------------------------- BLAS-1

/// y += alpha * x, split across threads.
pub fn axpy<T: Value>(cfg: &ParConfig, alpha: T, x: &[T], y: &mut [T]) {
    let ptr = SlicePtr(y.as_mut_ptr());
    par_for(cfg, x.len(), |_, s, e| {
        // SAFETY: [s, e) ranges are disjoint across threads.
        let y = unsafe { ptr.range(s, e - s) };
        reference::axpy(alpha, &x[s..e], y);
    });
}

/// y = alpha * x + beta * y.
pub fn axpby<T: Value>(cfg: &ParConfig, alpha: T, x: &[T], beta: T, y: &mut [T]) {
    let ptr = SlicePtr(y.as_mut_ptr());
    par_for(cfg, x.len(), |_, s, e| {
        let y = unsafe { ptr.range(s, e - s) };
        reference::axpby(alpha, &x[s..e], beta, y);
    });
}

/// x *= beta.
pub fn scal<T: Value>(cfg: &ParConfig, beta: T, x: &mut [T]) {
    let n = x.len();
    let ptr = SlicePtr(x.as_mut_ptr());
    par_for(cfg, n, |_, s, e| {
        let x = unsafe { ptr.range(s, e - s) };
        reference::scal(beta, x);
    });
}

/// Dot product (per-thread partials combined in thread order, so the
/// result is deterministic for a fixed thread count).
pub fn dot<T: Value>(cfg: &ParConfig, x: &[T], y: &[T]) -> T {
    par_reduce(
        cfg,
        x.len(),
        T::zero(),
        |s, e| reference::dot(&x[s..e], &y[s..e]),
        |a, b| a + b,
    )
}

/// Euclidean norm.
pub fn norm2<T: Value>(cfg: &ParConfig, x: &[T]) -> T {
    dot(cfg, x, x).sqrt()
}

/// z = x ⊙ y.
pub fn ew_mul<T: Value>(cfg: &ParConfig, x: &[T], y: &[T], z: &mut [T]) {
    let ptr = SlicePtr(z.as_mut_ptr());
    par_for(cfg, x.len(), |_, s, e| {
        let z = unsafe { ptr.range(s, e - s) };
        reference::ew_mul(&x[s..e], &y[s..e], z);
    });
}

// ------------------------------------------------------------------ SpMV

/// CSR SpMV, rows split across threads.
pub fn csr_spmv_advanced<T: Value>(
    cfg: &ParConfig,
    alpha: T,
    a: &Csr<T>,
    beta: T,
    b: &Dense<T>,
    x: &mut Dense<T>,
) {
    let nrhs = b.shape().cols;
    let nrows = a.shape().rows;
    let row_ptrs = a.row_ptrs();
    let col_idxs = a.col_idxs();
    let values = a.values();
    let bs = b.as_slice();
    let xptr = SlicePtr(x.as_mut_slice().as_mut_ptr());
    par_for(cfg, nrows, |_, rs, re| {
        for i in rs..re {
            for c in 0..nrhs {
                let mut acc = T::zero();
                for k in row_ptrs[i] as usize..row_ptrs[i + 1] as usize {
                    acc += values[k] * bs[col_idxs[k] as usize * nrhs + c];
                }
                // SAFETY: row ranges are disjoint across threads.
                let xv = unsafe { xptr.at(i * nrhs + c) };
                *xv = if beta.is_zero() {
                    alpha * acc
                } else {
                    alpha * acc + beta * *xv
                };
            }
        }
    });
}

/// COO SpMV (x = alpha A b + beta x), nnz split on row boundaries.
pub fn coo_spmv_advanced<T: Value>(
    cfg: &ParConfig,
    alpha: T,
    a: &Coo<T>,
    beta: T,
    b: &Dense<T>,
    x: &mut Dense<T>,
) {
    scal(cfg, beta, x.as_mut_slice());
    let nnz = a.nnz();
    if nnz == 0 {
        return;
    }
    let nrhs = b.shape().cols;
    let rows = a.row_idxs();
    let cols = a.col_idxs();
    let vals = a.values();
    let bs = b.as_slice();
    let threads = cfg.effective_threads().max(1);
    // Split [0, nnz) into ranges aligned to row boundaries: thread t owns
    // entries [starts[t], starts[t+1]) and therefore disjoint output rows.
    let chunk = nnz.div_ceil(threads);
    let mut starts = Vec::with_capacity(threads + 1);
    starts.push(0usize);
    for t in 1..threads {
        let mut pos = (t * chunk).min(nnz);
        // advance to the first entry of the next row so rows never split
        while pos < nnz && pos > 0 && rows[pos] == rows[pos - 1] {
            pos += 1;
        }
        let pos = pos.max(*starts.last().unwrap());
        starts.push(pos);
    }
    starts.push(nnz);
    let xptr = SlicePtr(x.as_mut_slice().as_mut_ptr());
    std::thread::scope(|s| {
        for t in 0..threads {
            let (lo, hi) = (starts[t], starts[t + 1]);
            if lo >= hi {
                continue;
            }
            let xptr = &xptr;
            s.spawn(move || {
                for idx in lo..hi {
                    let r = rows[idx] as usize;
                    let v = alpha * vals[idx];
                    for j in 0..nrhs {
                        // SAFETY: row ranges are disjoint across threads
                        // (chunk boundaries aligned to row changes).
                        let xv = unsafe { xptr.at(r * nrhs + j) };
                        *xv += v * bs[cols[idx] as usize * nrhs + j];
                    }
                }
            });
        }
    });
}

/// ELL SpMV, rows split across threads.
pub fn ell_spmv<T: Value>(cfg: &ParConfig, a: &Ell<T>, b: &Dense<T>, x: &mut Dense<T>) {
    let n = a.shape().rows;
    let nrhs = b.shape().cols;
    let k = a.stored_per_row();
    let cols = a.col_idxs();
    let vals = a.values();
    let bs = b.as_slice();
    let xptr = SlicePtr(x.as_mut_slice().as_mut_ptr());
    par_for(cfg, n, |_, rs, re| {
        for i in rs..re {
            for c in 0..nrhs {
                let mut acc = T::zero();
                for j in 0..k {
                    let pos = j * n + i;
                    acc += vals[pos] * bs[cols[pos] as usize * nrhs + c];
                }
                let xv = unsafe { xptr.at(i * nrhs + c) };
                *xv = acc;
            }
        }
    });
}

/// SELL-P SpMV, slices split across threads.
pub fn sellp_spmv<T: Value>(cfg: &ParConfig, a: &SellP<T>, b: &Dense<T>, x: &mut Dense<T>) {
    let n = a.shape().rows;
    let nrhs = b.shape().cols;
    let ss = a.slice_size();
    let bs = b.as_slice();
    let slice_lengths = &a.slice_lengths;
    let slice_sets = &a.slice_sets;
    let cols = &a.col_idxs;
    let vals = &a.values;
    let xptr = SlicePtr(x.as_mut_slice().as_mut_ptr());
    par_for(cfg, a.num_slices(), |_, s0, s1| {
        for s in s0..s1 {
            let width = slice_lengths[s];
            let base = slice_sets[s];
            for r in 0..ss {
                let i = s * ss + r;
                if i >= n {
                    break;
                }
                for c in 0..nrhs {
                    let mut acc = T::zero();
                    for j in 0..width {
                        let pos = base + j * ss + r;
                        acc += vals[pos] * bs[cols[pos] as usize * nrhs + c];
                    }
                    let xv = unsafe { xptr.at(i * nrhs + c) };
                    *xv = acc;
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::dim::Dim2;
    use crate::core::executor::Executor;
    use crate::core::matrix_data::MatrixData;
    use crate::testing::prng::Prng;

    fn cfg() -> ParConfig {
        ParConfig {
            threads: 4,
            seq_threshold: 8, // force the parallel path in tests
        }
    }

    #[test]
    fn blas1_matches_reference() {
        let mut rng = Prng::new(42);
        let n = 1000;
        let x: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut y1: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut y2 = y1.clone();
        axpy(&cfg(), 0.7, &x, &mut y1);
        reference::axpy(0.7, &x, &mut y2);
        assert_eq!(y1, y2);
        axpby(&cfg(), -0.3, &x, 1.1, &mut y1);
        reference::axpby(-0.3, &x, 1.1, &mut y2);
        assert_eq!(y1, y2);
        let d1 = dot(&cfg(), &x, &y1);
        let d2 = reference::dot(&x, &y2);
        assert!((d1 - d2).abs() < 1e-9 * d2.abs().max(1.0));
        let mut z1 = vec![0.0f64; n];
        let mut z2 = vec![0.0f64; n];
        ew_mul(&cfg(), &x, &y1, &mut z1);
        reference::ew_mul(&x, &y2, &mut z2);
        assert_eq!(z1, z2);
    }

    #[test]
    fn coo_row_boundary_split_correct() {
        // matrix with one huge row to stress boundary alignment
        let mut rng = Prng::new(7);
        let n = 64;
        let mut data = MatrixData::<f64>::new(Dim2::square(n));
        for j in 0..n {
            data.push(3, j as i32, rng.uniform(-1.0, 1.0));
        }
        for i in 0..n {
            data.push(i as i32, i as i32, 1.0);
        }
        data.normalize();
        let a = Coo::from_data(Executor::reference(), &data).unwrap();
        let bv: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b = Dense::vector(Executor::reference(), &bv);
        let mut x1 = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        let mut x2 = x1.clone();
        coo_spmv_advanced(&cfg(), 1.0, &a, 0.0, &b, &mut x1);
        reference::coo_spmv_advanced(1.0, &a, 0.0, &b, &mut x2);
        for i in 0..n {
            assert!(
                (x1.as_slice()[i] - x2.as_slice()[i]).abs() < 1e-12,
                "row {i}"
            );
        }
    }

    #[test]
    fn csr_matches_reference_random() {
        let mut rng = Prng::new(123);
        let n = 200;
        let mut data = MatrixData::<f64>::new(Dim2::square(n));
        for i in 0..n {
            for _ in 0..rng.below(8) {
                data.push(i as i32, rng.below(n) as i32, rng.uniform(-1.0, 1.0));
            }
            data.push(i as i32, i as i32, 2.0);
        }
        data.normalize();
        let a = Csr::from_data(Executor::reference(), &data).unwrap();
        let bv: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b = Dense::vector(Executor::reference(), &bv);
        let mut x1 = Dense::vector(Executor::reference(), &vec![1.0; n]);
        let mut x2 = x1.clone();
        csr_spmv_advanced(&cfg(), 2.0, &a, -0.5, &b, &mut x1);
        reference::csr_spmv_advanced(2.0, &a, -0.5, &b, &mut x2);
        for i in 0..n {
            assert!((x1.as_slice()[i] - x2.as_slice()[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn ell_and_sellp_match_reference() {
        let mut rng = Prng::new(55);
        let n = 150;
        let mut data = MatrixData::<f64>::new(Dim2::square(n));
        for i in 0..n {
            for _ in 0..(1 + rng.below(6)) {
                data.push(i as i32, rng.below(n) as i32, rng.uniform(-1.0, 1.0));
            }
        }
        data.normalize();
        let bv: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b = Dense::vector(Executor::reference(), &bv);

        let ell = Ell::from_data(Executor::reference(), &data).unwrap();
        let mut x1 = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        let mut x2 = x1.clone();
        ell_spmv(&cfg(), &ell, &b, &mut x1);
        reference::ell_spmv(&ell, &b, &mut x2);
        assert_eq!(x1.as_slice(), x2.as_slice());

        let sellp = SellP::from_data_with_slice(Executor::reference(), &data, 16).unwrap();
        let mut x3 = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        sellp_spmv(&cfg(), &sellp, &b, &mut x3);
        reference::sellp_spmv(&sellp, &b, &mut x2);
        assert_eq!(x3.as_slice(), x2.as_slice());
    }
}
