//! SpMV dispatch: per-format entry points switching on the executor.
//!
//! Like `kernels/blas.rs`, the Xla arms check the runtime's circuit
//! breaker *before* dispatching: once the breaker opens (repeated
//! execute failures) the formats route to the host `par` kernels, so a
//! read-modify-write kernel never runs twice on the same operand.

use std::sync::Arc;

use crate::core::error::{Result, SparkleError};
use crate::core::executor::{Executor, ParConfig};
use crate::core::types::Value;
use crate::observe;
use crate::kernels::{par, reference, xla};
use crate::matrix::coo::Coo;
use crate::matrix::csr::Csr;
use crate::matrix::dense::Dense;
use crate::matrix::ell::Ell;
use crate::matrix::hybrid::Hybrid;
use crate::matrix::sellp::SellP;

/// x = A b (CSR).
pub fn csr_apply<T: Value>(
    exec: &Arc<Executor>,
    a: &Csr<T>,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    csr_apply_advanced(exec, T::one(), a, T::zero(), b, x)
}

/// x = alpha A b + beta x (CSR).
pub fn csr_apply_advanced<T: Value>(
    exec: &Arc<Executor>,
    alpha: T,
    a: &Csr<T>,
    beta: T,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    let _obs = observe::spmv_guard("csr", exec.name(), x.len(), a.nnz(), T::PRECISION);
    match &**exec {
        Executor::Reference => reference::csr_spmv_advanced(alpha, a, beta, b, x),
        Executor::Par(cfg) => par::csr_spmv_advanced(cfg, alpha, a, beta, b, x),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::csr_spmv_advanced(&ParConfig::default(), alpha, a, beta, b, x)
            } else {
                xla::csr_spmv_advanced(&e.runtime, alpha, a, beta, b, x)?
            }
        }
    }
    Ok(())
}

/// x = A b (COO).
pub fn coo_apply<T: Value>(
    exec: &Arc<Executor>,
    a: &Coo<T>,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    coo_apply_advanced(exec, T::one(), a, T::zero(), b, x)
}

/// x = alpha A b + beta x (COO).
pub fn coo_apply_advanced<T: Value>(
    exec: &Arc<Executor>,
    alpha: T,
    a: &Coo<T>,
    beta: T,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    let _obs = observe::spmv_guard("coo", exec.name(), x.len(), a.nnz(), T::PRECISION);
    match &**exec {
        Executor::Reference => reference::coo_spmv_advanced(alpha, a, beta, b, x),
        Executor::Par(cfg) => par::coo_spmv_advanced(cfg, alpha, a, beta, b, x),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::coo_spmv_advanced(&ParConfig::default(), alpha, a, beta, b, x)
            } else {
                xla::coo_spmv_advanced(&e.runtime, alpha, a, beta, b, x)?
            }
        }
    }
    Ok(())
}

/// x = A b (ELL).
pub fn ell_apply<T: Value>(
    exec: &Arc<Executor>,
    a: &Ell<T>,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    let _obs = observe::spmv_guard("ell", exec.name(), x.len(), a.nnz(), T::PRECISION);
    match &**exec {
        Executor::Reference => reference::ell_spmv(a, b, x),
        Executor::Par(cfg) => par::ell_spmv(cfg, a, b, x),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::ell_spmv(&ParConfig::default(), a, b, x)
            } else {
                xla::ell_spmv_advanced(&e.runtime, T::one(), a, T::zero(), b, x)?
            }
        }
    }
    Ok(())
}

/// x = alpha A b + beta x (ELL).
pub fn ell_apply_advanced<T: Value>(
    exec: &Arc<Executor>,
    alpha: T,
    a: &Ell<T>,
    beta: T,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    match &**exec {
        Executor::Xla(e) if !e.runtime.degraded() => {
            // leaf dispatch: the composed path below is covered by the
            // guards inside ell_apply + axpby, so only this arm needs
            // its own guard (no double counting)
            let _obs = observe::spmv_guard("ell", exec.name(), x.len(), a.nnz(), T::PRECISION);
            xla::ell_spmv_advanced(&e.runtime, alpha, a, beta, b, x)
        }
        _ => {
            // compose: tmp = A b; x = alpha tmp + beta x
            let mut tmp = Dense::zeros(exec.clone(), x.shape());
            ell_apply(exec, a, b, &mut tmp)?;
            crate::kernels::blas::axpby(exec, alpha, &tmp, beta, x)
        }
    }
}

/// x = A b (SELL-P). The XLA executor has no dedicated SELL-P artifact
/// (its slice layout is what the ELL Pallas kernel already tiles), so it
/// reports `NotSupported` — callers convert to ELL/COO first.
pub fn sellp_apply<T: Value>(
    exec: &Arc<Executor>,
    a: &SellP<T>,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    match &**exec {
        Executor::Xla(_) => {
            return Err(SparkleError::NotSupported {
                op: "sellp spmv",
                exec: "xla",
            })
        }
        _ => {
            let _obs = observe::spmv_guard("sellp", exec.name(), x.len(), a.nnz(), T::PRECISION);
            match &**exec {
                Executor::Reference => reference::sellp_spmv(a, b, x),
                Executor::Par(cfg) => par::sellp_spmv(cfg, a, b, x),
                Executor::Xla(_) => unreachable!("handled above"),
            }
        }
    }
    Ok(())
}

/// x = alpha A b + beta x (SELL-P). Composed from the plain apply plus
/// an `axpby`, mirroring the ELL fallback path, so every format now
/// exposes the same `*_apply` / `*_apply_advanced` pair.
pub fn sellp_apply_advanced<T: Value>(
    exec: &Arc<Executor>,
    alpha: T,
    a: &SellP<T>,
    beta: T,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    if alpha == T::one() && beta == T::zero() {
        return sellp_apply(exec, a, b, x);
    }
    // compose: tmp = A b; x = alpha tmp + beta x
    let mut tmp = Dense::zeros(exec.clone(), x.shape());
    sellp_apply(exec, a, b, &mut tmp)?;
    crate::kernels::blas::axpby(exec, alpha, &tmp, beta, x)
}

// ------------------------------------------------------- fused SpMV+dot
//
// `x = A b` returning `(w·x, x·x)` — the Krylov drivers' dominant
// pattern (q = A p with p·q, or t = A s with t·s and t·t). Fused on the
// host backends; the composed fallback (`*_apply` + `blas::dot_norm2`)
// covers Xla and the `set_fused_enabled(false)` ablation baseline, with
// guards carried by the inner calls.

/// x = A b, returns `(w·x, x·x)` (CSR).
pub fn csr_apply_dot<T: Value>(
    exec: &Arc<Executor>,
    a: &Csr<T>,
    b: &Dense<T>,
    x: &mut Dense<T>,
    w: &Dense<T>,
) -> Result<(T, T)> {
    if crate::kernels::fused_enabled() {
        match &**exec {
            Executor::Reference => {
                let _obs =
                    observe::spmv_dot_guard("csr_dot", exec.name(), x.len(), a.nnz(), T::PRECISION);
                return Ok(reference::csr_spmv_dot(a, b, x, w));
            }
            Executor::Par(cfg) => {
                let _obs =
                    observe::spmv_dot_guard("csr_dot", exec.name(), x.len(), a.nnz(), T::PRECISION);
                return Ok(par::csr_spmv_dot(cfg, a, b, x, w));
            }
            Executor::Xla(_) => {}
        }
    }
    csr_apply(exec, a, b, x)?;
    crate::kernels::blas::dot_norm2(exec, w, x)
}

/// x = A b, returns `(w·x, x·x)` (ELL).
pub fn ell_apply_dot<T: Value>(
    exec: &Arc<Executor>,
    a: &Ell<T>,
    b: &Dense<T>,
    x: &mut Dense<T>,
    w: &Dense<T>,
) -> Result<(T, T)> {
    if crate::kernels::fused_enabled() {
        match &**exec {
            Executor::Reference => {
                let _obs =
                    observe::spmv_dot_guard("ell_dot", exec.name(), x.len(), a.nnz(), T::PRECISION);
                return Ok(reference::ell_spmv_dot(a, b, x, w));
            }
            Executor::Par(cfg) => {
                let _obs =
                    observe::spmv_dot_guard("ell_dot", exec.name(), x.len(), a.nnz(), T::PRECISION);
                return Ok(par::ell_spmv_dot(cfg, a, b, x, w));
            }
            Executor::Xla(_) => {}
        }
    }
    ell_apply(exec, a, b, x)?;
    crate::kernels::blas::dot_norm2(exec, w, x)
}

/// x = A b, returns `(w·x, x·x)` (SELL-P; `NotSupported` on xla like
/// the plain apply).
pub fn sellp_apply_dot<T: Value>(
    exec: &Arc<Executor>,
    a: &SellP<T>,
    b: &Dense<T>,
    x: &mut Dense<T>,
    w: &Dense<T>,
) -> Result<(T, T)> {
    if crate::kernels::fused_enabled() {
        match &**exec {
            Executor::Reference => {
                let _obs = observe::spmv_dot_guard(
                    "sellp_dot",
                    exec.name(),
                    x.len(),
                    a.nnz(),
                    T::PRECISION,
                );
                return Ok(reference::sellp_spmv_dot(a, b, x, w));
            }
            Executor::Par(cfg) => {
                let _obs = observe::spmv_dot_guard(
                    "sellp_dot",
                    exec.name(),
                    x.len(),
                    a.nnz(),
                    T::PRECISION,
                );
                return Ok(par::sellp_spmv_dot(cfg, a, b, x, w));
            }
            Executor::Xla(_) => {}
        }
    }
    sellp_apply(exec, a, b, x)?;
    crate::kernels::blas::dot_norm2(exec, w, x)
}

/// x = A b (Hybrid).
pub fn hybrid_apply<T: Value>(
    exec: &Arc<Executor>,
    a: &Hybrid<T>,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    // x = ell * b; x += coo * b — each part goes through its own
    // per-executor switch, so every backend that has ELL + COO kernels
    // (including xla) gets Hybrid for free.
    ell_apply(exec, a.ell_part(), b, x)?;
    coo_apply_advanced(exec, T::one(), a.coo_part(), T::one(), b, x)
}

/// x = alpha A b + beta x (Hybrid).
pub fn hybrid_apply_advanced<T: Value>(
    exec: &Arc<Executor>,
    alpha: T,
    a: &Hybrid<T>,
    beta: T,
    b: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    if alpha == T::one() && beta == T::zero() {
        return hybrid_apply(exec, a, b, x);
    }
    // compose: tmp = A b; x = alpha tmp + beta x
    let mut tmp = Dense::zeros(exec.clone(), x.shape());
    hybrid_apply(exec, a, b, &mut tmp)?;
    crate::kernels::blas::axpby(exec, alpha, &tmp, beta, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::dim::Dim2;
    use crate::core::linop::LinOp;
    use crate::testing::prng::Prng;
    use crate::testing::prop::{assert_close, gen_sparse, gen_vec};

    /// All host formats must agree with the CSR reference on random data.
    #[test]
    fn formats_agree_across_host_executors() {
        let mut rng = Prng::new(2024);
        for _ in 0..5 {
            let n = 40 + rng.below(80);
            let data = gen_sparse::<f64>(&mut rng, n, n, 5);
            let bv = gen_vec::<f64>(&mut rng, n);
            let reference_exec = Executor::reference();
            let b = Dense::vector(reference_exec.clone(), &bv);
            let csr = Csr::from_data(reference_exec.clone(), &data).unwrap();
            let mut expect = Dense::zeros(reference_exec.clone(), Dim2::new(n, 1));
            csr.apply(&b, &mut expect).unwrap();

            for exec in [Executor::reference(), Executor::par_with_threads(4)] {
                let b = Dense::vector(exec.clone(), &bv);
                let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));

                let coo = Coo::from_data(exec.clone(), &data).unwrap();
                coo.apply(&b, &mut x).unwrap();
                assert_close(x.as_slice(), expect.as_slice(), 1e-12, "coo");

                let ell = Ell::from_data(exec.clone(), &data).unwrap();
                ell.apply(&b, &mut x).unwrap();
                assert_close(x.as_slice(), expect.as_slice(), 1e-12, "ell");

                let sellp = SellP::from_data(exec.clone(), &data).unwrap();
                sellp.apply(&b, &mut x).unwrap();
                assert_close(x.as_slice(), expect.as_slice(), 1e-12, "sellp");

                let hybrid =
                    crate::matrix::hybrid::Hybrid::from_data(exec.clone(), &data).unwrap();
                hybrid.apply(&b, &mut x).unwrap();
                assert_close(x.as_slice(), expect.as_slice(), 1e-12, "hybrid");

                // the dispatch entry point and the LinOp path must agree
                let mut xd = Dense::zeros(exec.clone(), Dim2::new(n, 1));
                hybrid_apply(&exec, &hybrid, &b, &mut xd).unwrap();
                assert_close(xd.as_slice(), expect.as_slice(), 1e-12, "hybrid_apply");
            }
        }
    }

    /// `hybrid_apply_advanced` must match the CSR advanced kernel.
    #[test]
    fn hybrid_advanced_matches_csr() {
        let mut rng = Prng::new(77);
        let n = 64;
        let data = gen_sparse::<f64>(&mut rng, n, n, 6);
        let bv = gen_vec::<f64>(&mut rng, n);
        let x0 = gen_vec::<f64>(&mut rng, n);
        for exec in [Executor::reference(), Executor::par_with_threads(2)] {
            let b = Dense::vector(exec.clone(), &bv);
            let csr = Csr::from_data(exec.clone(), &data).unwrap();
            let mut expect = Dense::vector(exec.clone(), &x0);
            csr_apply_advanced(&exec, 2.5, &csr, -0.75, &b, &mut expect).unwrap();

            let hybrid = crate::matrix::hybrid::Hybrid::from_data(exec.clone(), &data).unwrap();
            let mut x = Dense::vector(exec.clone(), &x0);
            hybrid_apply_advanced(&exec, 2.5, &hybrid, -0.75, &b, &mut x).unwrap();
            assert_close(x.as_slice(), expect.as_slice(), 1e-12, "hybrid advanced");

            // alpha=1, beta=0 fast path equals plain apply
            let mut xa = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            let mut xb = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            hybrid_apply(&exec, &hybrid, &b, &mut xa).unwrap();
            hybrid_apply_advanced(&exec, 1.0, &hybrid, 0.0, &b, &mut xb).unwrap();
            assert_close(xa.as_slice(), xb.as_slice(), 0.0, "fast path");
        }
    }

    /// The new `sellp_apply_advanced` must match the CSR advanced kernel.
    #[test]
    fn sellp_advanced_matches_csr() {
        let mut rng = Prng::new(4242);
        let n = 48;
        let data = gen_sparse::<f64>(&mut rng, n, n, 7);
        let bv = gen_vec::<f64>(&mut rng, n);
        let x0 = gen_vec::<f64>(&mut rng, n);
        for exec in [Executor::reference(), Executor::par_with_threads(2)] {
            let b = Dense::vector(exec.clone(), &bv);
            let csr = Csr::from_data(exec.clone(), &data).unwrap();
            let mut expect = Dense::vector(exec.clone(), &x0);
            csr_apply_advanced(&exec, 1.5, &csr, 0.25, &b, &mut expect).unwrap();

            let sellp = SellP::from_data(exec.clone(), &data).unwrap();
            let mut x = Dense::vector(exec.clone(), &x0);
            sellp_apply_advanced(&exec, 1.5, &sellp, 0.25, &b, &mut x).unwrap();
            assert_close(x.as_slice(), expect.as_slice(), 1e-12, "sellp advanced");
        }
    }
}
