//! BLAS-1 dispatch: one entry point per operation, switching on the
//! executor (the paper's `operations` class, §2).
//!
//! Degraded mode: when the xla runtime's circuit breaker is open
//! (repeated dispatch failures — see `resilience/retry.rs`), the Xla
//! arms route to the host `par` kernels instead. The check happens
//! *before* the xla call so a mutating kernel never runs twice on the
//! same operand; while the breaker is closed, failures propagate
//! unchanged.

use std::sync::Arc;

use crate::core::error::{Result, SparkleError};
use crate::core::executor::{Executor, ParConfig};
use crate::core::types::Value;
use crate::kernels::{par, reference, xla};
use crate::matrix::dense::Dense;
use crate::observe;
use crate::perfmodel::traffic::FusedBlasKind;

fn check_same_len<T: Value>(op: &'static str, x: &Dense<T>, y: &Dense<T>) -> Result<()> {
    if x.shape() != y.shape() {
        return Err(SparkleError::dim(
            op,
            format!("{} vs {}", x.shape(), y.shape()),
        ));
    }
    Ok(())
}

/// Observe guard with the textbook BLAS-1 model: `flops_per_elem * n`
/// flops and `streams * n * sizeof(T)` useful bytes (one stream per
/// vector read or written).
#[inline]
fn guard<T: Value>(
    name: &'static str,
    exec: &Arc<Executor>,
    flops_per_elem: f64,
    streams: f64,
    n: usize,
) -> Option<observe::KernelGuard> {
    let n = n as f64;
    let elem = T::PRECISION.bytes() as f64;
    observe::blas_guard(name, exec.name(), flops_per_elem * n, streams * elem * n)
}

/// y += alpha * x.
pub fn axpy<T: Value>(exec: &Arc<Executor>, alpha: T, x: &Dense<T>, y: &mut Dense<T>) -> Result<()> {
    check_same_len("axpy", x, y)?;
    let _obs = guard::<T>("axpy", exec, 2.0, 3.0, x.len());
    match &**exec {
        Executor::Reference => reference::axpy(alpha, x.as_slice(), y.as_mut_slice()),
        Executor::Par(cfg) => par::axpy(cfg, alpha, x.as_slice(), y.as_mut_slice()),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::axpy(&ParConfig::default(), alpha, x.as_slice(), y.as_mut_slice())
            } else {
                xla::axpy(&e.runtime, alpha, x.as_slice(), y.as_mut_slice())?
            }
        }
    }
    Ok(())
}

/// y = alpha * x + beta * y.
pub fn axpby<T: Value>(
    exec: &Arc<Executor>,
    alpha: T,
    x: &Dense<T>,
    beta: T,
    y: &mut Dense<T>,
) -> Result<()> {
    check_same_len("axpby", x, y)?;
    let _obs = guard::<T>("axpby", exec, 3.0, 3.0, x.len());
    match &**exec {
        Executor::Reference => reference::axpby(alpha, x.as_slice(), beta, y.as_mut_slice()),
        Executor::Par(cfg) => par::axpby(cfg, alpha, x.as_slice(), beta, y.as_mut_slice()),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::axpby(
                    &ParConfig::default(),
                    alpha,
                    x.as_slice(),
                    beta,
                    y.as_mut_slice(),
                )
            } else {
                xla::axpby(&e.runtime, alpha, x.as_slice(), beta, y.as_mut_slice())?
            }
        }
    }
    Ok(())
}

/// x *= beta.
pub fn scal<T: Value>(exec: &Arc<Executor>, beta: T, x: &mut Dense<T>) -> Result<()> {
    let _obs = guard::<T>("scal", exec, 1.0, 2.0, x.len());
    match &**exec {
        Executor::Reference => reference::scal(beta, x.as_mut_slice()),
        Executor::Par(cfg) => par::scal(cfg, beta, x.as_mut_slice()),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::scal(&ParConfig::default(), beta, x.as_mut_slice())
            } else {
                xla::scal(&e.runtime, beta, x.as_mut_slice())?
            }
        }
    }
    Ok(())
}

/// Dot product of two equally-shaped dense objects (flattened).
pub fn dot<T: Value>(exec: &Arc<Executor>, x: &Dense<T>, y: &Dense<T>) -> Result<T> {
    check_same_len("dot", x, y)?;
    let _obs = guard::<T>("dot", exec, 2.0, 2.0, x.len());
    Ok(match &**exec {
        Executor::Reference => reference::dot(x.as_slice(), y.as_slice()),
        Executor::Par(cfg) => par::dot(cfg, x.as_slice(), y.as_slice()),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::dot(&ParConfig::default(), x.as_slice(), y.as_slice())
            } else {
                xla::dot(&e.runtime, x.as_slice(), y.as_slice())?
            }
        }
    })
}

/// Euclidean norm.
pub fn norm2<T: Value>(exec: &Arc<Executor>, x: &Dense<T>) -> Result<T> {
    let _obs = guard::<T>("norm2", exec, 2.0, 1.0, x.len());
    Ok(match &**exec {
        Executor::Reference => reference::norm2(x.as_slice()),
        Executor::Par(cfg) => par::norm2(cfg, x.as_slice()),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::norm2(&ParConfig::default(), x.as_slice())
            } else {
                xla::norm2(&e.runtime, x.as_slice())?
            }
        }
    })
}

/// z = x ⊙ y (element-wise product).
pub fn ew_mul<T: Value>(
    exec: &Arc<Executor>,
    x: &Dense<T>,
    y: &Dense<T>,
    z: &mut Dense<T>,
) -> Result<()> {
    check_same_len("ew_mul", x, y)?;
    check_same_len("ew_mul", x, z)?;
    let _obs = guard::<T>("ew_mul", exec, 1.0, 3.0, x.len());
    match &**exec {
        Executor::Reference => reference::ew_mul(x.as_slice(), y.as_slice(), z.as_mut_slice()),
        Executor::Par(cfg) => par::ew_mul(cfg, x.as_slice(), y.as_slice(), z.as_mut_slice()),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::ew_mul(
                    &ParConfig::default(),
                    x.as_slice(),
                    y.as_slice(),
                    z.as_mut_slice(),
                )
            } else {
                xla::ew_mul(&e.runtime, x.as_slice(), y.as_slice(), z.as_mut_slice())?
            }
        }
    }
    Ok(())
}

// ----------------------------------------------------------- fused BLAS-1
//
// Each entry has a fused arm for the host backends (Reference/Par) and
// a composed fallback used when `kernels::set_fused_enabled(false)` or
// when the executor lacks a fused impl (Xla — its iteration-body fusion
// lives in `solver/fused.rs`). Fused and composed are bit-identical per
// executor, so the toggle only changes memory sweeps, never results.
// Guards: the fused arms carry a `FusedBlasKind` model crediting the
// reduced byte count; the composed path is covered by its inner calls'
// guards (no double counting).

/// Observe guard for a fused kernel over length-`n` vectors.
#[inline]
fn fused_guard<T: Value>(
    kind: FusedBlasKind,
    exec: &Arc<Executor>,
    n: usize,
) -> Option<observe::KernelGuard> {
    observe::fused_blas_guard(kind, exec.name(), n, T::PRECISION)
}

fn composed_dot_norm2<T: Value>(exec: &Arc<Executor>, x: &Dense<T>, y: &Dense<T>) -> Result<(T, T)> {
    Ok((dot(exec, x, y)?, dot(exec, y, y)?))
}

/// `(x·y, y·y)` in one sweep (replaces two `dot` calls).
pub fn dot_norm2<T: Value>(exec: &Arc<Executor>, x: &Dense<T>, y: &Dense<T>) -> Result<(T, T)> {
    check_same_len("dot_norm2", x, y)?;
    if !super::fused_enabled() {
        return composed_dot_norm2(exec, x, y);
    }
    match &**exec {
        Executor::Reference => {
            let _obs = fused_guard::<T>(FusedBlasKind::DotNorm2, exec, x.len());
            Ok(reference::dot_norm2(x.as_slice(), y.as_slice()))
        }
        Executor::Par(cfg) => {
            let _obs = fused_guard::<T>(FusedBlasKind::DotNorm2, exec, x.len());
            Ok(par::dot_norm2(cfg, x.as_slice(), y.as_slice()))
        }
        Executor::Xla(_) => composed_dot_norm2(exec, x, y),
    }
}

fn composed_axpy_sub_norm2<T: Value>(
    exec: &Arc<Executor>,
    alpha: T,
    p: &Dense<T>,
    q: &Dense<T>,
    x: &mut Dense<T>,
    r: &mut Dense<T>,
) -> Result<T> {
    axpy(exec, alpha, p, x)?;
    axpy(exec, -alpha, q, r)?;
    dot(exec, r, r)
}

/// `x += α p; r -= α q; return r·r` in one sweep (the CG/CGS update
/// tail: replaces two `axpy` calls and a `dot`).
pub fn axpy_sub_norm2<T: Value>(
    exec: &Arc<Executor>,
    alpha: T,
    p: &Dense<T>,
    q: &Dense<T>,
    x: &mut Dense<T>,
    r: &mut Dense<T>,
) -> Result<T> {
    check_same_len("axpy_sub_norm2", p, q)?;
    check_same_len("axpy_sub_norm2", p, x)?;
    check_same_len("axpy_sub_norm2", p, r)?;
    if !super::fused_enabled() {
        return composed_axpy_sub_norm2(exec, alpha, p, q, x, r);
    }
    match &**exec {
        Executor::Reference => {
            let _obs = fused_guard::<T>(FusedBlasKind::AxpySubNorm2, exec, p.len());
            Ok(reference::axpy_sub_norm2(
                alpha,
                p.as_slice(),
                q.as_slice(),
                x.as_mut_slice(),
                r.as_mut_slice(),
            ))
        }
        Executor::Par(cfg) => {
            let _obs = fused_guard::<T>(FusedBlasKind::AxpySubNorm2, exec, p.len());
            Ok(par::axpy_sub_norm2(
                cfg,
                alpha,
                p.as_slice(),
                q.as_slice(),
                x.as_mut_slice(),
                r.as_mut_slice(),
            ))
        }
        Executor::Xla(_) => composed_axpy_sub_norm2(exec, alpha, p, q, x, r),
    }
}

fn composed_add_scaled<T: Value>(
    exec: &Arc<Executor>,
    z: &Dense<T>,
    alpha: T,
    x: &Dense<T>,
    out: &mut Dense<T>,
) -> Result<()> {
    out.copy_from(z)?;
    axpy(exec, alpha, x, out)
}

/// `out = z + α x` in one sweep (replaces copy + `axpy`).
pub fn add_scaled<T: Value>(
    exec: &Arc<Executor>,
    z: &Dense<T>,
    alpha: T,
    x: &Dense<T>,
    out: &mut Dense<T>,
) -> Result<()> {
    check_same_len("add_scaled", z, x)?;
    check_same_len("add_scaled", z, out)?;
    if !super::fused_enabled() {
        return composed_add_scaled(exec, z, alpha, x, out);
    }
    match &**exec {
        Executor::Reference => {
            let _obs = fused_guard::<T>(FusedBlasKind::AddScaled, exec, z.len());
            reference::add_scaled(z.as_slice(), alpha, x.as_slice(), out.as_mut_slice());
            Ok(())
        }
        Executor::Par(cfg) => {
            let _obs = fused_guard::<T>(FusedBlasKind::AddScaled, exec, z.len());
            par::add_scaled(cfg, z.as_slice(), alpha, x.as_slice(), out.as_mut_slice());
            Ok(())
        }
        Executor::Xla(_) => composed_add_scaled(exec, z, alpha, x, out),
    }
}

fn composed_update_p<T: Value>(
    exec: &Arc<Executor>,
    r: &Dense<T>,
    beta: T,
    omega: T,
    v: &Dense<T>,
    p: &mut Dense<T>,
) -> Result<()> {
    axpy(exec, -omega, v, p)?;
    axpby(exec, T::one(), r, beta, p)
}

/// BiCGSTAB direction update `p = r + β (p − ω v)` in one sweep
/// (replaces `axpy` + `axpby`; `β == 0` overwrites `p = r`).
pub fn update_p<T: Value>(
    exec: &Arc<Executor>,
    r: &Dense<T>,
    beta: T,
    omega: T,
    v: &Dense<T>,
    p: &mut Dense<T>,
) -> Result<()> {
    check_same_len("update_p", r, v)?;
    check_same_len("update_p", r, p)?;
    if !super::fused_enabled() {
        return composed_update_p(exec, r, beta, omega, v, p);
    }
    match &**exec {
        Executor::Reference => {
            let _obs = fused_guard::<T>(FusedBlasKind::UpdateP, exec, r.len());
            reference::update_p(r.as_slice(), beta, omega, v.as_slice(), p.as_mut_slice());
            Ok(())
        }
        Executor::Par(cfg) => {
            let _obs = fused_guard::<T>(FusedBlasKind::UpdateP, exec, r.len());
            par::update_p(cfg, r.as_slice(), beta, omega, v.as_slice(), p.as_mut_slice());
            Ok(())
        }
        Executor::Xla(_) => composed_update_p(exec, r, beta, omega, v, p),
    }
}

fn composed_update_p_cgs<T: Value>(
    exec: &Arc<Executor>,
    u: &Dense<T>,
    beta: T,
    q: &Dense<T>,
    p: &mut Dense<T>,
) -> Result<()> {
    axpby(exec, T::one(), q, beta, p)?;
    axpby(exec, T::one(), u, beta, p)
}

/// CGS direction update `p = u + β (q + β p)` in one sweep (replaces
/// two `axpby` calls; `β == 0` overwrites `p = u`).
pub fn update_p_cgs<T: Value>(
    exec: &Arc<Executor>,
    u: &Dense<T>,
    beta: T,
    q: &Dense<T>,
    p: &mut Dense<T>,
) -> Result<()> {
    check_same_len("update_p_cgs", u, q)?;
    check_same_len("update_p_cgs", u, p)?;
    if !super::fused_enabled() {
        return composed_update_p_cgs(exec, u, beta, q, p);
    }
    match &**exec {
        Executor::Reference => {
            let _obs = fused_guard::<T>(FusedBlasKind::UpdatePCgs, exec, u.len());
            reference::update_p_cgs(u.as_slice(), beta, q.as_slice(), p.as_mut_slice());
            Ok(())
        }
        Executor::Par(cfg) => {
            let _obs = fused_guard::<T>(FusedBlasKind::UpdatePCgs, exec, u.len());
            par::update_p_cgs(cfg, u.as_slice(), beta, q.as_slice(), p.as_mut_slice());
            Ok(())
        }
        Executor::Xla(_) => composed_update_p_cgs(exec, u, beta, q, p),
    }
}

fn composed_sub_scaled_norm2<T: Value>(
    exec: &Arc<Executor>,
    s: &Dense<T>,
    omega: T,
    t: &Dense<T>,
    r: &mut Dense<T>,
) -> Result<T> {
    r.copy_from(s)?;
    axpy(exec, -omega, t, r)?;
    dot(exec, r, r)
}

/// `r = s − ω t; return r·r` in one sweep (the BiCGSTAB residual tail:
/// replaces copy + `axpy` + `dot`).
pub fn sub_scaled_norm2<T: Value>(
    exec: &Arc<Executor>,
    s: &Dense<T>,
    omega: T,
    t: &Dense<T>,
    r: &mut Dense<T>,
) -> Result<T> {
    check_same_len("sub_scaled_norm2", s, t)?;
    check_same_len("sub_scaled_norm2", s, r)?;
    if !super::fused_enabled() {
        return composed_sub_scaled_norm2(exec, s, omega, t, r);
    }
    match &**exec {
        Executor::Reference => {
            let _obs = fused_guard::<T>(FusedBlasKind::SubScaledNorm2, exec, s.len());
            Ok(reference::sub_scaled_norm2(
                s.as_slice(),
                omega,
                t.as_slice(),
                r.as_mut_slice(),
            ))
        }
        Executor::Par(cfg) => {
            let _obs = fused_guard::<T>(FusedBlasKind::SubScaledNorm2, exec, s.len());
            Ok(par::sub_scaled_norm2(
                cfg,
                s.as_slice(),
                omega,
                t.as_slice(),
                r.as_mut_slice(),
            ))
        }
        Executor::Xla(_) => composed_sub_scaled_norm2(exec, s, omega, t, r),
    }
}

fn composed_axpy2<T: Value>(
    exec: &Arc<Executor>,
    alpha: T,
    p: &Dense<T>,
    omega: T,
    s: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    axpy(exec, alpha, p, x)?;
    axpy(exec, omega, s, x)
}

/// Two stacked axpys `x += α p; x += ω s` in one sweep.
pub fn axpy2<T: Value>(
    exec: &Arc<Executor>,
    alpha: T,
    p: &Dense<T>,
    omega: T,
    s: &Dense<T>,
    x: &mut Dense<T>,
) -> Result<()> {
    check_same_len("axpy2", p, s)?;
    check_same_len("axpy2", p, x)?;
    if !super::fused_enabled() {
        return composed_axpy2(exec, alpha, p, omega, s, x);
    }
    match &**exec {
        Executor::Reference => {
            let _obs = fused_guard::<T>(FusedBlasKind::Axpy2, exec, p.len());
            reference::axpy2(alpha, p.as_slice(), omega, s.as_slice(), x.as_mut_slice());
            Ok(())
        }
        Executor::Par(cfg) => {
            let _obs = fused_guard::<T>(FusedBlasKind::Axpy2, exec, p.len());
            par::axpy2(cfg, alpha, p.as_slice(), omega, s.as_slice(), x.as_mut_slice());
            Ok(())
        }
        Executor::Xla(_) => composed_axpy2(exec, alpha, p, omega, s, x),
    }
}

fn composed_scal_into<T: Value>(
    exec: &Arc<Executor>,
    beta: T,
    x: &Dense<T>,
    out: &mut Dense<T>,
) -> Result<()> {
    out.copy_from(x)?;
    scal(exec, beta, out)
}

/// `out = β x` (overwrite; replaces copy + `scal`, and `β == 0` writes
/// zeros without reading `out`).
pub fn scal_into<T: Value>(
    exec: &Arc<Executor>,
    beta: T,
    x: &Dense<T>,
    out: &mut Dense<T>,
) -> Result<()> {
    check_same_len("scal_into", x, out)?;
    if !super::fused_enabled() {
        return composed_scal_into(exec, beta, x, out);
    }
    match &**exec {
        Executor::Reference => {
            let _obs = fused_guard::<T>(FusedBlasKind::ScalInto, exec, x.len());
            reference::scal_into(beta, x.as_slice(), out.as_mut_slice());
            Ok(())
        }
        Executor::Par(cfg) => {
            let _obs = fused_guard::<T>(FusedBlasKind::ScalInto, exec, x.len());
            par::scal_into(cfg, beta, x.as_slice(), out.as_mut_slice());
            Ok(())
        }
        Executor::Xla(_) => composed_scal_into(exec, beta, x, out),
    }
}

fn composed_dot_axpy<T: Value>(exec: &Arc<Executor>, v: &Dense<T>, w: &mut Dense<T>) -> Result<T> {
    let h = dot(exec, w, v)?;
    axpy(exec, -h, v, w)?;
    Ok(h)
}

/// Fused MGS projection pair `h = <w, v>; w -= h·v` in one sweep
/// (replaces `dot` + `axpy`).
pub fn dot_axpy<T: Value>(exec: &Arc<Executor>, v: &Dense<T>, w: &mut Dense<T>) -> Result<T> {
    check_same_len("dot_axpy", v, w)?;
    if !super::fused_enabled() {
        return composed_dot_axpy(exec, v, w);
    }
    match &**exec {
        Executor::Reference => {
            let _obs = fused_guard::<T>(FusedBlasKind::DotAxpy, exec, v.len());
            Ok(reference::dot_axpy(v.as_slice(), w.as_mut_slice()))
        }
        Executor::Par(cfg) => {
            let _obs = fused_guard::<T>(FusedBlasKind::DotAxpy, exec, v.len());
            Ok(par::dot_axpy(cfg, v.as_slice(), w.as_mut_slice()))
        }
        Executor::Xla(_) => composed_dot_axpy(exec, v, w),
    }
}

// ------------------------------------------------------------ batched MGS
//
// The GMRES orthogonalization works on a growing block of basis
// vectors, so these two take a `&[&Dense<T>]` block instead of fixed
// operands. Traffic depends on the basis size k — the guards use the
// explicit `perfmodel::traffic::mgs_*` models rather than a
// `FusedBlasKind` entry.

/// Observe guard for the batched MGS projection over a k-vector basis.
#[inline]
fn mgs_project_guard<T: Value>(
    exec: &Arc<Executor>,
    k: usize,
    n: usize,
) -> Option<observe::KernelGuard> {
    observe::blas_guard(
        "mgs_project",
        exec.name(),
        crate::perfmodel::traffic::mgs_project_flops(k, n),
        crate::perfmodel::traffic::mgs_project_bytes(k, n, T::PRECISION),
    )
}

/// Observe guard for the batched basis update over a k-vector basis.
#[inline]
fn mgs_update_guard<T: Value>(
    exec: &Arc<Executor>,
    k: usize,
    n: usize,
) -> Option<observe::KernelGuard> {
    observe::blas_guard(
        "mgs_update",
        exec.name(),
        crate::perfmodel::traffic::mgs_update_flops(k, n),
        crate::perfmodel::traffic::mgs_update_bytes(k, n, T::PRECISION),
    )
}

fn composed_mgs_project<T: Value>(
    exec: &Arc<Executor>,
    basis: &[&Dense<T>],
    w: &mut Dense<T>,
    h: &mut [T],
) -> Result<T> {
    for (i, vi) in basis.iter().enumerate() {
        let hij = dot(exec, w, vi)?;
        h[i] = hij;
        axpy(exec, -hij, vi, w)?;
    }
    dot(exec, w, w)
}

/// Full modified-Gram-Schmidt sweep of `w` against the basis block:
/// `h[i] = <w, v_i>; w -= h[i]·v_i` for every column, returning `<w, w>`
/// of the projected remainder (the caller takes the square root for the
/// subdiagonal Hessenberg entry). The fused host kernels pipeline the
/// subtraction of column i with the projection onto column i+1, so `w`
/// is swept once per basis vector instead of twice — bit-identical to
/// the composed `dot`/`axpy`/`dot` chain per executor.
pub fn mgs_project<T: Value>(
    exec: &Arc<Executor>,
    basis: &[&Dense<T>],
    w: &mut Dense<T>,
    h: &mut [T],
) -> Result<T> {
    for &vi in basis {
        check_same_len("mgs_project", vi, w)?;
    }
    if h.len() < basis.len() {
        return Err(SparkleError::dim(
            "mgs_project",
            format!(
                "{} coefficient slots for {} basis vectors",
                h.len(),
                basis.len()
            ),
        ));
    }
    if !super::fused_enabled() {
        return composed_mgs_project(exec, basis, w, h);
    }
    match &**exec {
        Executor::Reference => {
            let _obs = mgs_project_guard::<T>(exec, basis.len(), w.len());
            let vs: Vec<&[T]> = basis.iter().map(|v| v.as_slice()).collect();
            Ok(reference::mgs_project(&vs, w.as_mut_slice(), h))
        }
        Executor::Par(cfg) => {
            let _obs = mgs_project_guard::<T>(exec, basis.len(), w.len());
            let vs: Vec<&[T]> = basis.iter().map(|v| v.as_slice()).collect();
            Ok(par::mgs_project(cfg, &vs, w.as_mut_slice(), h))
        }
        Executor::Xla(_) => composed_mgs_project(exec, basis, w, h),
    }
}

fn composed_mgs_update<T: Value>(
    exec: &Arc<Executor>,
    basis: &[&Dense<T>],
    y: &[T],
    x: &mut Dense<T>,
) -> Result<()> {
    for (j, vj) in basis.iter().enumerate() {
        axpy(exec, y[j], vj, x)?;
    }
    Ok(())
}

/// Batched basis update `x += Σ_j y_j·v_j` (gemv-like over the basis
/// block; replaces one `axpy` per column with a single sweep of `x`).
pub fn mgs_update<T: Value>(
    exec: &Arc<Executor>,
    basis: &[&Dense<T>],
    y: &[T],
    x: &mut Dense<T>,
) -> Result<()> {
    if basis.len() != y.len() {
        return Err(SparkleError::dim(
            "mgs_update",
            format!("{} coefficients for {} basis vectors", y.len(), basis.len()),
        ));
    }
    for &vj in basis {
        check_same_len("mgs_update", vj, x)?;
    }
    if basis.is_empty() {
        return Ok(());
    }
    if !super::fused_enabled() {
        return composed_mgs_update(exec, basis, y, x);
    }
    match &**exec {
        Executor::Reference => {
            let _obs = mgs_update_guard::<T>(exec, basis.len(), x.len());
            let vs: Vec<&[T]> = basis.iter().map(|v| v.as_slice()).collect();
            reference::mgs_update(&vs, y, x.as_mut_slice());
            Ok(())
        }
        Executor::Par(cfg) => {
            let _obs = mgs_update_guard::<T>(exec, basis.len(), x.len());
            let vs: Vec<&[T]> = basis.iter().map(|v| v.as_slice()).collect();
            par::mgs_update(cfg, &vs, y, x.as_mut_slice());
            Ok(())
        }
        Executor::Xla(_) => composed_mgs_update(exec, basis, y, x),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::dim::Dim2;

    #[test]
    fn dispatch_reference_and_par_agree() {
        for exec in [Executor::reference(), Executor::par_with_threads(3)] {
            let x = Dense::vector(exec.clone(), &[1.0f64, 2.0, 3.0]);
            let mut y = Dense::vector(exec.clone(), &[1.0f64, 1.0, 1.0]);
            axpy(&exec, 2.0, &x, &mut y).unwrap();
            assert_eq!(y.as_slice(), &[3.0, 5.0, 7.0], "exec {}", exec.name());
            assert_eq!(dot(&exec, &x, &x).unwrap(), 14.0);
            assert!((norm2(&exec, &x).unwrap() - 14.0f64.sqrt()).abs() < 1e-14);
            scal(&exec, 0.5, &mut y).unwrap();
            assert_eq!(y.as_slice(), &[1.5, 2.5, 3.5]);
            axpby(&exec, 1.0, &x, -1.0, &mut y).unwrap();
            assert_eq!(y.as_slice(), &[-0.5, -0.5, -0.5]);
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let exec = Executor::reference();
        let x = Dense::vector(exec.clone(), &[1.0f64, 2.0]);
        let mut y = Dense::<f64>::zeros(exec.clone(), Dim2::new(3, 1));
        assert!(axpy(&exec, 1.0, &x, &mut y).is_err());
        assert!(dot(&exec, &x, &y).is_err());
    }
}
