//! BLAS-1 dispatch: one entry point per operation, switching on the
//! executor (the paper's `operations` class, §2).
//!
//! Degraded mode: when the xla runtime's circuit breaker is open
//! (repeated dispatch failures — see `resilience/retry.rs`), the Xla
//! arms route to the host `par` kernels instead. The check happens
//! *before* the xla call so a mutating kernel never runs twice on the
//! same operand; while the breaker is closed, failures propagate
//! unchanged.

use std::sync::Arc;

use crate::core::error::{Result, SparkleError};
use crate::core::executor::{Executor, ParConfig};
use crate::core::types::Value;
use crate::kernels::{par, reference, xla};
use crate::matrix::dense::Dense;
use crate::observe;

fn check_same_len<T: Value>(op: &'static str, x: &Dense<T>, y: &Dense<T>) -> Result<()> {
    if x.shape() != y.shape() {
        return Err(SparkleError::dim(
            op,
            format!("{} vs {}", x.shape(), y.shape()),
        ));
    }
    Ok(())
}

/// Observe guard with the textbook BLAS-1 model: `flops_per_elem * n`
/// flops and `streams * n * sizeof(T)` useful bytes (one stream per
/// vector read or written).
#[inline]
fn guard<T: Value>(
    name: &'static str,
    exec: &Arc<Executor>,
    flops_per_elem: f64,
    streams: f64,
    n: usize,
) -> Option<observe::KernelGuard> {
    let n = n as f64;
    let elem = T::PRECISION.bytes() as f64;
    observe::blas_guard(name, exec.name(), flops_per_elem * n, streams * elem * n)
}

/// y += alpha * x.
pub fn axpy<T: Value>(exec: &Arc<Executor>, alpha: T, x: &Dense<T>, y: &mut Dense<T>) -> Result<()> {
    check_same_len("axpy", x, y)?;
    let _obs = guard::<T>("axpy", exec, 2.0, 3.0, x.len());
    match &**exec {
        Executor::Reference => reference::axpy(alpha, x.as_slice(), y.as_mut_slice()),
        Executor::Par(cfg) => par::axpy(cfg, alpha, x.as_slice(), y.as_mut_slice()),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::axpy(&ParConfig::default(), alpha, x.as_slice(), y.as_mut_slice())
            } else {
                xla::axpy(&e.runtime, alpha, x.as_slice(), y.as_mut_slice())?
            }
        }
    }
    Ok(())
}

/// y = alpha * x + beta * y.
pub fn axpby<T: Value>(
    exec: &Arc<Executor>,
    alpha: T,
    x: &Dense<T>,
    beta: T,
    y: &mut Dense<T>,
) -> Result<()> {
    check_same_len("axpby", x, y)?;
    let _obs = guard::<T>("axpby", exec, 3.0, 3.0, x.len());
    match &**exec {
        Executor::Reference => reference::axpby(alpha, x.as_slice(), beta, y.as_mut_slice()),
        Executor::Par(cfg) => par::axpby(cfg, alpha, x.as_slice(), beta, y.as_mut_slice()),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::axpby(
                    &ParConfig::default(),
                    alpha,
                    x.as_slice(),
                    beta,
                    y.as_mut_slice(),
                )
            } else {
                xla::axpby(&e.runtime, alpha, x.as_slice(), beta, y.as_mut_slice())?
            }
        }
    }
    Ok(())
}

/// x *= beta.
pub fn scal<T: Value>(exec: &Arc<Executor>, beta: T, x: &mut Dense<T>) -> Result<()> {
    let _obs = guard::<T>("scal", exec, 1.0, 2.0, x.len());
    match &**exec {
        Executor::Reference => reference::scal(beta, x.as_mut_slice()),
        Executor::Par(cfg) => par::scal(cfg, beta, x.as_mut_slice()),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::scal(&ParConfig::default(), beta, x.as_mut_slice())
            } else {
                xla::scal(&e.runtime, beta, x.as_mut_slice())?
            }
        }
    }
    Ok(())
}

/// Dot product of two equally-shaped dense objects (flattened).
pub fn dot<T: Value>(exec: &Arc<Executor>, x: &Dense<T>, y: &Dense<T>) -> Result<T> {
    check_same_len("dot", x, y)?;
    let _obs = guard::<T>("dot", exec, 2.0, 2.0, x.len());
    Ok(match &**exec {
        Executor::Reference => reference::dot(x.as_slice(), y.as_slice()),
        Executor::Par(cfg) => par::dot(cfg, x.as_slice(), y.as_slice()),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::dot(&ParConfig::default(), x.as_slice(), y.as_slice())
            } else {
                xla::dot(&e.runtime, x.as_slice(), y.as_slice())?
            }
        }
    })
}

/// Euclidean norm.
pub fn norm2<T: Value>(exec: &Arc<Executor>, x: &Dense<T>) -> Result<T> {
    let _obs = guard::<T>("norm2", exec, 2.0, 1.0, x.len());
    Ok(match &**exec {
        Executor::Reference => reference::norm2(x.as_slice()),
        Executor::Par(cfg) => par::norm2(cfg, x.as_slice()),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::norm2(&ParConfig::default(), x.as_slice())
            } else {
                xla::norm2(&e.runtime, x.as_slice())?
            }
        }
    })
}

/// z = x ⊙ y (element-wise product).
pub fn ew_mul<T: Value>(
    exec: &Arc<Executor>,
    x: &Dense<T>,
    y: &Dense<T>,
    z: &mut Dense<T>,
) -> Result<()> {
    check_same_len("ew_mul", x, y)?;
    check_same_len("ew_mul", x, z)?;
    let _obs = guard::<T>("ew_mul", exec, 1.0, 3.0, x.len());
    match &**exec {
        Executor::Reference => reference::ew_mul(x.as_slice(), y.as_slice(), z.as_mut_slice()),
        Executor::Par(cfg) => par::ew_mul(cfg, x.as_slice(), y.as_slice(), z.as_mut_slice()),
        Executor::Xla(e) => {
            if e.runtime.degraded() {
                par::ew_mul(
                    &ParConfig::default(),
                    x.as_slice(),
                    y.as_slice(),
                    z.as_mut_slice(),
                )
            } else {
                xla::ew_mul(&e.runtime, x.as_slice(), y.as_slice(), z.as_mut_slice())?
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::dim::Dim2;

    #[test]
    fn dispatch_reference_and_par_agree() {
        for exec in [Executor::reference(), Executor::par_with_threads(3)] {
            let x = Dense::vector(exec.clone(), &[1.0f64, 2.0, 3.0]);
            let mut y = Dense::vector(exec.clone(), &[1.0f64, 1.0, 1.0]);
            axpy(&exec, 2.0, &x, &mut y).unwrap();
            assert_eq!(y.as_slice(), &[3.0, 5.0, 7.0], "exec {}", exec.name());
            assert_eq!(dot(&exec, &x, &x).unwrap(), 14.0);
            assert!((norm2(&exec, &x).unwrap() - 14.0f64.sqrt()).abs() < 1e-14);
            scal(&exec, 0.5, &mut y).unwrap();
            assert_eq!(y.as_slice(), &[1.5, 2.5, 3.5]);
            axpby(&exec, 1.0, &x, -1.0, &mut y).unwrap();
            assert_eq!(y.as_slice(), &[-0.5, -0.5, -0.5]);
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let exec = Executor::reference();
        let x = Dense::vector(exec.clone(), &[1.0f64, 2.0]);
        let mut y = Dense::<f64>::zeros(exec.clone(), Dim2::new(3, 1));
        assert!(axpy(&exec, 1.0, &x, &mut y).is_err());
        assert!(dot(&exec, &x, &y).is_err());
    }
}
