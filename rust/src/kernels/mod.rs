//! Kernel layer: per-executor implementations behind a common dispatch
//! surface (the paper's Figure 1 "core ↔ backends" split).
//!
//! `blas` and `spmv` hold the dispatch functions every format/solver
//! calls; `reference`, `par` and `xla` hold the three backend
//! implementations. The reference backend is the correctness oracle —
//! `par` and `xla` are tested against it on random inputs.

pub mod blas;
pub mod par;
pub(crate) mod ptr;
pub mod reference;
pub mod spmv;
pub mod stream;
pub mod xla;
