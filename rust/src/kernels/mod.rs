//! Kernel layer: per-executor implementations behind a common dispatch
//! surface (the paper's Figure 1 "core ↔ backends" split).
//!
//! `blas` and `spmv` hold the dispatch functions every format/solver
//! calls; `reference`, `par` and `xla` hold the three backend
//! implementations. The reference backend is the correctness oracle —
//! `par` and `xla` are tested against it on random inputs.

pub mod blas;
pub mod par;
pub(crate) mod ptr;
pub mod reference;
pub mod spmv;
pub mod stream;
pub mod xla;

use std::sync::atomic::{AtomicBool, Ordering};

/// Global switch for the fused host kernels (`dot_norm2`,
/// `axpy_sub_norm2`, `spmv_dot`, ...). On by default; the ablation
/// bench flips it off to time the composed baseline through the exact
/// same driver code. The fused kernels are bit-identical to their
/// composed sequences per executor, so toggling never changes results —
/// only the number of memory sweeps.
static FUSED: AtomicBool = AtomicBool::new(true);

/// Whether fused host kernels are dispatched.
#[inline]
pub fn fused_enabled() -> bool {
    FUSED.load(Ordering::Relaxed)
}

/// Enable/disable the fused host kernels (ablation baseline switch).
pub fn set_fused_enabled(on: bool) {
    FUSED.store(on, Ordering::Relaxed);
}
