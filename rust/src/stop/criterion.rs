//! Stopping criteria for iterative solvers.
//!
//! Mirrors Ginkgo's combined-criterion design: a solver is handed one
//! [`Criterion`] that may combine an iteration budget with residual
//! thresholds; the solver consults it once per iteration.

/// Why a solver broke down (numerically diverged rather than merely
/// running out of budget).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Breakdown {
    /// The residual norm became NaN or infinite.
    NanResidual,
    /// A recurrence scalar (rho, omega, p·Ap, ...) became NaN/Inf.
    NanOperand { what: &'static str },
    /// A recurrence denominator collapsed to (near-)zero, so the next
    /// update would divide by it.
    ZeroDenominator { what: &'static str },
    /// The residual made no meaningful progress over a full window of
    /// iterations.
    Stagnation { window: usize },
}

impl std::fmt::Display for Breakdown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Breakdown::NanResidual => write!(f, "residual norm is NaN/Inf"),
            Breakdown::NanOperand { what } => write!(f, "recurrence scalar `{what}` is NaN/Inf"),
            Breakdown::ZeroDenominator { what } => {
                write!(f, "recurrence denominator `{what}` collapsed to zero")
            }
            Breakdown::Stagnation { window } => {
                write!(f, "no residual progress over {window} iterations")
            }
        }
    }
}

/// Why (or whether) a solver stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopStatus {
    /// Keep iterating.
    Continue,
    /// Residual criterion satisfied.
    Converged,
    /// Iteration budget exhausted without convergence.
    BudgetExhausted,
    /// The iteration broke down numerically; the current iterate is not
    /// trustworthy and further iterations cannot repair it.
    Diverged(Breakdown),
}

/// Combined stopping criterion.
#[derive(Debug, Clone)]
pub struct Criterion {
    /// Maximum number of iterations (0 = unlimited — discouraged).
    pub max_iters: usize,
    /// Relative residual threshold: stop when `||r|| <= rel_tol * ||b||`.
    pub rel_tol: f64,
    /// Absolute residual threshold: stop when `||r|| <= abs_tol`.
    pub abs_tol: f64,
    /// Wall-clock budget; `None` = unlimited (Ginkgo's `Time` criterion).
    pub time_limit: Option<std::time::Duration>,
    /// Start instant for the time budget, armed by the solver via
    /// [`Criterion::started`] at solve entry.
    start: Option<std::time::Instant>,
}

impl Default for Criterion {
    fn default() -> Self {
        Self {
            max_iters: 1000,
            rel_tol: 1e-8,
            abs_tol: 0.0,
            time_limit: None,
            start: None,
        }
    }
}

impl Criterion {
    /// Iteration-count-only criterion (the paper's solver benchmarks run
    /// exactly 1000 iterations regardless of convergence, §6.4).
    pub fn iterations(max_iters: usize) -> Self {
        Self {
            max_iters,
            rel_tol: 0.0,
            abs_tol: 0.0,
            ..Default::default()
        }
    }

    /// Relative-residual criterion with an iteration budget.
    pub fn residual(rel_tol: f64, max_iters: usize) -> Self {
        Self {
            max_iters,
            rel_tol,
            abs_tol: 0.0,
            ..Default::default()
        }
    }

    /// Add a wall-clock budget; the clock starts at [`Criterion::started`].
    pub fn with_time_limit(mut self, limit: std::time::Duration) -> Self {
        self.time_limit = Some(limit);
        self
    }

    /// Arm the time budget (called by solvers at solve entry). No-op
    /// without a time limit.
    pub fn started(&self) -> Self {
        let mut c = self.clone();
        if c.time_limit.is_some() {
            c.start = Some(std::time::Instant::now());
        }
        c
    }

    /// Evaluate after `iters` completed iterations with residual `resnorm`
    /// and initial/rhs norm `bnorm`.
    ///
    /// NaN-safe: a NaN/Inf residual reports [`StopStatus::Diverged`]
    /// before any threshold is consulted — NaN comparisons are all
    /// false, so without this a poisoned solve would silently spin to
    /// `max_iters` (or, worse, a NaN `bnorm` could mask convergence).
    pub fn check(&self, iters: usize, resnorm: f64, bnorm: f64) -> StopStatus {
        if !resnorm.is_finite() {
            return StopStatus::Diverged(Breakdown::NanResidual);
        }
        let rel_hit = self.rel_tol > 0.0 && resnorm <= self.rel_tol * bnorm;
        let abs_hit = self.abs_tol > 0.0 && resnorm <= self.abs_tol;
        if rel_hit || abs_hit {
            return StopStatus::Converged;
        }
        if self.max_iters > 0 && iters >= self.max_iters {
            return StopStatus::BudgetExhausted;
        }
        if let (Some(limit), Some(start)) = (self.time_limit, self.start) {
            if start.elapsed() >= limit {
                return StopStatus::BudgetExhausted;
            }
        }
        StopStatus::Continue
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iteration_only_never_converges() {
        let c = Criterion::iterations(10);
        assert_eq!(c.check(5, 1e-30, 1.0), StopStatus::Continue);
        assert_eq!(c.check(10, 1e-30, 1.0), StopStatus::BudgetExhausted);
    }

    #[test]
    fn relative_residual() {
        let c = Criterion::residual(1e-6, 100);
        assert_eq!(c.check(1, 1e-3, 1.0), StopStatus::Continue);
        assert_eq!(c.check(1, 9e-7, 1.0), StopStatus::Converged);
        // scaled by bnorm
        assert_eq!(c.check(1, 9e-4, 1000.0), StopStatus::Converged);
    }

    #[test]
    fn absolute_residual() {
        let c = Criterion {
            max_iters: 100,
            rel_tol: 0.0,
            abs_tol: 1e-10,
            ..Default::default()
        };
        assert_eq!(c.check(1, 1e-9, 1e20), StopStatus::Continue);
        assert_eq!(c.check(1, 1e-11, 1e20), StopStatus::Converged);
    }

    #[test]
    fn time_budget_stops() {
        let c = Criterion::iterations(1_000_000)
            .with_time_limit(std::time::Duration::from_millis(5))
            .started();
        assert_eq!(c.check(1, 1.0, 1.0), StopStatus::Continue);
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(c.check(2, 1.0, 1.0), StopStatus::BudgetExhausted);
        // converged still wins over time
        let c2 = Criterion::residual(1e-1, 10)
            .with_time_limit(std::time::Duration::from_nanos(1))
            .started();
        assert_eq!(c2.check(1, 1e-3, 1.0), StopStatus::Converged);
    }

    #[test]
    fn unarmed_time_limit_is_inert() {
        let c = Criterion::iterations(10)
            .with_time_limit(std::time::Duration::from_nanos(1));
        // not started(): never trips
        assert_eq!(c.check(1, 1.0, 1.0), StopStatus::Continue);
    }

    #[test]
    fn budget_wins_only_when_not_converged() {
        let c = Criterion::residual(1e-6, 10);
        assert_eq!(c.check(10, 1e-9, 1.0), StopStatus::Converged);
        assert_eq!(c.check(10, 1.0, 1.0), StopStatus::BudgetExhausted);
    }

    #[test]
    fn nan_residual_never_converges() {
        let c = Criterion::residual(1e-6, 10);
        assert_eq!(
            c.check(1, f64::NAN, 1.0),
            StopStatus::Diverged(Breakdown::NanResidual)
        );
        assert_eq!(
            c.check(1, f64::INFINITY, 1.0),
            StopStatus::Diverged(Breakdown::NanResidual)
        );
        // a NaN bnorm must not let a NaN resnorm through either
        assert_eq!(
            c.check(1, f64::NAN, f64::NAN),
            StopStatus::Diverged(Breakdown::NanResidual)
        );
        // diverged outranks an exhausted budget
        assert_eq!(
            c.check(10, f64::NAN, 1.0),
            StopStatus::Diverged(Breakdown::NanResidual)
        );
        // finite residuals are unaffected even with weird bnorm
        assert_eq!(c.check(1, 1.0, f64::NAN), StopStatus::Continue);
    }

    #[test]
    fn breakdown_displays() {
        assert!(Breakdown::NanResidual.to_string().contains("NaN"));
        assert!(Breakdown::NanOperand { what: "rho" }.to_string().contains("rho"));
        assert!(Breakdown::ZeroDenominator { what: "omega" }
            .to_string()
            .contains("omega"));
        assert!(Breakdown::Stagnation { window: 25 }.to_string().contains("25"));
    }
}
