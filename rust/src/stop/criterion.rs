//! Stopping criteria for iterative solvers.
//!
//! Mirrors Ginkgo's combined-criterion design: a solver is handed one
//! [`Criterion`] that may combine an iteration budget with residual
//! thresholds; the solver consults it once per iteration.

/// Why (or whether) a solver stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopStatus {
    /// Keep iterating.
    Continue,
    /// Residual criterion satisfied.
    Converged,
    /// Iteration budget exhausted without convergence.
    BudgetExhausted,
}

/// Combined stopping criterion.
#[derive(Debug, Clone)]
pub struct Criterion {
    /// Maximum number of iterations (0 = unlimited — discouraged).
    pub max_iters: usize,
    /// Relative residual threshold: stop when `||r|| <= rel_tol * ||b||`.
    pub rel_tol: f64,
    /// Absolute residual threshold: stop when `||r|| <= abs_tol`.
    pub abs_tol: f64,
    /// Wall-clock budget; `None` = unlimited (Ginkgo's `Time` criterion).
    pub time_limit: Option<std::time::Duration>,
    /// Start instant for the time budget, armed by the solver via
    /// [`Criterion::started`] at solve entry.
    start: Option<std::time::Instant>,
}

impl Default for Criterion {
    fn default() -> Self {
        Self {
            max_iters: 1000,
            rel_tol: 1e-8,
            abs_tol: 0.0,
            time_limit: None,
            start: None,
        }
    }
}

impl Criterion {
    /// Iteration-count-only criterion (the paper's solver benchmarks run
    /// exactly 1000 iterations regardless of convergence, §6.4).
    pub fn iterations(max_iters: usize) -> Self {
        Self {
            max_iters,
            rel_tol: 0.0,
            abs_tol: 0.0,
            ..Default::default()
        }
    }

    /// Relative-residual criterion with an iteration budget.
    pub fn residual(rel_tol: f64, max_iters: usize) -> Self {
        Self {
            max_iters,
            rel_tol,
            abs_tol: 0.0,
            ..Default::default()
        }
    }

    /// Add a wall-clock budget; the clock starts at [`Criterion::started`].
    pub fn with_time_limit(mut self, limit: std::time::Duration) -> Self {
        self.time_limit = Some(limit);
        self
    }

    /// Arm the time budget (called by solvers at solve entry). No-op
    /// without a time limit.
    pub fn started(&self) -> Self {
        let mut c = self.clone();
        if c.time_limit.is_some() {
            c.start = Some(std::time::Instant::now());
        }
        c
    }

    /// Evaluate after `iters` completed iterations with residual `resnorm`
    /// and initial/rhs norm `bnorm`.
    pub fn check(&self, iters: usize, resnorm: f64, bnorm: f64) -> StopStatus {
        let rel_hit = self.rel_tol > 0.0 && resnorm <= self.rel_tol * bnorm;
        let abs_hit = self.abs_tol > 0.0 && resnorm <= self.abs_tol;
        if rel_hit || abs_hit {
            return StopStatus::Converged;
        }
        if self.max_iters > 0 && iters >= self.max_iters {
            return StopStatus::BudgetExhausted;
        }
        if let (Some(limit), Some(start)) = (self.time_limit, self.start) {
            if start.elapsed() >= limit {
                return StopStatus::BudgetExhausted;
            }
        }
        StopStatus::Continue
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iteration_only_never_converges() {
        let c = Criterion::iterations(10);
        assert_eq!(c.check(5, 1e-30, 1.0), StopStatus::Continue);
        assert_eq!(c.check(10, 1e-30, 1.0), StopStatus::BudgetExhausted);
    }

    #[test]
    fn relative_residual() {
        let c = Criterion::residual(1e-6, 100);
        assert_eq!(c.check(1, 1e-3, 1.0), StopStatus::Continue);
        assert_eq!(c.check(1, 9e-7, 1.0), StopStatus::Converged);
        // scaled by bnorm
        assert_eq!(c.check(1, 9e-4, 1000.0), StopStatus::Converged);
    }

    #[test]
    fn absolute_residual() {
        let c = Criterion {
            max_iters: 100,
            rel_tol: 0.0,
            abs_tol: 1e-10,
            ..Default::default()
        };
        assert_eq!(c.check(1, 1e-9, 1e20), StopStatus::Continue);
        assert_eq!(c.check(1, 1e-11, 1e20), StopStatus::Converged);
    }

    #[test]
    fn time_budget_stops() {
        let c = Criterion::iterations(1_000_000)
            .with_time_limit(std::time::Duration::from_millis(5))
            .started();
        assert_eq!(c.check(1, 1.0, 1.0), StopStatus::Continue);
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(c.check(2, 1.0, 1.0), StopStatus::BudgetExhausted);
        // converged still wins over time
        let c2 = Criterion::residual(1e-1, 10)
            .with_time_limit(std::time::Duration::from_nanos(1))
            .started();
        assert_eq!(c2.check(1, 1e-3, 1.0), StopStatus::Converged);
    }

    #[test]
    fn unarmed_time_limit_is_inert() {
        let c = Criterion::iterations(10)
            .with_time_limit(std::time::Duration::from_nanos(1));
        // not started(): never trips
        assert_eq!(c.check(1, 1.0, 1.0), StopStatus::Continue);
    }

    #[test]
    fn budget_wins_only_when_not_converged() {
        let c = Criterion::residual(1e-6, 10);
        assert_eq!(c.check(10, 1e-9, 1.0), StopStatus::Converged);
        assert_eq!(c.check(10, 1.0, 1.0), StopStatus::BudgetExhausted);
    }
}
