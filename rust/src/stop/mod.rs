//! Stopping criteria (Ginkgo's `stop` namespace).

mod criterion;

pub use criterion::{Criterion, StopStatus};
