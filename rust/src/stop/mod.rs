//! Stopping criteria (Ginkgo's `stop` namespace).

mod criterion;

pub use criterion::{Breakdown, Criterion, StopStatus};
