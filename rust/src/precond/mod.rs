//! Preconditioners (Ginkgo's `preconditioner` namespace).

mod jacobi;

pub use jacobi::{BlockJacobi, Jacobi};
