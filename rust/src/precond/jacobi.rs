//! Jacobi preconditioners: scalar (diagonal) and block-diagonal.
//!
//! Ginkgo's flagship preconditioner family [Flegar et al. 2021]. The
//! scalar variant applies `z = D⁻¹ r` (one `ew_mul`); the block variant
//! inverts small diagonal blocks at generation time and applies them as
//! dense blocks.

use std::sync::Arc;

use crate::core::dim::Dim2;
use crate::core::error::{Result, SparkleError};
use crate::core::executor::Executor;
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::kernels::blas;
use crate::matrix::csr::Csr;
use crate::matrix::dense::Dense;

/// Scalar Jacobi: `M = diag(A)⁻¹`.
pub struct Jacobi<T: Value> {
    exec: Arc<Executor>,
    dim: Dim2,
    inv_diag: Dense<T>,
}

impl<T: Value> Jacobi<T> {
    /// Build from the diagonal of a CSR matrix. Zero diagonal entries are
    /// rejected (the preconditioner would be singular).
    pub fn from_csr(a: &Csr<T>) -> Result<Self> {
        let diag = a.extract_diagonal();
        Self::from_diagonal(a.executor().clone(), &diag)
    }

    /// Build directly from a diagonal.
    pub fn from_diagonal(exec: Arc<Executor>, diag: &[T]) -> Result<Self> {
        let mut inv = Vec::with_capacity(diag.len());
        for (i, &d) in diag.iter().enumerate() {
            if d.is_zero() {
                return Err(SparkleError::InvalidStructure(format!(
                    "jacobi: zero diagonal at row {i}"
                )));
            }
            inv.push(T::one() / d);
        }
        Ok(Self {
            exec: exec.clone(),
            dim: Dim2::square(diag.len()),
            inv_diag: Dense::vector(exec, &inv),
        })
    }

    /// The stored inverse diagonal.
    pub fn inv_diag(&self) -> &[T] {
        self.inv_diag.as_slice()
    }
}

impl<T: Value> LinOp<T> for Jacobi<T> {
    fn shape(&self) -> Dim2 {
        self.dim
    }

    fn executor(&self) -> &Arc<Executor> {
        &self.exec
    }

    fn apply(&self, b: &Dense<T>, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        blas::ew_mul(&self.exec, &self.inv_diag, b, x)
    }

    fn op_name(&self) -> &'static str {
        "jacobi"
    }
}

/// Block-Jacobi: `M = diag(A_11⁻¹, A_22⁻¹, ...)` with uniform block size.
///
/// Blocks are extracted from the CSR matrix, densified, and inverted with
/// Gauss-Jordan at generation time (blocks are tiny: ≤ 32).
pub struct BlockJacobi<T: Value> {
    exec: Arc<Executor>,
    dim: Dim2,
    block_size: usize,
    /// Inverted blocks, row-major, concatenated; the last block may be
    /// smaller than `block_size`.
    inv_blocks: Vec<T>,
}

impl<T: Value> BlockJacobi<T> {
    /// Build with uniform `block_size` from a square CSR matrix.
    pub fn from_csr(a: &Csr<T>, block_size: usize) -> Result<Self> {
        if block_size == 0 || block_size > 32 {
            return Err(SparkleError::InvalidStructure(
                "block size must be in 1..=32".into(),
            ));
        }
        let n = a.shape().rows;
        if !a.shape().is_square() {
            return Err(SparkleError::dim("block_jacobi", a.shape().to_string()));
        }
        let mut inv_blocks = Vec::new();
        let mut start = 0usize;
        while start < n {
            let bs = block_size.min(n - start);
            // densify the block
            let mut block = vec![T::zero(); bs * bs];
            for local in 0..bs {
                let i = start + local;
                for k in a.row_ptrs()[i] as usize..a.row_ptrs()[i + 1] as usize {
                    let c = a.col_idxs()[k] as usize;
                    if c >= start && c < start + bs {
                        block[local * bs + (c - start)] = a.values()[k];
                    }
                }
            }
            invert_in_place(&mut block, bs).map_err(|_| {
                SparkleError::InvalidStructure(format!(
                    "jacobi block at row {start} is singular"
                ))
            })?;
            inv_blocks.extend_from_slice(&block);
            start += bs;
        }
        Ok(Self {
            exec: a.executor().clone(),
            dim: a.shape(),
            block_size,
            inv_blocks,
        })
    }

    /// Uniform block size.
    pub fn block_size(&self) -> usize {
        self.block_size
    }
}

/// Gauss-Jordan inversion with partial pivoting; errors on singularity.
fn invert_in_place<T: Value>(a: &mut [T], n: usize) -> std::result::Result<(), ()> {
    let mut inv: Vec<T> = (0..n * n)
        .map(|i| if i / n == i % n { T::one() } else { T::zero() })
        .collect();
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if a[piv * n + col].is_zero() {
            return Err(());
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
                inv.swap(col * n + j, piv * n + j);
            }
        }
        let d = a[col * n + col];
        for j in 0..n {
            a[col * n + j] /= d;
            inv[col * n + j] /= d;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = a[r * n + col];
            if f.is_zero() {
                continue;
            }
            for j in 0..n {
                let acj = a[col * n + j];
                let icj = inv[col * n + j];
                a[r * n + j] -= f * acj;
                inv[r * n + j] -= f * icj;
            }
        }
    }
    a.copy_from_slice(&inv);
    Ok(())
}

impl<T: Value> LinOp<T> for BlockJacobi<T> {
    fn shape(&self) -> Dim2 {
        self.dim
    }

    fn executor(&self) -> &Arc<Executor> {
        &self.exec
    }

    fn apply(&self, b: &Dense<T>, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        let n = self.dim.rows;
        let bs = self.block_size;
        let bsl = b.as_slice();
        let xsl = x.as_mut_slice();
        let mut offset = 0usize; // into inv_blocks
        let mut start = 0usize;
        while start < n {
            let cur = bs.min(n - start);
            for r in 0..cur {
                let mut acc = T::zero();
                for c in 0..cur {
                    acc += self.inv_blocks[offset + r * cur + c] * bsl[start + c];
                }
                xsl[start + r] = acc;
            }
            offset += cur * cur;
            start += cur;
        }
        Ok(())
    }

    fn op_name(&self) -> &'static str {
        "block_jacobi"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::matrix_data::MatrixData;

    fn tridiag(n: usize) -> Csr<f64> {
        let mut d = MatrixData::new(Dim2::square(n));
        for i in 0..n {
            d.push(i as i32, i as i32, 4.0);
            if i + 1 < n {
                d.push(i as i32, (i + 1) as i32, -1.0);
                d.push((i + 1) as i32, i as i32, -1.0);
            }
        }
        d.normalize();
        Csr::from_data(Executor::reference(), &d).unwrap()
    }

    #[test]
    fn scalar_jacobi_applies_inverse_diagonal() {
        let a = tridiag(5);
        let m = Jacobi::from_csr(&a).unwrap();
        let b = Dense::vector(Executor::reference(), &[4.0, 8.0, 12.0, 16.0, 20.0]);
        let mut z = Dense::zeros(Executor::reference(), Dim2::new(5, 1));
        m.apply(&b, &mut z).unwrap();
        assert_eq!(z.as_slice(), &[1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn zero_diagonal_rejected() {
        let mut d = MatrixData::<f64>::new(Dim2::square(2));
        d.push(0, 0, 1.0);
        d.push(1, 0, 1.0); // no (1,1) entry
        d.normalize();
        let a = Csr::from_data(Executor::reference(), &d).unwrap();
        assert!(Jacobi::from_csr(&a).is_err());
    }

    #[test]
    fn block_jacobi_inverts_blocks_exactly() {
        // block size n -> the "preconditioner" is the exact inverse
        let n = 6;
        let a = tridiag(n);
        let m = BlockJacobi::from_csr(&a, n.min(32)).unwrap();
        let bv: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let b = Dense::vector(Executor::reference(), &bv);
        let mut z = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        m.apply(&b, &mut z).unwrap();
        // A z should equal b
        let mut az = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        a.apply(&z, &mut az).unwrap();
        for i in 0..n {
            assert!((az.as_slice()[i] - bv[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn block_jacobi_beats_scalar_on_block_structure() {
        // strong 2x2 coupling: block-2 Jacobi should solve in fewer
        // Richardson steps than scalar Jacobi
        let n = 40;
        let mut d = MatrixData::<f64>::new(Dim2::square(n));
        for i in (0..n).step_by(2) {
            d.push(i as i32, i as i32, 2.0);
            d.push((i + 1) as i32, (i + 1) as i32, 2.0);
            d.push(i as i32, (i + 1) as i32, 1.9);
            d.push((i + 1) as i32, i as i32, 1.9);
            if i + 2 < n {
                d.push(i as i32, (i + 2) as i32, 0.01);
            }
        }
        d.normalize();
        let a = Csr::from_data(Executor::reference(), &d).unwrap();
        let scalar = Jacobi::from_csr(&a).unwrap();
        let block = BlockJacobi::from_csr(&a, 2).unwrap();
        let b = Dense::filled(Executor::reference(), Dim2::new(n, 1), 1.0);
        use crate::solver::{Richardson, Solver, SolverConfig};
        use crate::stop::Criterion;
        let cfg = || SolverConfig::with_criterion(Criterion::residual(1e-8, 5000));
        let mut x1 = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        let r_scalar = Richardson::new(cfg(), 0.9)
            .with_preconditioner(Arc::new(scalar))
            .solve(&a, &b, &mut x1)
            .unwrap();
        let mut x2 = Dense::zeros(Executor::reference(), Dim2::new(n, 1));
        let r_block = Richardson::new(cfg(), 0.9)
            .with_preconditioner(Arc::new(block))
            .solve(&a, &b, &mut x2)
            .unwrap();
        assert!(r_block.converged);
        assert!(
            r_block.iterations < r_scalar.iterations,
            "block {} vs scalar {}",
            r_block.iterations,
            r_scalar.iterations
        );
    }

    #[test]
    fn gauss_jordan_known_inverse() {
        // [[2, 0], [0, 4]] -> [[0.5, 0], [0, 0.25]]
        let mut m = vec![2.0f64, 0.0, 0.0, 4.0];
        invert_in_place(&mut m, 2).unwrap();
        assert_eq!(m, vec![0.5, 0.0, 0.0, 0.25]);
        // singular rejected
        let mut s = vec![1.0f64, 2.0, 2.0, 4.0];
        assert!(invert_in_place(&mut s, 2).is_err());
    }
}
