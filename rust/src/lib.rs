//! `sparkle` — a platform-portable sparse linear algebra library.
//!
//! Reproduction of *"Porting a sparse linear algebra math library to
//! Intel GPUs"* (Tsai, Cojean, Anzt, 2021) in the three-layer
//! Rust + JAX + Pallas architecture:
//!
//! * **core / matrix / solver** — the Ginkgo-shaped library: executors,
//!   `LinOp`, sparse formats, Krylov solvers, preconditioners.
//! * **kernels** — per-executor backends: `reference` (sequential
//!   oracle), `par` (multithreaded host), `xla` (AOT JAX/Pallas HLO via
//!   PJRT — the analog of the paper's new DPC++ backend).
//! * **runtime** — PJRT artifact loading, shape buckets, manifest.
//! * **autotune** — adaptive format selection: sparsity features, a
//!   roofline prior, empirical top-k measurement and a persistent
//!   tuning cache behind the drop-in [`AutoMatrix`] operator.
//! * **resilience** — breakdown detection in every Krylov driver,
//!   checkpoint/restart recovery with true-residual verification
//!   ([`ResilientSolver`]), backend degradation (retry + circuit
//!   breaker, xla → par fallback) and a seedable fault-injection
//!   harness.
//! * **observe** — Ginkgo-style Logger/Event telemetry: zero-cost-
//!   when-disabled kernel timers, solver/resilience/autotune events,
//!   JSON-lines and in-memory sinks, and a [`Profile`](observe::Profile)
//!   report with per-kernel roofline efficiency.
//! * **perfmodel** — calibrated roofline models of the paper's GPUs
//!   (GEN9, GEN12, V100, RadeonVII): the testbed substitute.
//! * **matgen / io** — SuiteSparse-like synthetic matrices + MatrixMarket.
//! * **bench_util / testing** — hand-rolled bench harness and property
//!   testing (the offline vendor set has no criterion/proptest).
//!
//! See DESIGN.md for the full inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod autotune;
pub mod bench_util;
pub mod core;
pub mod io;
pub mod kernels;
pub mod matgen;
pub mod matrix;
pub mod observe;
pub mod perfmodel;
pub mod precond;
pub mod resilience;
pub mod runtime;
pub mod solver;
pub mod stop;
pub mod testing;
pub mod vendor_mkl;

pub use crate::autotune::AutoMatrix;
pub use crate::core::dim::Dim2;
pub use crate::core::error::{Result, SparkleError};
pub use crate::core::executor::Executor;
pub use crate::core::linop::LinOp;
pub use crate::core::matrix_data::MatrixData;
pub use crate::core::types::{IndexType, Precision, Value};
pub use crate::matrix::{Coo, Csr, Dense, Ell, Hybrid, SellP};
pub use crate::resilience::ResilientSolver;
pub use crate::solver::SolverBuilder;
