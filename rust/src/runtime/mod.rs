//! PJRT runtime: loads AOT-compiled HLO artifacts (produced by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//!
//! HLO *text* is the interchange format: jax >= 0.5 emits HloModuleProto
//! with 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids and round-trips cleanly.

pub mod bucket;
mod client;
pub mod exec;
pub mod manifest;

pub use client::XlaRuntime;
pub use exec::Arg;
pub use manifest::{ArtifactMeta, Manifest};
