//! Shape buckets for AOT artifacts.
//!
//! XLA executables have static shapes, so `aot.py` lowers every kernel at
//! a small set of power-of-4 sizes and the runtime pads inputs up to the
//! next bucket. Padding is arithmetic-neutral by construction (zero
//! values, index 0 columns/rows); tests in `kernels::xla` verify this.

/// Vector-length buckets lowered by `aot.py` (powers of 4 from 2^8 to 2^20).
pub const N_BUCKETS: &[usize] = &[256, 1024, 4096, 16384, 65536, 262144, 1048576];

/// ELL padded-width buckets.
pub const K_BUCKETS: &[usize] = &[8, 32, 128];

/// COO nnz buckets are multiples of the row bucket: `nnz = m * n`.
pub const NNZ_MULTIPLIERS: &[usize] = &[4, 16, 64];

/// Smallest bucket `>= need`, or `None` if `need` exceeds the largest.
pub fn fit(buckets: &[usize], need: usize) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= need)
}

/// Pad a slice with `pad` up to `len`.
pub fn pad_to<T: Copy>(data: &[T], len: usize, pad: T) -> Vec<T> {
    debug_assert!(data.len() <= len);
    let mut v = Vec::with_capacity(len);
    v.extend_from_slice(data);
    v.resize(len, pad);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_selects_next_bucket() {
        assert_eq!(fit(N_BUCKETS, 1), Some(256));
        assert_eq!(fit(N_BUCKETS, 256), Some(256));
        assert_eq!(fit(N_BUCKETS, 257), Some(1024));
        assert_eq!(fit(N_BUCKETS, 1 << 20), Some(1 << 20));
        assert_eq!(fit(N_BUCKETS, (1 << 20) + 1), None);
    }

    #[test]
    fn buckets_sorted_ascending() {
        assert!(N_BUCKETS.windows(2).all(|w| w[0] < w[1]));
        assert!(K_BUCKETS.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn pad_to_extends_with_value() {
        assert_eq!(pad_to(&[1, 2], 4, 0), vec![1, 2, 0, 0]);
        assert_eq!(pad_to(&[1.5f64], 1, 9.0), vec![1.5]);
        let empty: &[i32] = &[];
        assert_eq!(pad_to(empty, 3, 7), vec![7, 7, 7]);
    }
}
