//! Artifact manifest: what `python/compile/aot.py` produced.
//!
//! The manifest is a TSV file (`artifacts/manifest.tsv`) with one line per
//! artifact: `name  kernel  dtype  n  k  nnz` (unused params are 0).
//! TSV instead of JSON because the offline vendor set has no serde; the
//! format is trivially stable.

use std::collections::HashMap;
use std::path::Path;

use crate::core::error::{Result, SparkleError};
use crate::core::types::Precision;

/// Metadata of one AOT-compiled artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// File stem: `artifacts/<name>.hlo.txt`.
    pub name: String,
    /// Kernel family (`axpy`, `ell`, `coo`, `cg_step`, ...).
    pub kernel: String,
    /// Value precision the artifact was lowered at.
    pub dtype: Precision,
    /// Padded vector length (rows), 0 if not applicable.
    pub n: usize,
    /// Padded ELL width, 0 if not applicable.
    pub k: usize,
    /// Padded nnz (COO), 0 if not applicable.
    pub nnz: usize,
}

/// Parsed manifest with an index by (kernel, dtype).
#[derive(Debug, Default)]
pub struct Manifest {
    by_kernel: HashMap<(String, Precision), Vec<ArtifactMeta>>,
    count: usize,
}

fn parse_dtype(s: &str) -> Result<Precision> {
    match s {
        "f64" => Ok(Precision::Double),
        "f32" => Ok(Precision::Single),
        "f16" => Ok(Precision::Half),
        other => Err(SparkleError::Parse(format!("unknown dtype `{other}`"))),
    }
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`. A missing manifest yields an empty
    /// registry (the runtime then reports artifacts as unavailable).
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        if !path.exists() {
            return Ok(Self::default());
        }
        let text = std::fs::read_to_string(&path)?;
        Self::parse(&text)
    }

    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut m = Self::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() != 6 {
                return Err(SparkleError::Parse(format!(
                    "manifest line {}: expected 6 tab-separated fields, got {}",
                    lineno + 1,
                    fields.len()
                )));
            }
            let parse_usize = |s: &str, what: &str| -> Result<usize> {
                s.parse().map_err(|_| {
                    SparkleError::Parse(format!(
                        "manifest line {}: bad {what} `{s}`",
                        lineno + 1
                    ))
                })
            };
            let meta = ArtifactMeta {
                name: fields[0].to_string(),
                kernel: fields[1].to_string(),
                dtype: parse_dtype(fields[2])?,
                n: parse_usize(fields[3], "n")?,
                k: parse_usize(fields[4], "k")?,
                nnz: parse_usize(fields[5], "nnz")?,
            };
            m.by_kernel
                .entry((meta.kernel.clone(), meta.dtype))
                .or_default()
                .push(meta);
            m.count += 1;
        }
        // sort each family by (n, k, nnz) so selection picks the smallest fit
        for v in m.by_kernel.values_mut() {
            v.sort_by_key(|a| (a.n, a.k, a.nnz));
        }
        Ok(m)
    }

    /// Total number of artifacts.
    pub fn len(&self) -> usize {
        self.count
    }

    /// True if no artifacts are registered.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// All artifacts of a kernel family at a precision, sorted ascending.
    pub fn family(&self, kernel: &str, dtype: Precision) -> &[ArtifactMeta] {
        self.by_kernel
            .get(&(kernel.to_string(), dtype))
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Smallest artifact with `n >= need_n`, `k >= need_k`, `nnz >= need_nnz`.
    pub fn select(
        &self,
        kernel: &str,
        dtype: Precision,
        need_n: usize,
        need_k: usize,
        need_nnz: usize,
    ) -> Result<&ArtifactMeta> {
        self.family(kernel, dtype)
            .iter()
            .find(|a| a.n >= need_n && a.k >= need_k && a.nnz >= need_nnz)
            .ok_or_else(|| {
                SparkleError::Runtime(format!(
                    "no `{kernel}` artifact at {dtype} covering n={need_n} k={need_k} nnz={need_nnz} \
                     (have {} candidates; run `make artifacts`?)",
                    self.family(kernel, dtype).len()
                ))
            })
    }

    /// Largest nnz bucket of a COO-style family at a given n (for chunked
    /// dispatch when nnz exceeds every bucket).
    pub fn max_nnz_at(&self, kernel: &str, dtype: Precision, need_n: usize) -> Option<&ArtifactMeta> {
        self.family(kernel, dtype)
            .iter()
            .filter(|a| a.n >= need_n)
            .max_by_key(|a| a.nnz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment line
axpy_f32_1024\taxpy\tf32\t1024\t0\t0
axpy_f32_4096\taxpy\tf32\t4096\t0\t0
ell_f64_1024_8\tell\tf64\t1024\t8\t0
ell_f64_1024_32\tell\tf64\t1024\t32\t0
coo_f32_1024_4096\tcoo\tf32\t1024\t0\t4096
coo_f32_1024_16384\tcoo\tf32\t1024\t0\t16384
";

    #[test]
    fn parse_counts_and_indexes() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 6);
        assert_eq!(m.family("axpy", Precision::Single).len(), 2);
        assert_eq!(m.family("axpy", Precision::Double).len(), 0);
        assert_eq!(m.family("nope", Precision::Single).len(), 0);
    }

    #[test]
    fn select_smallest_fit() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.select("axpy", Precision::Single, 900, 0, 0).unwrap();
        assert_eq!(a.name, "axpy_f32_1024");
        let a = m.select("axpy", Precision::Single, 1025, 0, 0).unwrap();
        assert_eq!(a.name, "axpy_f32_4096");
        assert!(m.select("axpy", Precision::Single, 5000, 0, 0).is_err());
    }

    #[test]
    fn select_multi_param() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.select("ell", Precision::Double, 1000, 9, 0).unwrap();
        assert_eq!(a.k, 32);
        let a = m.select("coo", Precision::Single, 1024, 0, 5000).unwrap();
        assert_eq!(a.nnz, 16384);
    }

    #[test]
    fn max_nnz_at_picks_largest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.max_nnz_at("coo", Precision::Single, 1024).unwrap();
        assert_eq!(a.nnz, 16384);
        assert!(m.max_nnz_at("coo", Precision::Single, 4096).is_none());
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(Manifest::parse("too\tfew\tfields").is_err());
        assert!(Manifest::parse("x\tk\tbad_dtype\t1\t0\t0").is_err());
        assert!(Manifest::parse("x\tk\tf32\tNaN\t0\t0").is_err());
    }

    #[test]
    fn empty_manifest_ok() {
        let m = Manifest::parse("").unwrap();
        assert!(m.is_empty());
    }
}
