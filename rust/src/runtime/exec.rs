//! Typed execution helpers: marshal Rust slices into XLA literals and back.

use crate::core::error::{Result, SparkleError};
use crate::core::types::Value;

/// One kernel argument.
pub enum Arg<'a, T> {
    /// Scalar value (rank-0 literal).
    Scalar(T),
    /// Value array with explicit dims.
    Values(&'a [T], Vec<i64>),
    /// Index array (i32) with explicit dims.
    Indices(&'a [i32], Vec<i64>),
}

impl<'a, T: Value> Arg<'a, T> {
    /// 1-D value array.
    pub fn vec(data: &'a [T]) -> Self {
        Arg::Values(data, vec![data.len() as i64])
    }

    /// 2-D value array (row-major).
    pub fn mat(data: &'a [T], rows: usize, cols: usize) -> Self {
        debug_assert_eq!(data.len(), rows * cols);
        Arg::Values(data, vec![rows as i64, cols as i64])
    }

    /// 1-D index array.
    pub fn idx(data: &'a [i32]) -> Self {
        Arg::Indices(data, vec![data.len() as i64])
    }

    /// 2-D index array (row-major).
    pub fn idx_mat(data: &'a [i32], rows: usize, cols: usize) -> Self {
        debug_assert_eq!(data.len(), rows * cols);
        Arg::Indices(data, vec![rows as i64, cols as i64])
    }

    pub(crate) fn to_literal(&self) -> Result<xla::Literal> {
        let reshape = |lit: xla::Literal, dims: &[i64]| -> Result<xla::Literal> {
            // vec1 gives rank-1; keep as-is when dims already match
            if dims.len() == 1 {
                Ok(lit)
            } else {
                lit.reshape(dims)
                    .map_err(|e| SparkleError::Runtime(format!("reshape arg: {e:?}")))
            }
        };
        match self {
            Arg::Scalar(v) => {
                let lit = T::literal_vec(&[*v]);
                lit.reshape(&[])
                    .map_err(|e| SparkleError::Runtime(format!("scalar reshape: {e:?}")))
            }
            Arg::Values(data, dims) => reshape(T::literal_vec(data), dims),
            Arg::Indices(data, dims) => reshape(xla::Literal::vec1(data), dims),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arg_constructors_shape() {
        let v = [1.0f32, 2.0, 3.0, 4.0];
        match Arg::vec(&v) {
            Arg::Values(d, dims) => {
                assert_eq!(d.len(), 4);
                assert_eq!(dims, vec![4]);
            }
            _ => panic!(),
        }
        match Arg::mat(&v, 2, 2) {
            Arg::Values(_, dims) => assert_eq!(dims, vec![2, 2]),
            _ => panic!(),
        }
        let i = [1i32, 2];
        match Arg::<f32>::idx(&i) {
            Arg::Indices(_, dims) => assert_eq!(dims, vec![2]),
            _ => panic!(),
        }
    }

    #[test]
    fn literals_build() {
        let v = [1.0f64, 2.0];
        assert!(Arg::vec(&v).to_literal().is_ok());
        assert!(Arg::Scalar(3.5f64).to_literal().is_ok());
        let i = [0i32, 1, 2, 3];
        assert!(Arg::<f64>::idx_mat(&i, 2, 2).to_literal().is_ok());
    }
}
