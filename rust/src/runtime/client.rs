//! PJRT client wrapper with a compile cache and manifest-driven artifact
//! selection. This is the load-and-execute half of the AOT bridge
//! (`python/compile/aot.py` is the author half).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::core::error::{Result, SparkleError};
use crate::core::types::{Precision, Value};
use crate::resilience::{CircuitBreaker, RetryPolicy};
use crate::runtime::exec::Arg;
use crate::runtime::manifest::{ArtifactMeta, Manifest};

/// Consecutive dispatch failures before the runtime degrades to the
/// host fallback path.
const BREAKER_THRESHOLD: u32 = 3;

/// Owns the PJRT CPU client, the artifact manifest, and a cache of
/// compiled executables keyed by artifact name. Compilation is lazy.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative number of kernel launches (for perf accounting).
    launches: std::sync::atomic::AtomicU64,
    /// Retry-with-backoff for the execute phase of a dispatch. Only
    /// execution is retried: manifest lookups, HLO loads and compiles
    /// are deterministic, so their failures are permanent.
    retry: RetryPolicy,
    /// Opens after repeated execute failures; kernels then route to
    /// the host `par` implementations ([`XlaRuntime::degraded`]).
    breaker: CircuitBreaker,
}

impl XlaRuntime {
    /// Create a runtime reading artifacts from `artifact_dir`.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| SparkleError::Runtime(format!("PJRT cpu client: {e:?}")))?;
        let artifact_dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&artifact_dir)?;
        Ok(Self {
            client,
            artifact_dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
            launches: std::sync::atomic::AtomicU64::new(0),
            retry: RetryPolicy::default(),
            breaker: CircuitBreaker::new(BREAKER_THRESHOLD),
        })
    }

    /// Override the execute-phase retry policy.
    pub fn with_retry_policy(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Whether the dispatch circuit breaker has opened — kernels should
    /// route to the host fallback instead of this runtime.
    pub fn degraded(&self) -> bool {
        self.breaker.is_open()
    }

    /// The dispatch circuit breaker (inspection, tests, operator
    /// override via `trip`/`reset`).
    pub fn breaker(&self) -> &CircuitBreaker {
        &self.breaker
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// The artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Smallest artifact of `kernel` at `dtype` covering the given sizes.
    pub fn select(
        &self,
        kernel: &str,
        dtype: Precision,
        need_n: usize,
        need_k: usize,
        need_nnz: usize,
    ) -> Result<&ArtifactMeta> {
        self.manifest.select(kernel, dtype, need_n, need_k, need_nnz)
    }

    /// Number of kernel launches so far.
    pub fn launch_count(&self) -> u64 {
        self.launches.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Move host data into a device-resident PJRT buffer. Matrix operands
    /// cached this way skip per-call literal marshalling entirely
    /// (EXPERIMENTS.md §Perf, L3 iteration 4).
    pub fn to_device<E: xla::ArrayElement>(
        &self,
        data: &[E],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| SparkleError::Runtime(format!("to_device: {e:?}")))
    }

    /// Execute an artifact on device-resident buffers (`execute_b`),
    /// returning all outputs at precision `T`.
    pub fn run_buffers<T: Value>(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Vec<T>>> {
        let exe = self.executable(name)?;
        self.launches
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let t0 = std::time::Instant::now();
        let bufs = match self.retry.run_observed("execute_b", || {
            exe.execute_b::<&xla::PjRtBuffer>(args)
                .map_err(|e| SparkleError::Runtime(format!("execute_b {name}: {e:?}")))
        }) {
            Ok(b) => {
                self.breaker.record_success();
                crate::observe::emit(|| crate::observe::Event::Launch {
                    artifact: name.to_string(),
                    seconds: t0.elapsed().as_secs_f64(),
                    ok: true,
                });
                b
            }
            Err(e) => {
                self.breaker.record_failure();
                crate::observe::emit(|| crate::observe::Event::Launch {
                    artifact: name.to_string(),
                    seconds: t0.elapsed().as_secs_f64(),
                    ok: false,
                });
                if self.breaker.is_open() {
                    crate::observe::emit(|| crate::observe::Event::BreakerOpen {
                        failures: self.breaker.failures_total(),
                    });
                }
                return Err(e);
            }
        };
        let mut result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| SparkleError::Runtime(format!("fetch result: {e:?}")))?;
        let parts = result
            .decompose_tuple()
            .map_err(|e| SparkleError::Runtime(format!("decompose tuple: {e:?}")))?;
        parts
            .iter()
            .map(|l| {
                T::literal_to_vec(l)
                    .map_err(|e| SparkleError::Runtime(format!("read output: {e:?}")))
            })
            .collect()
    }

    /// Load + compile `<artifact_dir>/<name>.hlo.txt` (cached).
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let path_str = path
            .to_str()
            .ok_or_else(|| SparkleError::Runtime("artifact path not utf-8".into()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| SparkleError::Runtime(format!("load HLO text {path:?}: {e:?}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| SparkleError::Runtime(format!("compile {name}: {e:?}")))?;
        let exe = Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact. All value inputs/outputs share precision `T`;
    /// index inputs are i32. Artifacts are lowered with
    /// `return_tuple=True`, so the single result is a tuple we decompose.
    pub fn run<T: Value>(&self, name: &str, args: &[Arg<'_, T>]) -> Result<Vec<Vec<T>>> {
        let exe = self.executable(name)?;
        let literals = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<Vec<_>>>()?;
        self.launches
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let t0 = std::time::Instant::now();
        let bufs = match self.retry.run_observed("execute", || {
            exe.execute::<xla::Literal>(&literals)
                .map_err(|e| SparkleError::Runtime(format!("execute {name}: {e:?}")))
        }) {
            Ok(b) => {
                self.breaker.record_success();
                crate::observe::emit(|| crate::observe::Event::Launch {
                    artifact: name.to_string(),
                    seconds: t0.elapsed().as_secs_f64(),
                    ok: true,
                });
                b
            }
            Err(e) => {
                self.breaker.record_failure();
                crate::observe::emit(|| crate::observe::Event::Launch {
                    artifact: name.to_string(),
                    seconds: t0.elapsed().as_secs_f64(),
                    ok: false,
                });
                if self.breaker.is_open() {
                    crate::observe::emit(|| crate::observe::Event::BreakerOpen {
                        failures: self.breaker.failures_total(),
                    });
                }
                return Err(e);
            }
        };
        let mut result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| SparkleError::Runtime(format!("fetch result: {e:?}")))?;
        let parts = result
            .decompose_tuple()
            .map_err(|e| SparkleError::Runtime(format!("decompose tuple: {e:?}")))?;
        parts
            .iter()
            .map(|l| {
                T::literal_to_vec(l)
                    .map_err(|e| SparkleError::Runtime(format!("read output: {e:?}")))
            })
            .collect()
    }
}

impl std::fmt::Debug for XlaRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "XlaRuntime(dir={:?}, artifacts={})",
            self.artifact_dir,
            self.manifest.len()
        )
    }
}
