//! File I/O: MatrixMarket exchange format.

mod matrix_market;

pub use matrix_market::{read_matrix_market, read_matrix_market_str, write_matrix_market};
