//! Format conversions.
//!
//! Ginkgo exposes `convert_to` between every pair of formats; here the
//! generic path round-trips through `MatrixData` (always correct), with
//! direct fast paths for the pairs that matter on the hot path
//! (CSR ↔ COO, CSR → ELL).

use std::sync::Arc;

use crate::core::error::Result;
use crate::core::executor::Executor;
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::kernels::reference::row_ptrs_to_idxs;
use crate::matrix::coo::Coo;
use crate::matrix::csr::Csr;
use crate::matrix::ell::Ell;
use crate::matrix::hybrid::Hybrid;
use crate::matrix::sellp::SellP;

/// CSR → COO without going through `MatrixData` (hot path: the XLA
/// executor's CSR SpMV uses the same expansion).
pub fn csr_to_coo<T: Value>(a: &Csr<T>) -> Result<Coo<T>> {
    let rows = row_ptrs_to_idxs(a.row_ptrs(), a.nnz());
    Coo::from_raw(
        a.executor().clone(),
        a.shape(),
        rows,
        a.col_idxs().to_vec(),
        a.values().to_vec(),
    )
}

/// COO → CSR without going through `MatrixData`.
pub fn coo_to_csr<T: Value>(a: &Coo<T>) -> Result<Csr<T>> {
    let n = a.shape().rows;
    let mut row_ptrs: Vec<i32> = vec![0; n + 1];
    for &r in a.row_idxs() {
        row_ptrs[r as usize + 1] += 1;
    }
    for i in 0..n {
        row_ptrs[i + 1] += row_ptrs[i];
    }
    Csr::from_raw(
        a.executor().clone(),
        a.shape(),
        row_ptrs,
        a.col_idxs().to_vec(),
        a.values().to_vec(),
    )
}

/// CSR → ELL padded to the longest row.
pub fn csr_to_ell<T: Value>(a: &Csr<T>) -> Result<Ell<T>> {
    Ell::from_data(a.executor().clone(), &a.to_data())
}

/// CSR → SELL-P with the default slice size.
pub fn csr_to_sellp<T: Value>(a: &Csr<T>) -> Result<SellP<T>> {
    SellP::from_data(a.executor().clone(), &a.to_data())
}

/// CSR → Hybrid with the default strategy.
pub fn csr_to_hybrid<T: Value>(a: &Csr<T>) -> Result<Hybrid<T>> {
    Hybrid::from_data(a.executor().clone(), &a.to_data())
}

/// Any format → any format via `MatrixData` (convenience for tests and
/// the CLI's `convert` command).
pub fn convert<T: Value, S, D>(src: &S, exec: Arc<Executor>) -> Result<D>
where
    S: ToData<T>,
    D: FromData<T>,
{
    D::from_data_on(exec, &src.to_data_generic())
}

/// Formats that can export assembly data.
pub trait ToData<T: Value> {
    fn to_data_generic(&self) -> crate::core::matrix_data::MatrixData<T>;
}

/// Formats that can be built from assembly data.
pub trait FromData<T: Value>: Sized {
    fn from_data_on(
        exec: Arc<Executor>,
        data: &crate::core::matrix_data::MatrixData<T>,
    ) -> Result<Self>;
}

macro_rules! impl_data_traits {
    ($ty:ident) => {
        impl<T: Value> ToData<T> for $ty<T> {
            fn to_data_generic(&self) -> crate::core::matrix_data::MatrixData<T> {
                self.to_data()
            }
        }
        impl<T: Value> FromData<T> for $ty<T> {
            fn from_data_on(
                exec: Arc<Executor>,
                data: &crate::core::matrix_data::MatrixData<T>,
            ) -> Result<Self> {
                $ty::from_data(exec, data)
            }
        }
    };
}

impl_data_traits!(Coo);
impl_data_traits!(Csr);
impl_data_traits!(Ell);
impl_data_traits!(SellP);
impl_data_traits!(Hybrid);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prng::Prng;
    use crate::testing::prop::gen_sparse;

    #[test]
    fn csr_coo_round_trip() {
        let mut rng = Prng::new(31);
        let data = gen_sparse::<f64>(&mut rng, 60, 60, 4);
        let csr = Csr::from_data(Executor::reference(), &data).unwrap();
        let coo = csr_to_coo(&csr).unwrap();
        let back = coo_to_csr(&coo).unwrap();
        assert_eq!(back.row_ptrs(), csr.row_ptrs());
        assert_eq!(back.col_idxs(), csr.col_idxs());
        assert_eq!(back.values(), csr.values());
    }

    #[test]
    fn every_pair_preserves_dense_image() {
        let mut rng = Prng::new(77);
        let data = gen_sparse::<f64>(&mut rng, 30, 30, 3);
        let expect = data.to_dense_vec();
        let exec = Executor::reference();
        let csr = Csr::from_data(exec.clone(), &data).unwrap();

        let coo: Coo<f64> = convert(&csr, exec.clone()).unwrap();
        assert_eq!(coo.to_data().to_dense_vec(), expect);
        let ell: Ell<f64> = convert(&coo, exec.clone()).unwrap();
        assert_eq!(ell.to_data().to_dense_vec(), expect);
        let sellp: SellP<f64> = convert(&ell, exec.clone()).unwrap();
        assert_eq!(sellp.to_data().to_dense_vec(), expect);
        let hybrid: Hybrid<f64> = convert(&sellp, exec.clone()).unwrap();
        assert_eq!(hybrid.to_data().to_dense_vec(), expect);
        let back: Csr<f64> = convert(&hybrid, exec).unwrap();
        assert_eq!(back.to_data().to_dense_vec(), expect);
    }

    #[test]
    fn direct_fast_paths_match_generic() {
        let mut rng = Prng::new(5);
        let data = gen_sparse::<f32>(&mut rng, 45, 45, 6);
        let exec = Executor::reference();
        let csr = Csr::from_data(exec.clone(), &data).unwrap();
        let ell = csr_to_ell(&csr).unwrap();
        let ell2: Ell<f32> = convert(&csr, exec).unwrap();
        assert_eq!(ell.values(), ell2.values());
        assert_eq!(ell.col_idxs(), ell2.col_idxs());
    }
}
