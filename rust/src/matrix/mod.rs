//! Matrix formats: dense plus the sparse formats of the paper's study
//! (COO, CSR) and Ginkgo's wider format zoo (ELL, SELL-P, Hybrid) used by
//! the format-ablation benches.

pub mod conversion;
pub mod coo;
pub mod csr;
pub mod dense;
pub mod ell;
pub mod hybrid;
pub mod sellp;

pub use coo::Coo;
pub use csr::Csr;
pub use dense::Dense;
pub use ell::Ell;
pub use hybrid::Hybrid;
pub use sellp::SellP;
