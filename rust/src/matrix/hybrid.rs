//! Hybrid (ELL + COO) format.
//!
//! Stores the regular part of each row (up to a width chosen from the
//! row-length distribution) in ELL and spills the remainder into COO.
//! This keeps power-law matrices (circuit5M, FullChip — §6's hardest
//! cases) SIMD-friendly without ELL's padding explosion.

use std::sync::Arc;

use crate::core::dim::Dim2;
use crate::core::error::Result;
use crate::core::executor::Executor;
use crate::core::linop::LinOp;
use crate::core::matrix_data::MatrixData;
use crate::core::types::Value;
use crate::matrix::coo::Coo;
use crate::matrix::dense::Dense;
use crate::matrix::ell::Ell;

/// Strategy for choosing the ELL width.
#[derive(Debug, Clone, Copy)]
pub enum HybridStrategy {
    /// Fixed ELL width.
    Fixed(usize),
    /// Width = the `q`-quantile of row lengths (Ginkgo's `imbalance_limit`
    /// approach; default q = 0.8).
    Percentile(f64),
}

impl Default for HybridStrategy {
    fn default() -> Self {
        HybridStrategy::Percentile(0.8)
    }
}

/// Hybrid sparse matrix: `A = ell_part + coo_part`.
#[derive(Clone)]
pub struct Hybrid<T> {
    exec: Arc<Executor>,
    dim: Dim2,
    pub(crate) ell: Ell<T>,
    pub(crate) coo: Coo<T>,
}

impl<T: Value> Hybrid<T> {
    /// Build with the default percentile strategy.
    pub fn from_data(exec: Arc<Executor>, data: &MatrixData<T>) -> Result<Self> {
        Self::from_data_with_strategy(exec, data, HybridStrategy::default())
    }

    /// Build with an explicit strategy.
    pub fn from_data_with_strategy(
        exec: Arc<Executor>,
        data: &MatrixData<T>,
        strategy: HybridStrategy,
    ) -> Result<Self> {
        data.validate()?;
        let owned;
        let src = if data.is_normalized() {
            data
        } else {
            let mut d = data.clone();
            d.normalize();
            owned = d;
            &owned
        };
        let width = match strategy {
            HybridStrategy::Fixed(w) => w,
            HybridStrategy::Percentile(q) => {
                let mut lens = src.row_lengths();
                lens.sort_unstable();
                if lens.is_empty() {
                    0
                } else {
                    let idx = ((lens.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as usize;
                    lens[idx]
                }
            }
        };
        let mut ell_data = MatrixData::new(src.dim);
        let mut coo_data = MatrixData::new(src.dim);
        let mut fill = vec![0usize; src.dim.rows];
        for e in &src.entries {
            let i = e.row as usize;
            if fill[i] < width {
                ell_data.push(e.row, e.col, e.val);
                fill[i] += 1;
            } else {
                coo_data.push(e.row, e.col, e.val);
            }
        }
        Ok(Self {
            exec: exec.clone(),
            dim: src.dim,
            ell: Ell::from_data_with_width(exec.clone(), &ell_data, width)?,
            coo: Coo::from_data(exec, &coo_data)?,
        })
    }

    /// ELL partition.
    pub fn ell_part(&self) -> &Ell<T> {
        &self.ell
    }

    /// COO partition.
    pub fn coo_part(&self) -> &Coo<T> {
        &self.coo
    }

    /// Actual nonzeros.
    pub fn nnz(&self) -> usize {
        self.ell.nnz() + self.coo.nnz()
    }

    /// Back to assembly form.
    pub fn to_data(&self) -> MatrixData<T> {
        let mut d = self.ell.to_data();
        d.entries.extend(self.coo.to_data().entries);
        d.normalize();
        d
    }

    /// Rebind executor.
    pub fn to_executor(&self, exec: Arc<Executor>) -> Self {
        Self {
            exec: exec.clone(),
            dim: self.dim,
            ell: self.ell.to_executor(exec.clone()),
            coo: self.coo.to_executor(exec),
        }
    }
}

impl<T: Value> LinOp<T> for Hybrid<T> {
    fn shape(&self) -> Dim2 {
        self.dim
    }

    fn executor(&self) -> &Arc<Executor> {
        &self.exec
    }

    fn apply(&self, b: &Dense<T>, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        crate::kernels::spmv::hybrid_apply(&self.exec, self, b, x)
    }

    fn apply_advanced(&self, alpha: T, b: &Dense<T>, beta: T, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        crate::kernels::spmv::hybrid_apply_advanced(&self.exec, alpha, self, beta, b, x)
    }

    fn op_name(&self) -> &'static str {
        "hybrid"
    }
}

impl<T: Value> std::fmt::Debug for Hybrid<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Hybrid<{}>({}, ell_width={}, coo_nnz={})",
            T::PRECISION,
            self.dim,
            self.ell.stored_per_row(),
            self.coo.nnz()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::types::IndexType;

    fn skewed_data() -> MatrixData<f64> {
        // row 0 has 8 entries, rows 1..7 have 1
        let n = 8;
        let mut d = MatrixData::new(Dim2::square(n));
        for j in 0..n {
            d.push(0, j as IndexType, (j + 1) as f64);
        }
        for i in 1..n {
            d.push(i as IndexType, i as IndexType, 2.0);
        }
        d.normalize();
        d
    }

    #[test]
    fn percentile_strategy_splits() {
        let m = Hybrid::from_data(Executor::reference(), &skewed_data()).unwrap();
        // 80th percentile of row lengths [8,1,1,1,1,1,1,1] sorted -> 1
        assert_eq!(m.ell_part().stored_per_row(), 1);
        assert_eq!(m.coo_part().nnz(), 7); // row 0 spill
        assert_eq!(m.nnz(), 15);
    }

    #[test]
    fn fixed_strategy() {
        let m = Hybrid::from_data_with_strategy(
            Executor::reference(),
            &skewed_data(),
            HybridStrategy::Fixed(4),
        )
        .unwrap();
        assert_eq!(m.ell_part().stored_per_row(), 4);
        assert_eq!(m.coo_part().nnz(), 4);
    }

    #[test]
    fn apply_matches_dense() {
        let d = skewed_data();
        let m = Hybrid::from_data(Executor::reference(), &d).unwrap();
        let b_vals: Vec<f64> = (0..8).map(|i| (i as f64) - 3.0).collect();
        let b = Dense::vector(Executor::reference(), &b_vals);
        let mut x = Dense::zeros(Executor::reference(), Dim2::new(8, 1));
        m.apply(&b, &mut x).unwrap();
        // dense check
        let dense = d.to_dense_vec();
        for i in 0..8 {
            let expect: f64 = (0..8).map(|j| dense[i * 8 + j] * b_vals[j]).sum();
            assert!((x.as_slice()[i] - expect).abs() < 1e-12, "row {i}");
        }
    }

    #[test]
    fn round_trip_via_data() {
        let d = skewed_data();
        let m = Hybrid::from_data(Executor::reference(), &d).unwrap();
        assert_eq!(m.to_data().to_dense_vec(), d.to_dense_vec());
    }
}
