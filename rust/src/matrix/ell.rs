//! ELLPACK (ELL) format.
//!
//! Pads every row to the longest row length `k` and stores values/columns
//! in column-major order (`values[j*n + i]` = j-th stored entry of row i),
//! which gives coalesced access on SIMD architectures. Padding entries
//! hold `col = 0, val = 0` — a *valid* index with a neutral value, so the
//! same arrays can be fed directly to the gather-based XLA/Pallas kernel
//! (TPU adaptation: no `-1` sentinel branch, padding is arithmetic-neutral).
//!
//! ELL is the storage the AOT SpMV kernel artifacts operate on; the `Xla`
//! executor converts CSR/COO to ELL slices on first apply (cached).

use std::sync::Arc;

use crate::core::dim::Dim2;
use crate::core::error::{Result, SparkleError};
use crate::core::executor::Executor;
use crate::core::linop::LinOp;
use crate::core::matrix_data::MatrixData;
use crate::core::types::{IndexType, Value};
use crate::matrix::dense::Dense;

/// ELL sparse matrix (column-major padded storage).
#[derive(Clone)]
pub struct Ell<T> {
    exec: Arc<Executor>,
    dim: Dim2,
    /// Stored entries per row (padded row length).
    pub(crate) stored_per_row: usize,
    /// Column-major: `col_idxs[j * dim.rows + i]`.
    pub(crate) col_idxs: Vec<IndexType>,
    /// Column-major: `values[j * dim.rows + i]`.
    pub(crate) values: Vec<T>,
    /// Bucket-padded, *device-resident* copies of values/cols for the
    /// XLA backend, built once on first apply (EXPERIMENTS.md §Perf, L3
    /// iterations 3-4: re-padding and literal marshalling dominated the
    /// per-apply cost). `Arc` keeps the struct Clone (clones share the
    /// immutable device buffers).
    pub(crate) padded_cache: once_cell::unsync::OnceCell<
        std::sync::Arc<(usize, usize, xla::PjRtBuffer, xla::PjRtBuffer)>,
    >,
}

impl<T: Value> Ell<T> {
    /// Build from assembly data, padding to the longest row.
    pub fn from_data(exec: Arc<Executor>, data: &MatrixData<T>) -> Result<Self> {
        let k = data.max_row_length();
        Self::from_data_with_width(exec, data, k)
    }

    /// Build with an explicit padded width `k`; fails if a row exceeds it.
    pub fn from_data_with_width(
        exec: Arc<Executor>,
        data: &MatrixData<T>,
        stored_per_row: usize,
    ) -> Result<Self> {
        data.validate()?;
        let owned;
        let src = if data.is_normalized() {
            data
        } else {
            let mut d = data.clone();
            d.normalize();
            owned = d;
            &owned
        };
        let n = src.dim.rows;
        let mut col_idxs = vec![0 as IndexType; n * stored_per_row];
        let mut values = vec![T::zero(); n * stored_per_row];
        let mut fill = vec![0usize; n];
        for e in &src.entries {
            let i = e.row as usize;
            let j = fill[i];
            if j >= stored_per_row {
                return Err(SparkleError::InvalidStructure(format!(
                    "row {i} exceeds ELL width {stored_per_row}"
                )));
            }
            col_idxs[j * n + i] = e.col;
            values[j * n + i] = e.val;
            fill[i] += 1;
        }
        Ok(Self {
            exec,
            dim: src.dim,
            stored_per_row,
            col_idxs,
            values,
            padded_cache: once_cell::unsync::OnceCell::new(),
        })
    }

    /// Padded row width.
    pub fn stored_per_row(&self) -> usize {
        self.stored_per_row
    }

    /// Stored entry count including padding.
    pub fn stored_total(&self) -> usize {
        self.values.len()
    }

    /// Actual nonzeros (non-padding entries).
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|v| !v.is_zero()).count()
    }

    /// Column-major column index array.
    pub fn col_idxs(&self) -> &[IndexType] {
        &self.col_idxs
    }

    /// Column-major value array.
    pub fn values(&self) -> &[T] {
        &self.values
    }

    /// Back to assembly form (drops padding).
    pub fn to_data(&self) -> MatrixData<T> {
        let n = self.dim.rows;
        let mut d = MatrixData::new(self.dim);
        for i in 0..n {
            for j in 0..self.stored_per_row {
                let v = self.values[j * n + i];
                if !v.is_zero() {
                    d.push(i as IndexType, self.col_idxs[j * n + i], v);
                }
            }
        }
        d.normalize();
        d
    }

    /// Rebind executor.
    pub fn to_executor(&self, exec: Arc<Executor>) -> Self {
        let mut c = self.clone();
        c.exec = exec;
        c
    }
}

impl<T: Value> LinOp<T> for Ell<T> {
    fn shape(&self) -> Dim2 {
        self.dim
    }

    fn executor(&self) -> &Arc<Executor> {
        &self.exec
    }

    fn apply(&self, b: &Dense<T>, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        crate::kernels::spmv::ell_apply(&self.exec, self, b, x)
    }

    fn apply_advanced(&self, alpha: T, b: &Dense<T>, beta: T, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        crate::kernels::spmv::ell_apply_advanced(&self.exec, alpha, self, beta, b, x)
    }

    fn apply_dot(&self, b: &Dense<T>, x: &mut Dense<T>, w: &Dense<T>) -> Result<(T, T)> {
        self.check_conformant(b, x)?;
        crate::kernels::spmv::ell_apply_dot(&self.exec, self, b, x, w)
    }

    fn op_name(&self) -> &'static str {
        "ell"
    }
}

impl<T: Value> std::fmt::Debug for Ell<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Ell<{}>({}, k={})",
            T::PRECISION,
            self.dim,
            self.stored_per_row
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_data() -> MatrixData<f64> {
        MatrixData::from_triplets(
            Dim2::square(3),
            &[0, 0, 1, 2, 2],
            &[0, 1, 1, 0, 2],
            &[2.0, 1.0, 3.0, 4.0, 5.0],
        )
        .unwrap()
    }

    #[test]
    fn from_data_pads_to_max_row() {
        let m = Ell::from_data(Executor::reference(), &sample_data()).unwrap();
        assert_eq!(m.stored_per_row(), 2);
        assert_eq!(m.stored_total(), 6);
        assert_eq!(m.nnz(), 5);
        // column-major: first stored entry of each row
        assert_eq!(&m.col_idxs()[0..3], &[0, 1, 0]);
        assert_eq!(&m.values()[0..3], &[2.0, 3.0, 4.0]);
        // second stored entry; row 1 padded with col 0 / val 0
        assert_eq!(&m.col_idxs()[3..6], &[1, 0, 2]);
        assert_eq!(&m.values()[3..6], &[1.0, 0.0, 5.0]);
    }

    #[test]
    fn explicit_width_too_small_fails() {
        let r = Ell::from_data_with_width(Executor::reference(), &sample_data(), 1);
        assert!(r.is_err());
    }

    #[test]
    fn round_trip_via_data() {
        let m = Ell::from_data(Executor::reference(), &sample_data()).unwrap();
        assert_eq!(m.to_data().to_dense_vec(), sample_data().to_dense_vec());
    }

    #[test]
    fn apply_reference() {
        let m = Ell::from_data(Executor::reference(), &sample_data()).unwrap();
        let b = Dense::vector(Executor::reference(), &[1.0, 2.0, 3.0]);
        let mut x = Dense::zeros(Executor::reference(), Dim2::new(3, 1));
        m.apply(&b, &mut x).unwrap();
        assert_eq!(x.as_slice(), &[4.0, 6.0, 19.0]);
    }

    #[test]
    fn wider_than_needed_is_fine() {
        let m =
            Ell::from_data_with_width(Executor::reference(), &sample_data(), 4).unwrap();
        let b = Dense::vector(Executor::reference(), &[1.0, 2.0, 3.0]);
        let mut x = Dense::zeros(Executor::reference(), Dim2::new(3, 1));
        m.apply(&b, &mut x).unwrap();
        assert_eq!(x.as_slice(), &[4.0, 6.0, 19.0]);
    }
}
