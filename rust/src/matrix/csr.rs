//! Compressed Sparse Row (CSR) format.
//!
//! Replaces COO's explicit row indices with `n+1` row pointers. Footprint
//! per nonzero: 1 value + 1 index (12 B double / 8 B single, §5) plus the
//! row-pointer array. This is the format oneMKL's vendor kernel operates
//! on and one of the two formats in the paper's SpMV study.

use std::sync::Arc;

use crate::core::dim::Dim2;
use crate::core::error::{Result, SparkleError};
use crate::core::executor::Executor;
use crate::core::linop::LinOp;
use crate::core::matrix_data::MatrixData;
use crate::core::types::{IndexType, Value};
use crate::matrix::dense::Dense;

/// CSR sparse matrix.
#[derive(Clone)]
pub struct Csr<T> {
    exec: Arc<Executor>,
    dim: Dim2,
    pub(crate) row_ptrs: Vec<IndexType>,
    pub(crate) col_idxs: Vec<IndexType>,
    pub(crate) values: Vec<T>,
    /// Lazily cached explicit row indices (COO expansion) — the XLA
    /// backend's CSR SpMV dispatches to the segment-sum artifact and
    /// would otherwise recompute this O(nnz) array every apply
    /// (EXPERIMENTS.md §Perf, L3 iteration 2).
    pub(crate) expanded_rows: once_cell::unsync::OnceCell<Vec<IndexType>>,
}

impl<T: Value> Csr<T> {
    /// Build from assembly data.
    pub fn from_data(exec: Arc<Executor>, data: &MatrixData<T>) -> Result<Self> {
        data.validate()?;
        let owned;
        let src = if data.is_normalized() {
            data
        } else {
            let mut d = data.clone();
            d.normalize();
            owned = d;
            &owned
        };
        let nnz = src.nnz();
        let mut row_ptrs = vec![0 as IndexType; src.dim.rows + 1];
        for e in &src.entries {
            row_ptrs[e.row as usize + 1] += 1;
        }
        for i in 0..src.dim.rows {
            row_ptrs[i + 1] += row_ptrs[i];
        }
        let mut col_idxs = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        for e in &src.entries {
            col_idxs.push(e.col);
            values.push(e.val);
        }
        Ok(Self {
            exec,
            dim: src.dim,
            row_ptrs,
            col_idxs,
            values,
            expanded_rows: once_cell::unsync::OnceCell::new(),
        })
    }

    /// Build from raw CSR arrays (validated).
    pub fn from_raw(
        exec: Arc<Executor>,
        dim: Dim2,
        row_ptrs: Vec<IndexType>,
        col_idxs: Vec<IndexType>,
        values: Vec<T>,
    ) -> Result<Self> {
        if row_ptrs.len() != dim.rows + 1 {
            return Err(SparkleError::InvalidStructure(format!(
                "csr row_ptrs has {} entries for {} rows",
                row_ptrs.len(),
                dim.rows
            )));
        }
        if col_idxs.len() != values.len() {
            return Err(SparkleError::InvalidStructure(
                "csr col/val arrays disagree".into(),
            ));
        }
        if row_ptrs[0] != 0
            || *row_ptrs.last().unwrap() as usize != values.len()
            || row_ptrs.windows(2).any(|w| w[0] > w[1])
        {
            return Err(SparkleError::InvalidStructure(
                "csr row_ptrs not monotone from 0 to nnz".into(),
            ));
        }
        if col_idxs
            .iter()
            .any(|&c| c < 0 || c as usize >= dim.cols)
        {
            return Err(SparkleError::InvalidStructure(
                "csr column index out of bounds".into(),
            ));
        }
        Ok(Self {
            exec,
            dim,
            row_ptrs,
            col_idxs,
            values,
            expanded_rows: once_cell::unsync::OnceCell::new(),
        })
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Row pointer array (`rows + 1` entries).
    pub fn row_ptrs(&self) -> &[IndexType] {
        &self.row_ptrs
    }

    /// Column index array.
    pub fn col_idxs(&self) -> &[IndexType] {
        &self.col_idxs
    }

    /// Explicit row indices (COO expansion), computed once and cached.
    pub fn expanded_rows(&self) -> &[IndexType] {
        self.expanded_rows.get_or_init(|| {
            crate::kernels::reference::row_ptrs_to_idxs(&self.row_ptrs, self.values.len())
        })
    }

    /// Value array.
    pub fn values(&self) -> &[T] {
        &self.values
    }

    /// Mutable values (used by Jacobi scaling tests and generators).
    pub fn values_mut(&mut self) -> &mut [T] {
        &mut self.values
    }

    /// Length of row `i`.
    pub fn row_len(&self, i: usize) -> usize {
        (self.row_ptrs[i + 1] - self.row_ptrs[i]) as usize
    }

    /// Extract the diagonal (missing entries are zero).
    pub fn extract_diagonal(&self) -> Vec<T> {
        let n = self.dim.rows.min(self.dim.cols);
        let mut diag = vec![T::zero(); n];
        for i in 0..self.dim.rows.min(n) {
            for k in self.row_ptrs[i] as usize..self.row_ptrs[i + 1] as usize {
                if self.col_idxs[k] as usize == i {
                    diag[i] = self.values[k];
                }
            }
        }
        diag
    }

    /// Transposed copy (direct CSC-style pass, no MatrixData detour).
    pub fn transpose(&self) -> Result<Csr<T>> {
        let (rows, cols) = (self.dim.rows, self.dim.cols);
        let nnz = self.nnz();
        // count entries per column -> transposed row pointers
        let mut t_ptrs = vec![0 as IndexType; cols + 1];
        for &c in &self.col_idxs {
            t_ptrs[c as usize + 1] += 1;
        }
        for i in 0..cols {
            t_ptrs[i + 1] += t_ptrs[i];
        }
        let mut t_cols = vec![0 as IndexType; nnz];
        let mut t_vals = vec![T::zero(); nnz];
        let mut cursor = t_ptrs.clone();
        for i in 0..rows {
            for k in self.row_ptrs[i] as usize..self.row_ptrs[i + 1] as usize {
                let c = self.col_idxs[k] as usize;
                let pos = cursor[c] as usize;
                t_cols[pos] = i as IndexType;
                t_vals[pos] = self.values[k];
                cursor[c] += 1;
            }
        }
        Csr::from_raw(
            self.exec.clone(),
            self.dim.transposed(),
            t_ptrs,
            t_cols,
            t_vals,
        )
    }

    /// Back to assembly form.
    pub fn to_data(&self) -> MatrixData<T> {
        let mut d = MatrixData::new(self.dim);
        for i in 0..self.dim.rows {
            for k in self.row_ptrs[i] as usize..self.row_ptrs[i + 1] as usize {
                d.push(i as IndexType, self.col_idxs[k], self.values[k]);
            }
        }
        d
    }

    /// Rebind executor.
    pub fn to_executor(&self, exec: Arc<Executor>) -> Self {
        let mut c = self.clone();
        c.exec = exec;
        c
    }
}

impl<T: Value> LinOp<T> for Csr<T> {
    fn shape(&self) -> Dim2 {
        self.dim
    }

    fn executor(&self) -> &Arc<Executor> {
        &self.exec
    }

    fn apply(&self, b: &Dense<T>, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        crate::kernels::spmv::csr_apply(&self.exec, self, b, x)
    }

    fn apply_advanced(&self, alpha: T, b: &Dense<T>, beta: T, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        crate::kernels::spmv::csr_apply_advanced(&self.exec, alpha, self, beta, b, x)
    }

    fn apply_dot(&self, b: &Dense<T>, x: &mut Dense<T>, w: &Dense<T>) -> Result<(T, T)> {
        self.check_conformant(b, x)?;
        crate::kernels::spmv::csr_apply_dot(&self.exec, self, b, x, w)
    }

    fn op_name(&self) -> &'static str {
        "csr"
    }
}

impl<T: Value> std::fmt::Debug for Csr<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Csr<{}>({}, nnz={})", T::PRECISION, self.dim, self.nnz())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_data() -> MatrixData<f64> {
        MatrixData::from_triplets(
            Dim2::square(3),
            &[0, 0, 1, 2, 2],
            &[0, 1, 1, 0, 2],
            &[2.0, 1.0, 3.0, 4.0, 5.0],
        )
        .unwrap()
    }

    #[test]
    fn from_data_layout() {
        let m = Csr::from_data(Executor::reference(), &sample_data()).unwrap();
        assert_eq!(m.row_ptrs(), &[0, 2, 3, 5]);
        assert_eq!(m.col_idxs(), &[0, 1, 1, 0, 2]);
        assert_eq!(m.row_len(0), 2);
        assert_eq!(m.row_len(1), 1);
    }

    #[test]
    fn from_raw_validation() {
        let e = Executor::reference();
        // bad row_ptrs length
        assert!(Csr::<f64>::from_raw(e.clone(), Dim2::square(2), vec![0, 1], vec![0], vec![1.0])
            .is_err());
        // non-monotone
        assert!(Csr::<f64>::from_raw(
            e.clone(),
            Dim2::square(2),
            vec![0, 2, 1],
            vec![0],
            vec![1.0]
        )
        .is_err());
        // column out of bounds
        assert!(Csr::<f64>::from_raw(
            e.clone(),
            Dim2::square(2),
            vec![0, 1, 1],
            vec![5],
            vec![1.0]
        )
        .is_err());
        // good
        assert!(Csr::<f64>::from_raw(e, Dim2::square(2), vec![0, 1, 1], vec![1], vec![1.0])
            .is_ok());
    }

    #[test]
    fn diagonal_extraction() {
        let m = Csr::from_data(Executor::reference(), &sample_data()).unwrap();
        assert_eq!(m.extract_diagonal(), vec![2.0, 3.0, 5.0]);
    }

    #[test]
    fn round_trip_via_data() {
        let m = Csr::from_data(Executor::reference(), &sample_data()).unwrap();
        assert_eq!(m.to_data().to_dense_vec(), sample_data().to_dense_vec());
    }

    #[test]
    fn apply_reference() {
        let m = Csr::from_data(Executor::reference(), &sample_data()).unwrap();
        let b = Dense::vector(Executor::reference(), &[1.0, 2.0, 3.0]);
        let mut x = Dense::zeros(Executor::reference(), Dim2::new(3, 1));
        m.apply(&b, &mut x).unwrap();
        assert_eq!(x.as_slice(), &[4.0, 6.0, 19.0]);
    }

    #[test]
    fn transpose_direct_matches_data_transpose() {
        let m = Csr::from_data(Executor::reference(), &sample_data()).unwrap();
        let t = m.transpose().unwrap();
        assert_eq!(
            t.to_data().to_dense_vec(),
            sample_data().transpose().to_dense_vec()
        );
        // rectangular
        let mut d = MatrixData::<f64>::new(Dim2::new(2, 3));
        d.push(0, 2, 7.0);
        d.push(1, 0, -2.0);
        d.normalize();
        let m = Csr::from_data(Executor::reference(), &d).unwrap();
        let t = m.transpose().unwrap();
        assert_eq!(t.shape(), Dim2::new(3, 2));
        assert_eq!(t.to_data().to_dense_vec(), d.transpose().to_dense_vec());
    }

    #[test]
    fn apply_advanced_reference() {
        let m = Csr::from_data(Executor::reference(), &sample_data()).unwrap();
        let b = Dense::vector(Executor::reference(), &[1.0, 2.0, 3.0]);
        let mut x = Dense::vector(Executor::reference(), &[1.0, 1.0, 1.0]);
        // x = 2*A*b - 1*x
        m.apply_advanced(2.0, &b, -1.0, &mut x).unwrap();
        assert_eq!(x.as_slice(), &[7.0, 11.0, 37.0]);
    }
}
