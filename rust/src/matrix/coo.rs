//! Coordinate (COO) sparse format.
//!
//! Stores one explicit `(row, col, value)` triplet per nonzero, sorted
//! row-major. Memory footprint per nonzero: 1 value + 2 indices
//! (16 B double / 12 B single — the arithmetic-intensity numbers of §5).
//!
//! This is the format the paper uses inside all Krylov solver benchmarks
//! (§6.4) and one of the two formats in the SpMV study (§6.3).

use std::sync::Arc;

use crate::core::dim::Dim2;
use crate::core::error::{Result, SparkleError};
use crate::core::executor::Executor;
use crate::core::linop::LinOp;
use crate::core::matrix_data::MatrixData;
use crate::core::types::{IndexType, Value};
use crate::matrix::dense::Dense;

/// COO sparse matrix (row-sorted).
#[derive(Clone)]
pub struct Coo<T> {
    exec: Arc<Executor>,
    dim: Dim2,
    pub(crate) row_idxs: Vec<IndexType>,
    pub(crate) col_idxs: Vec<IndexType>,
    pub(crate) values: Vec<T>,
    /// Bucket-padded, *device-resident* copies of (rows, cols, values)
    /// for the XLA backend, built once on first apply when the matrix
    /// fits a single nnz bucket (EXPERIMENTS.md §Perf, L3 iterations
    /// 3-4). `Arc` keeps the struct Clone.
    pub(crate) padded_cache: once_cell::unsync::OnceCell<
        std::sync::Arc<(usize, xla::PjRtBuffer, xla::PjRtBuffer, xla::PjRtBuffer)>,
    >,
}

impl<T: Value> Coo<T> {
    /// Build from assembly data (normalizes a copy if needed).
    pub fn from_data(exec: Arc<Executor>, data: &MatrixData<T>) -> Result<Self> {
        data.validate()?;
        let owned;
        let src = if data.is_normalized() {
            data
        } else {
            let mut d = data.clone();
            d.normalize();
            owned = d;
            &owned
        };
        let nnz = src.nnz();
        let mut row_idxs = Vec::with_capacity(nnz);
        let mut col_idxs = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        for e in &src.entries {
            row_idxs.push(e.row);
            col_idxs.push(e.col);
            values.push(e.val);
        }
        Ok(Self {
            exec,
            dim: src.dim,
            row_idxs,
            col_idxs,
            values,
            padded_cache: once_cell::unsync::OnceCell::new(),
        })
    }

    /// Build directly from raw sorted arrays (validated).
    pub fn from_raw(
        exec: Arc<Executor>,
        dim: Dim2,
        row_idxs: Vec<IndexType>,
        col_idxs: Vec<IndexType>,
        values: Vec<T>,
    ) -> Result<Self> {
        if row_idxs.len() != col_idxs.len() || row_idxs.len() != values.len() {
            return Err(SparkleError::InvalidStructure(
                "coo arrays disagree in length".into(),
            ));
        }
        let sorted = row_idxs
            .windows(2)
            .all(|w| w[0] <= w[1]);
        if !sorted {
            return Err(SparkleError::InvalidStructure(
                "coo row indices must be sorted".into(),
            ));
        }
        let m = Self {
            exec,
            dim,
            row_idxs,
            col_idxs,
            values,
            padded_cache: once_cell::unsync::OnceCell::new(),
        };
        m.validate_bounds()?;
        Ok(m)
    }

    fn validate_bounds(&self) -> Result<()> {
        for i in 0..self.nnz() {
            let (r, c) = (self.row_idxs[i], self.col_idxs[i]);
            if r < 0 || c < 0 || r as usize >= self.dim.rows || c as usize >= self.dim.cols {
                return Err(SparkleError::InvalidStructure(format!(
                    "coo entry {i} at ({r},{c}) out of bounds for {}",
                    self.dim
                )));
            }
        }
        Ok(())
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Row index array.
    pub fn row_idxs(&self) -> &[IndexType] {
        &self.row_idxs
    }

    /// Column index array.
    pub fn col_idxs(&self) -> &[IndexType] {
        &self.col_idxs
    }

    /// Value array.
    pub fn values(&self) -> &[T] {
        &self.values
    }

    /// Back to assembly form.
    pub fn to_data(&self) -> MatrixData<T> {
        let mut d = MatrixData::new(self.dim);
        for i in 0..self.nnz() {
            d.push(self.row_idxs[i], self.col_idxs[i], self.values[i]);
        }
        d
    }

    /// Rebind executor.
    pub fn to_executor(&self, exec: Arc<Executor>) -> Self {
        let mut c = self.clone();
        c.exec = exec;
        c
    }
}

impl<T: Value> LinOp<T> for Coo<T> {
    fn shape(&self) -> Dim2 {
        self.dim
    }

    fn executor(&self) -> &Arc<Executor> {
        &self.exec
    }

    fn apply(&self, b: &Dense<T>, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        crate::kernels::spmv::coo_apply(&self.exec, self, b, x)
    }

    fn apply_advanced(&self, alpha: T, b: &Dense<T>, beta: T, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        crate::kernels::spmv::coo_apply_advanced(&self.exec, alpha, self, beta, b, x)
    }

    fn op_name(&self) -> &'static str {
        "coo"
    }
}

impl<T: Value> std::fmt::Debug for Coo<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Coo<{}>({}, nnz={})", T::PRECISION, self.dim, self.nnz())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_data() -> MatrixData<f64> {
        MatrixData::from_triplets(
            Dim2::square(3),
            &[0, 0, 1, 2, 2],
            &[0, 1, 1, 0, 2],
            &[2.0, 1.0, 3.0, 4.0, 5.0],
        )
        .unwrap()
    }

    #[test]
    fn from_data_layout() {
        let m = Coo::from_data(Executor::reference(), &sample_data()).unwrap();
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.row_idxs(), &[0, 0, 1, 2, 2]);
        assert_eq!(m.col_idxs(), &[0, 1, 1, 0, 2]);
        assert_eq!(m.values(), &[2.0, 1.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn from_unsorted_data_normalizes() {
        let mut d = MatrixData::<f64>::new(Dim2::square(2));
        d.push(1, 0, 4.0);
        d.push(0, 0, 1.0);
        let m = Coo::from_data(Executor::reference(), &d).unwrap();
        assert_eq!(m.row_idxs(), &[0, 1]);
    }

    #[test]
    fn from_raw_rejects_unsorted() {
        let r = Coo::from_raw(
            Executor::reference(),
            Dim2::square(2),
            vec![1, 0],
            vec![0, 0],
            vec![1.0f64, 2.0],
        );
        assert!(r.is_err());
    }

    #[test]
    fn round_trip_via_data() {
        let m = Coo::from_data(Executor::reference(), &sample_data()).unwrap();
        let d2 = m.to_data();
        assert_eq!(d2.to_dense_vec(), sample_data().to_dense_vec());
    }

    #[test]
    fn apply_reference() {
        let m = Coo::from_data(Executor::reference(), &sample_data()).unwrap();
        let b = Dense::vector(Executor::reference(), &[1.0, 2.0, 3.0]);
        let mut x = Dense::zeros(Executor::reference(), Dim2::new(3, 1));
        m.apply(&b, &mut x).unwrap();
        // [[2,1,0],[0,3,0],[4,0,5]] * [1,2,3] = [4, 6, 19]
        assert_eq!(x.as_slice(), &[4.0, 6.0, 19.0]);
    }
}
