//! Dense matrix / (multi-)vector, row-major.
//!
//! In Ginkgo `Dense` doubles as the vector type: a vector is an `n × 1`
//! dense matrix, a block of `k` right-hand sides an `n × k` one. Solvers
//! and SpMV kernels follow that convention here.

use std::sync::Arc;

use crate::core::dim::Dim2;
use crate::core::error::{Result, SparkleError};
use crate::core::executor::Executor;
use crate::core::linop::LinOp;
use crate::core::types::Value;

/// Row-major dense matrix with executor affinity.
#[derive(Clone)]
pub struct Dense<T> {
    exec: Arc<Executor>,
    dim: Dim2,
    values: Vec<T>,
}

impl<T: Value> Dense<T> {
    /// Zero-initialized matrix.
    pub fn zeros(exec: Arc<Executor>, dim: Dim2) -> Self {
        Self {
            exec,
            dim,
            values: vec![T::zero(); dim.count()],
        }
    }

    /// Constant-filled matrix.
    pub fn filled(exec: Arc<Executor>, dim: Dim2, value: T) -> Self {
        Self {
            exec,
            dim,
            values: vec![value; dim.count()],
        }
    }

    /// Take ownership of a row-major buffer.
    pub fn from_vec(exec: Arc<Executor>, dim: Dim2, values: Vec<T>) -> Result<Self> {
        if values.len() != dim.count() {
            return Err(SparkleError::dim(
                "dense::from_vec",
                format!("{} values for {}", values.len(), dim),
            ));
        }
        Ok(Self { exec, dim, values })
    }

    /// Column vector from a slice.
    pub fn vector(exec: Arc<Executor>, values: &[T]) -> Self {
        Self {
            exec,
            dim: Dim2::new(values.len(), 1),
            values: values.to_vec(),
        }
    }

    /// Dimensions.
    pub fn shape(&self) -> Dim2 {
        self.dim
    }

    /// Number of rows (vector length for n×1).
    pub fn len(&self) -> usize {
        self.dim.rows
    }

    /// True if the matrix holds no entries.
    pub fn is_empty(&self) -> bool {
        self.dim.count() == 0
    }

    /// Executor this object is bound to.
    pub fn executor(&self) -> &Arc<Executor> {
        &self.exec
    }

    /// Raw row-major values.
    pub fn as_slice(&self) -> &[T] {
        &self.values
    }

    /// Mutable raw values.
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.values
    }

    /// Entry accessor (row, col).
    pub fn at(&self, row: usize, col: usize) -> T {
        debug_assert!(row < self.dim.rows && col < self.dim.cols);
        self.values[row * self.dim.cols + col]
    }

    /// Mutable entry accessor.
    pub fn at_mut(&mut self, row: usize, col: usize) -> &mut T {
        debug_assert!(row < self.dim.rows && col < self.dim.cols);
        &mut self.values[row * self.dim.cols + col]
    }

    /// Overwrite every entry.
    pub fn fill(&mut self, value: T) {
        self.values.fill(value);
    }

    /// Consume the object, returning the row-major buffer (used by the
    /// solver workspace to recycle allocations across solves).
    pub fn into_vec(self) -> Vec<T> {
        self.values
    }

    /// Copy values from another dense of identical shape.
    pub fn copy_from(&mut self, other: &Dense<T>) -> Result<()> {
        if self.dim != other.dim {
            return Err(SparkleError::dim(
                "dense::copy_from",
                format!("{} vs {}", self.dim, other.dim),
            ));
        }
        self.values.copy_from_slice(&other.values);
        Ok(())
    }

    /// Rebind to a different executor (host memory is shared, so this is
    /// a metadata change — mirrors Ginkgo's `clone(exec)`).
    pub fn to_executor(&self, exec: Arc<Executor>) -> Self {
        Self {
            exec,
            dim: self.dim,
            values: self.values.clone(),
        }
    }

    /// Convert values to another precision.
    pub fn convert<U: Value>(&self) -> Dense<U> {
        Dense {
            exec: self.exec.clone(),
            dim: self.dim,
            values: self.values.iter().map(|v| U::from_f64(v.as_f64())).collect(),
        }
    }

    /// Euclidean norm of the whole buffer computed in f64 (host-side;
    /// used by tests and stopping criteria bootstrapping).
    pub fn norm2_host(&self) -> f64 {
        self.values
            .iter()
            .map(|v| {
                let x = v.as_f64();
                x * x
            })
            .sum::<f64>()
            .sqrt()
    }
}

impl<T: Value> std::fmt::Debug for Dense<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Dense<{}>({})", T::PRECISION, self.dim)
    }
}

/// Dense mat-vec: x = A b (reference implementation only — dense apply is
/// not on the paper's hot path; it exists for GMRES Hessenberg handling
/// and tests).
impl<T: Value> LinOp<T> for Dense<T> {
    fn shape(&self) -> Dim2 {
        self.dim
    }

    fn executor(&self) -> &Arc<Executor> {
        &self.exec
    }

    fn apply(&self, b: &Dense<T>, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        let (m, n, k) = (self.dim.rows, self.dim.cols, b.shape().cols);
        for i in 0..m {
            for c in 0..k {
                let mut acc = T::zero();
                for j in 0..n {
                    acc += self.at(i, j) * b.at(j, c);
                }
                *x.at_mut(i, c) = acc;
            }
        }
        Ok(())
    }

    fn op_name(&self) -> &'static str {
        "dense"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exec() -> Arc<Executor> {
        Executor::reference()
    }

    #[test]
    fn zeros_filled_vector() {
        let z = Dense::<f64>::zeros(exec(), Dim2::new(2, 3));
        assert_eq!(z.as_slice(), &[0.0; 6]);
        let f = Dense::<f32>::filled(exec(), Dim2::new(2, 2), 7.0);
        assert_eq!(f.as_slice(), &[7.0; 4]);
        let v = Dense::vector(exec(), &[1.0f64, 2.0]);
        assert_eq!(v.shape(), Dim2::new(2, 1));
        assert_eq!(v.len(), 2);
    }

    #[test]
    fn from_vec_checks_size() {
        assert!(Dense::from_vec(exec(), Dim2::new(2, 2), vec![1.0f64; 3]).is_err());
        assert!(Dense::from_vec(exec(), Dim2::new(2, 2), vec![1.0f64; 4]).is_ok());
    }

    #[test]
    fn indexing() {
        let mut a = Dense::from_vec(exec(), Dim2::new(2, 3), (0..6).map(f64::from).collect())
            .unwrap();
        assert_eq!(a.at(0, 0), 0.0);
        assert_eq!(a.at(1, 2), 5.0);
        *a.at_mut(1, 0) = 10.0;
        assert_eq!(a.at(1, 0), 10.0);
    }

    #[test]
    fn copy_from_and_fill() {
        let mut a = Dense::<f64>::zeros(exec(), Dim2::new(2, 2));
        let b = Dense::filled(exec(), Dim2::new(2, 2), 3.0);
        a.copy_from(&b).unwrap();
        assert_eq!(a.as_slice(), &[3.0; 4]);
        a.fill(1.0);
        assert_eq!(a.as_slice(), &[1.0; 4]);
        let c = Dense::<f64>::zeros(exec(), Dim2::new(3, 2));
        assert!(a.copy_from(&c).is_err());
    }

    #[test]
    fn dense_apply_matvec() {
        let a = Dense::from_vec(
            exec(),
            Dim2::new(2, 3),
            vec![1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0],
        )
        .unwrap();
        let b = Dense::vector(exec(), &[1.0, 0.0, -1.0]);
        let mut x = Dense::zeros(exec(), Dim2::new(2, 1));
        a.apply(&b, &mut x).unwrap();
        assert_eq!(x.as_slice(), &[-2.0, -2.0]);
    }

    #[test]
    fn dense_apply_rejects_mismatch() {
        let a = Dense::<f64>::zeros(exec(), Dim2::new(2, 3));
        let b = Dense::vector(exec(), &[1.0, 0.0]);
        let mut x = Dense::zeros(exec(), Dim2::new(2, 1));
        assert!(a.apply(&b, &mut x).is_err());
    }

    #[test]
    fn precision_convert_and_norm() {
        let v = Dense::vector(exec(), &[3.0f64, 4.0]);
        assert!((v.norm2_host() - 5.0).abs() < 1e-15);
        let s: Dense<f32> = v.convert();
        assert_eq!(s.as_slice(), &[3.0f32, 4.0]);
    }
}
