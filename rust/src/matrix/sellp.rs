//! SELL-P (sliced ELLPACK with padding) format.
//!
//! Rows are grouped into slices of `slice_size` rows; each slice is padded
//! only to *its own* longest row, removing ELL's global-padding blowup for
//! matrices with a few long rows. Storage inside a slice is column-major
//! (like ELL), so SIMD lanes still get coalesced access. This is Ginkgo's
//! GPU workhorse format; we include it for the format-ablation benches.

use std::sync::Arc;

use crate::core::dim::Dim2;
use crate::core::error::{Result, SparkleError};
use crate::core::executor::Executor;
use crate::core::linop::LinOp;
use crate::core::matrix_data::MatrixData;
use crate::core::types::{IndexType, Value};
use crate::matrix::dense::Dense;

/// Default rows per slice (Ginkgo uses the warp/subgroup size; the paper's
/// DPC++ port keeps 32 as the subgroup size on Intel GPUs).
pub const DEFAULT_SLICE_SIZE: usize = 32;

/// SELL-P sparse matrix.
#[derive(Clone)]
pub struct SellP<T> {
    exec: Arc<Executor>,
    dim: Dim2,
    pub(crate) slice_size: usize,
    /// Per-slice padded width; `slice_lengths[s]`.
    pub(crate) slice_lengths: Vec<usize>,
    /// Offset (in entries) of slice `s` in `values` / `col_idxs`.
    pub(crate) slice_sets: Vec<usize>,
    /// Within slice `s`: entry `j` of local row `r` is at
    /// `slice_sets[s] + j * slice_size + r` (column-major per slice).
    pub(crate) col_idxs: Vec<IndexType>,
    pub(crate) values: Vec<T>,
}

impl<T: Value> SellP<T> {
    /// Build with the default slice size.
    pub fn from_data(exec: Arc<Executor>, data: &MatrixData<T>) -> Result<Self> {
        Self::from_data_with_slice(exec, data, DEFAULT_SLICE_SIZE)
    }

    /// Build with an explicit slice size.
    pub fn from_data_with_slice(
        exec: Arc<Executor>,
        data: &MatrixData<T>,
        slice_size: usize,
    ) -> Result<Self> {
        if slice_size == 0 {
            return Err(SparkleError::InvalidStructure("slice_size = 0".into()));
        }
        data.validate()?;
        let owned;
        let src = if data.is_normalized() {
            data
        } else {
            let mut d = data.clone();
            d.normalize();
            owned = d;
            &owned
        };
        let n = src.dim.rows;
        let num_slices = n.div_ceil(slice_size).max(1);
        let row_lens = src.row_lengths();
        let mut slice_lengths = vec![0usize; num_slices];
        for (i, &len) in row_lens.iter().enumerate() {
            let s = i / slice_size;
            slice_lengths[s] = slice_lengths[s].max(len);
        }
        let mut slice_sets = vec![0usize; num_slices + 1];
        for s in 0..num_slices {
            slice_sets[s + 1] = slice_sets[s] + slice_lengths[s] * slice_size;
        }
        let total = slice_sets[num_slices];
        let mut col_idxs = vec![0 as IndexType; total];
        let mut values = vec![T::zero(); total];
        let mut fill = vec![0usize; n];
        for e in &src.entries {
            let i = e.row as usize;
            let s = i / slice_size;
            let r = i % slice_size;
            let j = fill[i];
            let pos = slice_sets[s] + j * slice_size + r;
            col_idxs[pos] = e.col;
            values[pos] = e.val;
            fill[i] += 1;
        }
        Ok(Self {
            exec,
            dim: src.dim,
            slice_size,
            slice_lengths,
            slice_sets,
            col_idxs,
            values,
        })
    }

    /// Rows per slice.
    pub fn slice_size(&self) -> usize {
        self.slice_size
    }

    /// Number of slices.
    pub fn num_slices(&self) -> usize {
        self.slice_lengths.len()
    }

    /// Stored entries including padding.
    pub fn stored_total(&self) -> usize {
        self.values.len()
    }

    /// Actual nonzeros.
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|v| !v.is_zero()).count()
    }

    /// Padding overhead ratio: stored / nnz (≥ 1).
    pub fn padding_ratio(&self) -> f64 {
        let nnz = self.nnz().max(1);
        self.stored_total() as f64 / nnz as f64
    }

    /// Back to assembly form (drops padding).
    pub fn to_data(&self) -> MatrixData<T> {
        let mut d = MatrixData::new(self.dim);
        for s in 0..self.num_slices() {
            for r in 0..self.slice_size {
                let i = s * self.slice_size + r;
                if i >= self.dim.rows {
                    break;
                }
                for j in 0..self.slice_lengths[s] {
                    let pos = self.slice_sets[s] + j * self.slice_size + r;
                    let v = self.values[pos];
                    if !v.is_zero() {
                        d.push(i as IndexType, self.col_idxs[pos], v);
                    }
                }
            }
        }
        d.normalize();
        d
    }

    /// Rebind executor.
    pub fn to_executor(&self, exec: Arc<Executor>) -> Self {
        let mut c = self.clone();
        c.exec = exec;
        c
    }
}

impl<T: Value> LinOp<T> for SellP<T> {
    fn shape(&self) -> Dim2 {
        self.dim
    }

    fn executor(&self) -> &Arc<Executor> {
        &self.exec
    }

    fn apply(&self, b: &Dense<T>, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        crate::kernels::spmv::sellp_apply(&self.exec, self, b, x)
    }

    fn apply_advanced(&self, alpha: T, b: &Dense<T>, beta: T, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        crate::kernels::spmv::sellp_apply_advanced(&self.exec, alpha, self, beta, b, x)
    }

    fn apply_dot(&self, b: &Dense<T>, x: &mut Dense<T>, w: &Dense<T>) -> Result<(T, T)> {
        self.check_conformant(b, x)?;
        crate::kernels::spmv::sellp_apply_dot(&self.exec, self, b, x, w)
    }

    fn op_name(&self) -> &'static str {
        "sellp"
    }
}

impl<T: Value> std::fmt::Debug for SellP<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SellP<{}>({}, slices={}, slice_size={})",
            T::PRECISION,
            self.dim,
            self.num_slices(),
            self.slice_size
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_data() -> MatrixData<f64> {
        MatrixData::from_triplets(
            Dim2::square(3),
            &[0, 0, 1, 2, 2],
            &[0, 1, 1, 0, 2],
            &[2.0, 1.0, 3.0, 4.0, 5.0],
        )
        .unwrap()
    }

    #[test]
    fn slicing_structure() {
        // slice_size 2 -> slices {rows 0,1} width 2, {row 2} width 2
        let m =
            SellP::from_data_with_slice(Executor::reference(), &sample_data(), 2).unwrap();
        assert_eq!(m.num_slices(), 2);
        assert_eq!(m.slice_lengths, vec![2, 2]);
        assert_eq!(m.slice_sets, vec![0, 4, 8]);
        assert_eq!(m.nnz(), 5);
    }

    #[test]
    fn slice_padding_beats_ell_on_skewed_rows() {
        // one dense row of 64, 63 rows of 1 entry
        let n = 64;
        let mut d = MatrixData::<f64>::new(Dim2::square(n));
        for j in 0..n {
            d.push(0, j as IndexType, 1.0);
        }
        for i in 1..n {
            d.push(i as IndexType, 0, 1.0);
        }
        let sellp =
            SellP::from_data_with_slice(Executor::reference(), &d, 8).unwrap();
        let ell = crate::matrix::ell::Ell::from_data(Executor::reference(), &d).unwrap();
        // ELL pads all 64 rows to width 64 (4096 stored); SELL-P only pads
        // the slice containing the dense row (568 stored).
        assert!(sellp.stored_total() < ell.stored_total() / 4);
        assert!(sellp.padding_ratio() < ell.stored_total() as f64 / ell.nnz() as f64 / 4.0);
    }

    #[test]
    fn round_trip_via_data() {
        let m =
            SellP::from_data_with_slice(Executor::reference(), &sample_data(), 2).unwrap();
        assert_eq!(m.to_data().to_dense_vec(), sample_data().to_dense_vec());
    }

    #[test]
    fn apply_reference() {
        for slice in [1, 2, 3, 32] {
            let m = SellP::from_data_with_slice(Executor::reference(), &sample_data(), slice)
                .unwrap();
            let b = Dense::vector(Executor::reference(), &[1.0, 2.0, 3.0]);
            let mut x = Dense::zeros(Executor::reference(), Dim2::new(3, 1));
            m.apply(&b, &mut x).unwrap();
            assert_eq!(x.as_slice(), &[4.0, 6.0, 19.0], "slice_size={slice}");
        }
    }

    #[test]
    fn zero_slice_size_rejected() {
        assert!(
            SellP::from_data_with_slice(Executor::reference(), &sample_data(), 0).is_err()
        );
    }
}
