//! Vendor-library stand-in (oneMKL's sparse CSR SpMV).
//!
//! The paper compares Ginkgo's kernels against Intel oneMKL's CSR SpMV.
//! oneMKL is closed-source and Intel-GPU-only, so the comparison slot is
//! filled by a *different real implementation with different scheduling
//! characteristics*: a merge-path CSR SpMV (Merrill & Garland 2016 — the
//! algorithm vendor libraries commonly ship). Its perfectly
//! nonzero-balanced partitioning behaves differently from sparkle's
//! row-parallel kernel on skewed matrices, reproducing the
//! "vendor kernel inconsistency" effect of §6.5 with mechanism instead
//! of mockery. The perf model carries the matching efficiency curve.

mod csr_merge;

pub use csr_merge::{merge_csr_spmv, VendorCsr};
pub(crate) use csr_merge::merge_row_splits;
