//! Merge-path CSR SpMV.
//!
//! The merge-path formulation treats SpMV as a 2-D merge of the
//! row-pointer array with the nonzero index range: each thread gets an
//! equal-length diagonal of the merge grid, which balances work by
//! *nonzeros* regardless of row lengths [Merrill & Garland 2016].
//! Partial sums for rows shared between threads are fixed up in a short
//! sequential carry pass.

use std::sync::Arc;

use crate::core::dim::Dim2;
use crate::core::error::Result;
use crate::core::executor::{Executor, ParConfig};
use crate::core::linop::LinOp;
use crate::core::types::Value;
use crate::kernels::ptr::SlicePtr;
use crate::matrix::csr::Csr;
use crate::matrix::dense::Dense;

/// Find the merge-path split point for diagonal `diag`: returns the row
/// index `i` such that the first `diag` merge steps consume row
/// boundaries `..i` and nonzeros `..(diag - i)`.
pub(crate) fn merge_path_search(diag: usize, row_ptrs: &[i32], nnz: usize) -> usize {
    let nrows = row_ptrs.len() - 1;
    let mut lo = diag.saturating_sub(nnz);
    let mut hi = diag.min(nrows);
    while lo < hi {
        let mid = (lo + hi) / 2;
        // consume row boundary `mid` before nonzero `diag - mid - 1`?
        if (row_ptrs[mid + 1] as usize) <= diag - mid - 1 {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Split rows into `parts` contiguous chunks balanced by *work*
/// (rows + nonzeros), by cutting at merge-grid diagonals. Returns
/// `parts + 1` monotone row boundaries; each chunk owns whole rows, so
/// callers need no carry fixup — a thread with a power-law row still
/// gets it alone while its neighbors take many light rows.
pub(crate) fn merge_row_splits(row_ptrs: &[i32], nnz: usize, parts: usize) -> Vec<usize> {
    let nrows = row_ptrs.len() - 1;
    let parts = parts.max(1);
    let total = nrows + nnz;
    let chunk = total.div_ceil(parts);
    let mut splits = Vec::with_capacity(parts + 1);
    splits.push(0usize);
    for t in 1..parts {
        let d = (t * chunk).min(total);
        let r = merge_path_search(d, row_ptrs, nnz).min(nrows);
        splits.push(r.max(*splits.last().unwrap()));
    }
    splits.push(nrows);
    splits
}

/// x = A b with merge-path scheduling (single rhs).
///
/// Phase 1: each thread walks its merge-grid diagonal range, writing
/// rows it owns exclusively and accumulating a carry for its first
/// (possibly shared) row. Phase 2: carries are added sequentially.
pub fn merge_csr_spmv<T: Value>(cfg: &ParConfig, a: &Csr<T>, b: &Dense<T>, x: &mut Dense<T>) {
    let nrows = a.shape().rows;
    let nnz = a.nnz();
    let row_ptrs = a.row_ptrs();
    let col_idxs = a.col_idxs();
    let values = a.values();
    let bs = b.as_slice();
    let threads = cfg.effective_threads().max(1).min(nrows.max(1));
    let total = nrows + nnz;
    let chunk = total.div_ceil(threads);

    let xs = x.as_mut_slice();
    xs.fill(T::zero());
    let xptr = SlicePtr(xs.as_mut_ptr());

    // carries[t] = (first row of thread t, its partial contribution)
    let carries: Vec<(usize, T)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let xptr = &xptr;
                s.spawn(move || {
                    let d0 = (t * chunk).min(total);
                    let d1 = ((t + 1) * chunk).min(total);
                    let row0 = merge_path_search(d0, row_ptrs, nnz);
                    let row1 = merge_path_search(d1, row_ptrs, nnz);
                    let mut k = d0 - row0; // first owned nonzero
                    let k_end = d1 - row1; // first nonzero past the chunk
                    let mut carry = T::zero();
                    let mut row = row0;
                    // rows fully or partially inside this chunk
                    while row <= row1 && row < nrows {
                        let boundary = if row < row1 {
                            row_ptrs[row + 1] as usize
                        } else {
                            k_end // trailing partial row
                        };
                        let mut acc = T::zero();
                        while k < boundary {
                            acc += values[k] * bs[col_idxs[k] as usize];
                            k += 1;
                        }
                        if row == row0 || row == row1 {
                            // shared with a neighbor thread -> carry;
                            // (row0 shares left, row1 shares right: the
                            // right neighbor records it as ITS row0, so
                            // only the in-chunk part goes through carry)
                            if row == row0 {
                                carry += acc;
                            } else {
                                // row1 > row0: exclusively-owned part of
                                // the trailing row goes via atomic-free
                                // accumulate too; the right neighbor adds
                                // its own part as carry. Writing += here
                                // is safe: the neighbor only touches this
                                // row through the sequential carry pass.
                                // SAFETY: see above.
                                unsafe { *xptr.at(row) += acc };
                            }
                        } else {
                            // SAFETY: rows strictly between row0 and row1
                            // are owned by exactly this thread.
                            unsafe { *xptr.at(row) += acc };
                        }
                        if row == row1 {
                            break;
                        }
                        row += 1;
                    }
                    (row0, carry)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("merge spmv worker panicked"))
            .collect()
    });
    // sequential carry fixup
    for (row, val) in carries {
        if row < nrows {
            xs[row] += val;
        }
    }
}

/// Vendor-style CSR operator: merge-path scheduled SpMV (the oneMKL
/// comparison slot of Fig. 8 / Fig. 10).
pub struct VendorCsr<T> {
    inner: Csr<T>,
    cfg: ParConfig,
}

impl<T: Value> VendorCsr<T> {
    /// Wrap a CSR matrix with vendor-style scheduling.
    pub fn new(inner: Csr<T>) -> Self {
        Self {
            inner,
            cfg: ParConfig::default(),
        }
    }

    /// Explicit thread configuration.
    pub fn with_config(mut self, cfg: ParConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// The wrapped CSR matrix.
    pub fn inner(&self) -> &Csr<T> {
        &self.inner
    }
}

impl<T: Value> LinOp<T> for VendorCsr<T> {
    fn shape(&self) -> Dim2 {
        self.inner.shape()
    }

    fn executor(&self) -> &Arc<Executor> {
        self.inner.executor()
    }

    fn apply(&self, b: &Dense<T>, x: &mut Dense<T>) -> Result<()> {
        self.check_conformant(b, x)?;
        merge_csr_spmv(&self.cfg, &self.inner, b, x);
        Ok(())
    }

    fn op_name(&self) -> &'static str {
        "vendor_csr"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::executor::Executor;
    use crate::testing::prng::Prng;
    use crate::testing::prop::{assert_close, gen_sparse, gen_vec};

    #[test]
    fn merge_path_search_basics() {
        // 2 rows: row 0 has 3 nnz, row 1 has 1
        let rp = [0, 3, 4];
        assert_eq!(merge_path_search(0, &rp, 4), 0);
        // full grid length = rows + nnz = 6
        assert_eq!(merge_path_search(6, &rp, 4), 2);
        // monotone
        let mut prev = 0;
        for d in 0..=6 {
            let r = merge_path_search(d, &rp, 4);
            assert!(r >= prev);
            prev = r;
        }
    }

    #[test]
    fn row_splits_balanced_and_monotone() {
        // 6 rows, skewed: row 2 holds most of the nonzeros
        let rp = [0, 1, 2, 12, 13, 14, 16];
        let nnz = 16;
        for parts in [1, 2, 3, 5, 9] {
            let s = merge_row_splits(&rp, nnz, parts);
            assert_eq!(s.len(), parts + 1);
            assert_eq!(s[0], 0);
            assert_eq!(*s.last().unwrap(), 6);
            for w in s.windows(2) {
                assert!(w[0] <= w[1], "monotone: {s:?}");
            }
            // every chunk's work (rows + nnz) stays within one grid chunk
            let total = 6 + nnz;
            let chunk = total.div_ceil(parts);
            for t in 0..parts {
                let rows = s[t + 1] - s[t];
                let work = rows + (rp[s[t + 1]] - rp[s[t]]) as usize;
                // a chunk can exceed `chunk` only via one indivisible row
                let heaviest = (s[t]..s[t + 1])
                    .map(|i| (rp[i + 1] - rp[i]) as usize)
                    .max()
                    .unwrap_or(0);
                assert!(
                    work <= chunk + heaviest + 1,
                    "parts={parts} t={t} work={work} chunk={chunk} splits={s:?}"
                );
            }
        }
        // empty matrix
        let s = merge_row_splits(&[0, 0, 0], 0, 4);
        assert_eq!(s[0], 0);
        assert_eq!(*s.last().unwrap(), 2);
    }

    #[test]
    fn matches_reference_on_random() {
        let mut rng = Prng::new(91);
        for trial in 0..6 {
            let n = 50 + rng.below(300);
            let data = gen_sparse::<f64>(&mut rng, n, n, 6);
            let exec = Executor::reference();
            let a = Csr::from_data(exec.clone(), &data).unwrap();
            let bv = gen_vec::<f64>(&mut rng, n);
            let b = Dense::vector(exec.clone(), &bv);
            let mut expect = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            a.apply(&b, &mut expect).unwrap();
            for threads in [1, 2, 4, 7] {
                let v = VendorCsr::new(a.clone()).with_config(ParConfig {
                    threads,
                    seq_threshold: 0,
                });
                let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
                v.apply(&b, &mut x).unwrap();
                assert_close(
                    x.as_slice(),
                    expect.as_slice(),
                    1e-12,
                    &format!("trial {trial} threads {threads}"),
                );
            }
        }
    }

    #[test]
    fn handles_skewed_rows_and_empty_rows() {
        let mut rng = Prng::new(92);
        let n = 128;
        let mut data = crate::MatrixData::<f64>::new(Dim2::square(n));
        // one huge row, many empty rows
        for j in 0..n {
            data.push(5, j as i32, rng.uniform(-1.0, 1.0));
        }
        for i in (0..n).step_by(3) {
            data.push(i as i32, ((i * 7) % n) as i32, rng.uniform(-1.0, 1.0));
        }
        data.normalize();
        let exec = Executor::reference();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let bv = gen_vec::<f64>(&mut rng, n);
        let b = Dense::vector(exec.clone(), &bv);
        let mut expect = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        a.apply(&b, &mut expect).unwrap();
        for threads in [1, 3, 8] {
            let v = VendorCsr::new(a.clone()).with_config(ParConfig {
                threads,
                seq_threshold: 0,
            });
            let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            v.apply(&b, &mut x).unwrap();
            assert_close(x.as_slice(), expect.as_slice(), 1e-12, "skewed");
        }
    }

    #[test]
    fn empty_matrix_ok() {
        let exec = Executor::reference();
        let data = crate::MatrixData::<f64>::new(Dim2::square(10));
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let v = VendorCsr::new(a);
        let b = Dense::vector(exec.clone(), &[1.0; 10]);
        let mut x = Dense::vector(exec.clone(), &[9.0; 10]);
        v.apply(&b, &mut x).unwrap();
        assert_eq!(x.as_slice(), &[0.0; 10]);
    }
}
