//! Integration tests for the autotune subsystem: cache-hit behaviour,
//! graceful degradation, and the solver wire-up.

use std::path::PathBuf;

use sparkle::autotune::{AutoConfig, AutoMatrix, ChoiceSource, TuneCache};
use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::matgen::stencil;
use sparkle::solver::{Cg, Solver, SolverConfig};
use sparkle::stop::Criterion;
use sparkle::testing::prng::Prng;
use sparkle::testing::prop::{assert_close, gen_sparse, gen_vec};
use sparkle::{Csr, Dense, Dim2};

fn tmp_cache(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "sparkle_autotune_it_{}_{tag}.json",
        std::process::id()
    ))
}

/// Acceptance criterion: a second tuning run against a warm cache must
/// perform zero measurement applies and land on the same format.
#[test]
fn warm_cache_second_run_measures_nothing() {
    let path = tmp_cache("warm");
    let _ = std::fs::remove_file(&path);
    let mut rng = Prng::new(31);
    let data = gen_sparse::<f64>(&mut rng, 150, 150, 6);
    let exec = Executor::par_with_threads(2);
    let cfg = AutoConfig {
        cache_path: Some(path.clone()),
        ..AutoConfig::default()
    };

    let cold = AutoMatrix::with_config(exec.clone(), &data, &cfg).unwrap();
    assert_eq!(cold.report().source, ChoiceSource::Measured);
    assert!(cold.report().measure_applies > 0, "cold run must measure");

    let warm = AutoMatrix::with_config(exec.clone(), &data, &cfg).unwrap();
    assert_eq!(warm.report().source, ChoiceSource::Cache);
    assert_eq!(
        warm.report().measure_applies,
        0,
        "warm cache must perform zero measurement applies"
    );
    assert_eq!(warm.chosen_format(), cold.chosen_format());
    assert!(warm.report().candidates.is_empty(), "no model query either");

    // the decision is keyed by precision: f32 re-tunes
    let mut rng32 = Prng::new(31);
    let data32 = gen_sparse::<f32>(&mut rng32, 150, 150, 6);
    let cold32 = AutoMatrix::with_config(exec, &data32, &cfg).unwrap();
    assert_eq!(cold32.report().source, ChoiceSource::Measured);

    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_cache_degrades_to_retune_then_heals() {
    let path = tmp_cache("corrupt");
    std::fs::write(&path, "}{ definitely not json").unwrap();
    let mut rng = Prng::new(32);
    let data = gen_sparse::<f64>(&mut rng, 60, 60, 4);
    let exec = Executor::reference();
    let cfg = AutoConfig {
        cache_path: Some(path.clone()),
        ..AutoConfig::default()
    };

    let first = AutoMatrix::with_config(exec.clone(), &data, &cfg).unwrap();
    assert_eq!(first.report().source, ChoiceSource::Measured);

    // the measured run rewrote the file; it must now parse and hit
    assert!(!TuneCache::load(&path).is_empty());
    let second = AutoMatrix::with_config(exec, &data, &cfg).unwrap();
    assert_eq!(second.report().source, ChoiceSource::Cache);

    let _ = std::fs::remove_file(&path);
}

#[test]
fn auto_is_a_drop_in_solver_operator() {
    let data = stencil::laplace_2d::<f64>(16, 16);
    let n = data.dim.rows;
    let exec = Executor::par_with_threads(2);
    let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);

    let auto = AutoMatrix::from_data(exec.clone(), &data).unwrap();
    let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let cg = Cg::new(SolverConfig::with_criterion(Criterion::residual(1e-10, 1000)));
    let result = cg.solve(&auto, &b, &mut x).unwrap();
    assert!(result.converged, "CG on AutoMatrix: {result:?}");

    // solve_data: the constructor path that accepts assembly data
    let mut x2 = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let result2 = cg.solve_data(&exec, &data, &b, &mut x2).unwrap();
    assert!(result2.converged, "CG solve_data: {result2:?}");
    assert_close(x2.as_slice(), x.as_slice(), 1e-6, "same solution");
}

#[test]
fn auto_apply_matches_hand_picked_csr() {
    let mut rng = Prng::new(33);
    let n = 120;
    let data = gen_sparse::<f64>(&mut rng, n, n, 7);
    let bv = gen_vec::<f64>(&mut rng, n);
    for exec in [Executor::reference(), Executor::par_with_threads(2)] {
        let auto = AutoMatrix::from_data(exec.clone(), &data).unwrap();
        let csr = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let mut xa = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let mut xc = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        auto.apply(&b, &mut xa).unwrap();
        csr.apply(&b, &mut xc).unwrap();
        assert_close(xa.as_slice(), xc.as_slice(), 1e-12, "auto vs csr");
    }
}

/// ROADMAP item "workspace-aware autotune": the measurement pass draws
/// its trial operands from the solver workspace pool, so a warm re-tune
/// of the same shape performs zero pool misses — zero Dense allocations
/// skewing a candidate's timing. The pool is thread-local, so the test
/// is isolated by construction.
#[test]
fn measure_reuses_workspace_operands() {
    use sparkle::autotune::{measure_formats, FormatChoice, MeasurePolicy};
    use sparkle::solver::workspace as ws;

    let mut rng = Prng::new(35);
    let data = gen_sparse::<f64>(&mut rng, 80, 80, 5);
    let exec = Executor::reference();

    ws::clear();
    let cold = measure_formats(&exec, &data, &FormatChoice::ALL, MeasurePolicy::default());
    assert_eq!(cold.len(), FormatChoice::ALL.len());
    let (_, cold_misses) = ws::stats();
    assert!(cold_misses > 0, "first tune must populate the pool");

    ws::reset_stats();
    let warm = measure_formats(&exec, &data, &FormatChoice::ALL, MeasurePolicy::default());
    assert_eq!(warm.len(), cold.len());
    let (hits, misses) = ws::stats();
    assert_eq!(misses, 0, "warm re-tune must reuse pooled operands ({hits} hits)");
    assert!(hits > 0, "warm re-tune must draw from the pool");
    ws::clear();
}

#[test]
fn auto_on_ported_backend_without_artifacts_constructs() {
    // no artifacts dir: measurement probes fail, the prior decides, and
    // apply reports the real runtime error instead of panicking
    let exec = Executor::xla("nonexistent_artifacts_for_autotune_test").unwrap();
    let mut rng = Prng::new(34);
    let data = gen_sparse::<f64>(&mut rng, 30, 30, 3);
    let auto = AutoMatrix::from_data(exec.clone(), &data).unwrap();
    assert_eq!(auto.report().source, ChoiceSource::Prior);
    assert_eq!(auto.report().measure_applies, 0);
    let b = Dense::filled(exec.clone(), Dim2::new(30, 1), 1.0);
    let mut x = Dense::zeros(exec, Dim2::new(30, 1));
    assert!(auto.apply(&b, &mut x).is_err());
}
