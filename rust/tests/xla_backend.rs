//! Integration tests for the XLA ("ported") backend: every kernel family
//! must agree with the reference executor through the full
//! pad-to-bucket → PJRT execute → slice-back path.
//!
//! Requires `make artifacts`; tests are skipped (pass vacuously, with a
//! note) when the artifact directory is missing so `cargo test` works on
//! a fresh checkout.

use std::sync::Arc;

use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::kernels::{blas, spmv};
use sparkle::matrix::conversion::{csr_to_coo, csr_to_ell};
use sparkle::matrix::{Csr, Dense};
use sparkle::testing::prng::Prng;
use sparkle::testing::prop::{assert_close, gen_sparse, gen_vec};
use sparkle::Dim2;

fn xla_exec() -> Option<Arc<Executor>> {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Executor::xla("artifacts").expect("xla executor"))
}

#[test]
fn blas1_matches_reference_f64() {
    let Some(exec) = xla_exec() else { return };
    let reference = Executor::reference();
    let mut rng = Prng::new(1);
    for n in [100, 256, 1000, 5000] {
        let xv = gen_vec::<f64>(&mut rng, n);
        let yv = gen_vec::<f64>(&mut rng, n);
        let x = Dense::vector(exec.clone(), &xv);
        let mut y = Dense::vector(exec.clone(), &yv);
        let xr = Dense::vector(reference.clone(), &xv);
        let mut yr = Dense::vector(reference.clone(), &yv);

        blas::axpy(&exec, 1.5, &x, &mut y).unwrap();
        blas::axpy(&reference, 1.5, &xr, &mut yr).unwrap();
        assert_close(y.as_slice(), yr.as_slice(), 1e-13, "axpy");

        blas::axpby(&exec, -0.25, &x, 2.0, &mut y).unwrap();
        blas::axpby(&reference, -0.25, &xr, 2.0, &mut yr).unwrap();
        assert_close(y.as_slice(), yr.as_slice(), 1e-13, "axpby");

        blas::scal(&exec, 0.5, &mut y).unwrap();
        blas::scal(&reference, 0.5, &mut yr).unwrap();
        assert_close(y.as_slice(), yr.as_slice(), 1e-13, "scal");

        let d = blas::dot(&exec, &x, &y).unwrap();
        let dr = blas::dot(&reference, &xr, &yr).unwrap();
        assert!((d - dr).abs() < 1e-10 * dr.abs().max(1.0), "dot n={n}");

        let nm = blas::norm2(&exec, &x).unwrap();
        let nr = blas::norm2(&reference, &xr).unwrap();
        assert!((nm - nr).abs() < 1e-12 * nr, "norm2 n={n}");
    }
}

#[test]
fn blas1_matches_reference_f32() {
    let Some(exec) = xla_exec() else { return };
    let reference = Executor::reference();
    let mut rng = Prng::new(2);
    let n = 777; // deliberately not a bucket size
    let xv = gen_vec::<f32>(&mut rng, n);
    let yv = gen_vec::<f32>(&mut rng, n);
    let x = Dense::vector(exec.clone(), &xv);
    let mut y = Dense::vector(exec.clone(), &yv);
    let xr = Dense::vector(reference.clone(), &xv);
    let mut yr = Dense::vector(reference.clone(), &yv);
    blas::axpy(&exec, 0.7f32, &x, &mut y).unwrap();
    blas::axpy(&reference, 0.7f32, &xr, &mut yr).unwrap();
    assert_close(y.as_slice(), yr.as_slice(), 1e-6, "axpy f32");
}

#[test]
fn ew_mul_matches() {
    let Some(exec) = xla_exec() else { return };
    let mut rng = Prng::new(3);
    let xv = gen_vec::<f64>(&mut rng, 300);
    let yv = gen_vec::<f64>(&mut rng, 300);
    let x = Dense::vector(exec.clone(), &xv);
    let y = Dense::vector(exec.clone(), &yv);
    let mut z = Dense::zeros(exec.clone(), Dim2::new(300, 1));
    blas::ew_mul(&exec, &x, &y, &mut z).unwrap();
    let expect: Vec<f64> = xv.iter().zip(&yv).map(|(a, b)| a * b).collect();
    assert_close(z.as_slice(), &expect, 1e-14, "ew_mul");
}

#[test]
fn spmv_all_formats_match_reference() {
    let Some(exec) = xla_exec() else { return };
    let reference = Executor::reference();
    let mut rng = Prng::new(4);
    for n in [64, 300, 1500] {
        let data = gen_sparse::<f64>(&mut rng, n, n, 5);
        let bv = gen_vec::<f64>(&mut rng, n);

        let csr_r = Csr::from_data(reference.clone(), &data).unwrap();
        let br = Dense::vector(reference.clone(), &bv);
        let mut expect = Dense::zeros(reference.clone(), Dim2::new(n, 1));
        csr_r.apply(&br, &mut expect).unwrap();

        let b = Dense::vector(exec.clone(), &bv);

        // CSR via row-expansion -> coo_adv artifact
        let csr = Csr::from_data(exec.clone(), &data).unwrap();
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        csr.apply(&b, &mut x).unwrap();
        assert_close(x.as_slice(), expect.as_slice(), 1e-12, "xla csr");

        // COO segment-sum artifact
        let coo = csr_to_coo(&csr).unwrap();
        coo.apply(&b, &mut x).unwrap();
        assert_close(x.as_slice(), expect.as_slice(), 1e-12, "xla coo");

        // ELL pallas artifact
        let ell = csr_to_ell(&csr).unwrap();
        ell.apply(&b, &mut x).unwrap();
        assert_close(x.as_slice(), expect.as_slice(), 1e-12, "xla ell");
    }
}

#[test]
fn spmv_advanced_alpha_beta() {
    let Some(exec) = xla_exec() else { return };
    let reference = Executor::reference();
    let mut rng = Prng::new(5);
    let n = 400;
    let data = gen_sparse::<f64>(&mut rng, n, n, 4);
    let bv = gen_vec::<f64>(&mut rng, n);
    let x0 = gen_vec::<f64>(&mut rng, n);

    let csr_r = Csr::from_data(reference.clone(), &data).unwrap();
    let br = Dense::vector(reference.clone(), &bv);
    let mut xr = Dense::vector(reference.clone(), &x0);
    csr_r.apply_advanced(2.5, &br, -0.75, &mut xr).unwrap();

    let csr = Csr::from_data(exec.clone(), &data).unwrap();
    let b = Dense::vector(exec.clone(), &bv);
    let mut x = Dense::vector(exec.clone(), &x0);
    csr.apply_advanced(2.5, &b, -0.75, &mut x).unwrap();
    assert_close(x.as_slice(), xr.as_slice(), 1e-12, "csr advanced");

    let ell_r = csr_to_ell(&csr_r).unwrap();
    let mut xr2 = Dense::vector(reference.clone(), &x0);
    spmv::ell_apply_advanced(&reference, 2.5, &ell_r, -0.75, &br, &mut xr2).unwrap();
    assert_close(xr2.as_slice(), xr.as_slice(), 1e-12, "ell advanced ref");

    let ell = csr_to_ell(&csr).unwrap();
    let mut x2 = Dense::vector(exec.clone(), &x0);
    spmv::ell_apply_advanced(&exec, 2.5, &ell, -0.75, &b, &mut x2).unwrap();
    assert_close(x2.as_slice(), xr.as_slice(), 1e-12, "ell advanced xla");
}

#[test]
fn coo_chunking_oversized_nnz() {
    // A matrix whose nnz exceeds the largest bucket multiplier at its
    // row bucket (n=256 -> max nnz bucket 64*256=16384). 20000 nnz forces
    // the chunked accumulation path.
    let Some(exec) = xla_exec() else { return };
    let reference = Executor::reference();
    let mut rng = Prng::new(6);
    let n = 256;
    let mut data = sparkle::MatrixData::<f64>::new(Dim2::square(n));
    for _ in 0..20_000 {
        data.push(
            rng.below(n) as i32,
            rng.below(n) as i32,
            rng.uniform(-1.0, 1.0),
        );
    }
    data.normalize(); // duplicates summed; still ~>16k entries
    assert!(data.nnz() > 16_384, "need the chunked path, nnz={}", data.nnz());
    let bv = gen_vec::<f64>(&mut rng, n);

    let coo_r = sparkle::Coo::from_data(reference.clone(), &data).unwrap();
    let br = Dense::vector(reference.clone(), &bv);
    let mut expect = Dense::zeros(reference.clone(), Dim2::new(n, 1));
    coo_r.apply(&br, &mut expect).unwrap();

    let coo = sparkle::Coo::from_data(exec.clone(), &data).unwrap();
    let b = Dense::vector(exec.clone(), &bv);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    coo.apply(&b, &mut x).unwrap();
    assert_close(x.as_slice(), expect.as_slice(), 1e-12, "chunked coo");
}

#[test]
fn ell_width_chunking() {
    // Width 150 exceeds the largest k bucket (128) -> two width-chunks.
    let Some(exec) = xla_exec() else { return };
    let reference = Executor::reference();
    let mut rng = Prng::new(7);
    let n = 256;
    let mut data = sparkle::MatrixData::<f64>::new(Dim2::square(n));
    for i in 0..n {
        for j in 0..150 {
            data.push(i as i32, ((i + j * 7) % n) as i32, rng.uniform(-1.0, 1.0));
        }
    }
    data.normalize();
    let bv = gen_vec::<f64>(&mut rng, n);

    let ell_r = sparkle::Ell::from_data(reference.clone(), &data).unwrap();
    assert!(ell_r.stored_per_row() > 128);
    let br = Dense::vector(reference.clone(), &bv);
    let mut expect = Dense::zeros(reference.clone(), Dim2::new(n, 1));
    ell_r.apply(&br, &mut expect).unwrap();

    let ell = sparkle::Ell::from_data(exec.clone(), &data).unwrap();
    let b = Dense::vector(exec.clone(), &bv);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    ell.apply(&b, &mut x).unwrap();
    assert_close(x.as_slice(), expect.as_slice(), 1e-11, "width-chunked ell");
}

#[test]
fn stream_kernels_on_xla() {
    let Some(exec) = xla_exec() else { return };
    use sparkle::kernels::stream::{self, StreamKernel};
    let mut ar = stream::StreamArrays::<f64>::new(1000);
    let iters = 2;
    for _ in 0..iters {
        for k in [
            StreamKernel::Copy,
            StreamKernel::Mul,
            StreamKernel::Add,
            StreamKernel::Triad,
        ] {
            stream::run(&exec, k, &mut ar).unwrap();
        }
    }
    assert!(stream::verify(&ar, iters) < 1e-12);
    let d = stream::run(&exec, StreamKernel::Dot, &mut ar).unwrap();
    let host: f64 = ar.a.iter().zip(&ar.b).map(|(x, y)| x * y).sum();
    assert!((d - host).abs() < 1e-9 * host.abs().max(1.0));
}

#[test]
fn launch_counter_increments() {
    let Some(exec) = xla_exec() else { return };
    let rt = exec.xla_runtime().unwrap();
    let before = rt.launch_count();
    let x = Dense::vector(exec.clone(), &[1.0f64; 100]);
    let mut y = Dense::vector(exec.clone(), &[2.0f64; 100]);
    blas::axpy(&exec, 1.0, &x, &mut y).unwrap();
    assert!(rt.launch_count() > before);
}
