//! Property tests: every sparse format preserves assembly data exactly.
//!
//! Csr → {Coo, Ell, SellP, Hybrid} → Csr must reproduce the original
//! `MatrixData` bit-for-bit — conversions only rearrange storage, they
//! never do arithmetic, so exact equality (not tolerance) is the
//! contract.

use std::sync::Arc;

use sparkle::core::executor::Executor;
use sparkle::matrix::conversion::{convert, FromData, ToData};
use sparkle::matrix::{Coo, Csr, Ell, Hybrid, SellP};
use sparkle::testing::prop::{for_all, gen_sparse};
use sparkle::{MatrixData, Value};

fn assert_data_eq<T: Value>(a: &MatrixData<T>, b: &MatrixData<T>, what: &str) {
    assert_eq!(a.dim, b.dim, "{what}: dim");
    assert_eq!(a.nnz(), b.nnz(), "{what}: nnz");
    for (i, (x, y)) in a.entries.iter().zip(&b.entries).enumerate() {
        assert_eq!(x, y, "{what}: entry {i}");
    }
}

fn round_trip_preserves<T, F>(csr: &Csr<T>, d0: &MatrixData<T>, exec: &Arc<Executor>, what: &str)
where
    T: Value,
    F: FromData<T> + ToData<T>,
{
    let via: F = convert(csr, exec.clone()).expect(what);
    let back: Csr<T> = convert(&via, exec.clone()).expect(what);
    assert_data_eq(&back.to_data(), d0, what);
    // and the intermediate format itself exports the same data
    assert_data_eq(&via.to_data_generic(), d0, what);
}

#[test]
fn prop_csr_round_trips_through_every_format() {
    let exec = Executor::reference();
    for_all(0x5EED, 12, |rng, _| {
        let rows = 10 + rng.below(70);
        let cols = 10 + rng.below(70);
        let data = gen_sparse::<f64>(rng, rows, cols, 5);
        let csr = Csr::from_data(exec.clone(), &data).unwrap();
        let d0 = csr.to_data();
        assert_data_eq(&d0, &data, "csr itself");

        round_trip_preserves::<f64, Coo<f64>>(&csr, &d0, &exec, "via coo");
        round_trip_preserves::<f64, Ell<f64>>(&csr, &d0, &exec, "via ell");
        round_trip_preserves::<f64, SellP<f64>>(&csr, &d0, &exec, "via sellp");
        round_trip_preserves::<f64, Hybrid<f64>>(&csr, &d0, &exec, "via hybrid");
    });
}

#[test]
fn prop_round_trip_f32() {
    let exec = Executor::reference();
    for_all(0xF32, 6, |rng, _| {
        let n = 8 + rng.below(40);
        let data = gen_sparse::<f32>(rng, n, n, 4);
        let csr = Csr::from_data(exec.clone(), &data).unwrap();
        let d0 = csr.to_data();
        round_trip_preserves::<f32, Coo<f32>>(&csr, &d0, &exec, "via coo f32");
        round_trip_preserves::<f32, Hybrid<f32>>(&csr, &d0, &exec, "via hybrid f32");
    });
}

#[test]
fn pathological_shapes_round_trip() {
    let exec = Executor::reference();

    // empty rows: entries only in the first and last row
    let mut d = MatrixData::<f64>::new(sparkle::Dim2::new(9, 9));
    d.push(0, 3, 1.5);
    d.push(8, 0, -2.0);
    d.normalize();
    let csr = Csr::from_data(exec.clone(), &d).unwrap();
    round_trip_preserves::<f64, Coo<f64>>(&csr, &d, &exec, "empty rows coo");
    round_trip_preserves::<f64, Ell<f64>>(&csr, &d, &exec, "empty rows ell");
    round_trip_preserves::<f64, SellP<f64>>(&csr, &d, &exec, "empty rows sellp");
    round_trip_preserves::<f64, Hybrid<f64>>(&csr, &d, &exec, "empty rows hybrid");

    // single dense row on top of a diagonal
    let n = 17;
    let mut d = MatrixData::<f64>::new(sparkle::Dim2::square(n));
    for j in 0..n {
        d.push(0, j as i32, (j + 1) as f64);
    }
    for i in 1..n {
        d.push(i as i32, i as i32, 3.0);
    }
    d.normalize();
    let csr = Csr::from_data(exec.clone(), &d).unwrap();
    round_trip_preserves::<f64, SellP<f64>>(&csr, &d, &exec, "dense row sellp");
    round_trip_preserves::<f64, Hybrid<f64>>(&csr, &d, &exec, "dense row hybrid");
}
