//! Integration tests for the observe layer: event ordering and
//! nesting under a real solve, JSON-lines round-trip, roofline
//! efficiency on the host backend, and the zero-cost disabled path.
//!
//! The logger slot is global, so every test that installs one holds
//! `LOCK` for its whole body — the tests in this binary serialize
//! instead of racing each other's events.

use std::sync::{Arc, Mutex};

use sparkle::core::executor::Executor;
use sparkle::core::types::Precision;
use sparkle::matgen::stencil;
use sparkle::observe::{self, Event, JsonlLogger, KernelClass, NullLogger, Profile, Record};
use sparkle::perfmodel::Device;
use sparkle::solver::SolverBuilder;
use sparkle::stop::Criterion;
use sparkle::{Dense, Dim2};

static LOCK: Mutex<()> = Mutex::new(());

fn poisson(exec: &Arc<Executor>) -> (sparkle::Csr<f64>, Dense<f64>, Dense<f64>) {
    let data = stencil::laplace_2d::<f64>(16, 16);
    let n = data.dim.rows;
    let a = sparkle::Csr::from_data(exec.clone(), &data).unwrap();
    let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
    let x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    (a, b, x)
}

fn builder() -> SolverBuilder<f64> {
    SolverBuilder::cg().with_criterion(Criterion::residual(1e-10, 500))
}

/// Acceptance criterion: an instrumented CG solve produces a properly
/// ordered, properly nested event stream.
#[test]
fn solve_emits_ordered_nested_events() {
    let _lock = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let exec = Executor::par_with_threads(2);
    let (a, b, mut x) = poisson(&exec);
    let rec = Arc::new(Record::new());
    let result = builder()
        .with_logger(rec.clone())
        .solve(&a, &b, &mut x)
        .unwrap();
    assert!(result.converged, "{result:?}");
    assert!(
        !observe::enabled(),
        "scoped logger must be uninstalled after the solve"
    );

    let events = rec.events();
    assert!(matches!(events.first(), Some(Event::SolverStart { .. })));
    assert!(matches!(events.last(), Some(Event::SolverDone { .. })));

    // kernel start/stop must pair up without nesting (guards sit at
    // dispatch leaves only)
    let mut depth = 0usize;
    let mut iter_seen = 0usize;
    for e in &events {
        match e {
            Event::KernelStart { .. } => {
                depth += 1;
                assert_eq!(depth, 1, "kernel events must not nest: {e:?}");
            }
            Event::KernelStop { seconds, .. } => {
                assert_eq!(depth, 1, "stop without start: {e:?}");
                depth -= 1;
                assert!(*seconds >= 0.0 && seconds.is_finite());
            }
            Event::SolverIteration {
                solver, iteration, ..
            } => {
                assert_eq!(solver, "cg");
                iter_seen += 1;
                assert_eq!(*iteration, iter_seen, "iterations must be consecutive");
            }
            _ => {}
        }
    }
    assert_eq!(depth, 0, "every kernel start must be stopped");
    assert_eq!(iter_seen, result.iterations);
    match events.last() {
        Some(Event::SolverDone {
            iterations,
            converged,
            ..
        }) => {
            assert_eq!(*iterations, result.iterations);
            assert!(*converged);
        }
        other => panic!("expected SolverDone, got {other:?}"),
    }
}

/// Acceptance criterion: every event variant survives the JSON-lines
/// sink byte-exactly.
#[test]
fn jsonl_sink_round_trips_every_variant() {
    let samples = vec![
        Event::KernelStart {
            class: KernelClass::Spmv,
            name: "csr".to_string(),
        },
        Event::KernelStop {
            class: KernelClass::Spmv,
            name: "csr".to_string(),
            exec: "par".to_string(),
            seconds: 1.25e-5,
            flops: 9800.0,
            bytes: 74804.0,
        },
        Event::SolverStart {
            solver: "cg".to_string(),
            rows: 256,
        },
        Event::SolverIteration {
            solver: "cg".to_string(),
            iteration: 7,
            resnorm: 3.2e-4,
        },
        Event::SolverDone {
            solver: "cg".to_string(),
            iterations: 41,
            converged: true,
            resnorm: 8.1e-11,
        },
        Event::Checkpoint {
            solver: "bicgstab".to_string(),
            at_iter: 25,
            true_resnorm: 1.7e-3,
        },
        Event::Rollback {
            solver: "cg".to_string(),
            reason: "breakdown: ZeroDenominator { what: \"p·Ap\" }".to_string(),
        },
        Event::Drift {
            solver: "cgs".to_string(),
            recurrence: 1e-9,
            true_resnorm: 1e-2,
        },
        Event::Fallback {
            from: "cg".to_string(),
            to: "bicgstab".to_string(),
        },
        Event::AutotuneCandidate {
            format: "ell".to_string(),
            median_us: 12.75,
            applies: 7,
        },
        Event::AutotuneDecision {
            format: "csr".to_string(),
            source: "measured".to_string(),
            predicted_us: 10.5,
        },
        Event::Launch {
            artifact: "spmv_csr_f64_b4096".to_string(),
            seconds: 2.5e-4,
            ok: true,
        },
        Event::Retry {
            what: "execute".to_string(),
            attempt: 2,
        },
        Event::BreakerOpen { failures: 3 },
    ];
    let sink = JsonlLogger::in_memory();
    for e in &samples {
        use sparkle::observe::Logger as _;
        sink.log(e);
    }
    let lines = sink.lines();
    assert_eq!(lines.len(), samples.len());
    for (line, expect) in lines.iter().zip(&samples) {
        let parsed = Event::from_json_line(line)
            .unwrap_or_else(|| panic!("unparseable line: {line}"));
        assert_eq!(&parsed, expect, "round-trip mismatch for {line}");
    }
}

/// Acceptance criterion: the aggregated Profile of a host-backend CG
/// solve reports SpMV roofline efficiency in (0, 1].
#[test]
fn profile_reports_spmv_efficiency_in_unit_interval() {
    let _lock = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let exec = Executor::par_with_threads(2);
    let (a, b, mut x) = poisson(&exec);
    let rec = Arc::new(Record::new());
    let result = builder()
        .with_logger(rec.clone())
        .solve(&a, &b, &mut x)
        .unwrap();
    assert!(result.converged);

    let profile = Profile::from_events(&rec.events(), Device::Gen12, Precision::Double);
    let roofline = profile.roofline();
    let spmv: Vec<_> = profile
        .kernels
        .iter()
        .filter(|k| k.class == KernelClass::Spmv)
        .collect();
    assert!(!spmv.is_empty(), "CG must have run SpMV kernels");
    for k in spmv {
        let eff = k
            .efficiency(&roofline, profile.precision)
            .expect("spmv has a flop model");
        assert!(
            eff > 0.0 && eff <= 1.0,
            "efficiency out of (0,1]: {eff} for {k:?}"
        );
    }
    assert_eq!(profile.iterations, result.iterations);
    assert!(profile.converged);
    let json = profile.to_json();
    assert!(json.contains("\"schema\": \"sparkle/observe/v1\""));
    assert!(json.contains("\"class\": \"spmv\""));
}

/// Acceptance criterion: with no logger (or the NullLogger) the event
/// path does no work — the emit closure is never even called.
#[test]
fn disabled_logger_adds_no_events_and_no_work() {
    let _lock = LOCK.lock().unwrap_or_else(|p| p.into_inner());

    // no logger installed: closure must not run
    let mut ran = false;
    observe::emit(|| {
        ran = true;
        Event::BreakerOpen { failures: 0 }
    });
    assert!(!ran, "emit closure ran with no logger installed");

    // NullLogger installed: still disabled, closure still must not run
    {
        let _scope = observe::install_scoped(Arc::new(NullLogger));
        assert!(!observe::enabled());
        let mut ran = false;
        observe::emit(|| {
            ran = true;
            Event::BreakerOpen { failures: 0 }
        });
        assert!(!ran, "emit closure ran under NullLogger");
    }

    // a Record captures a solve; re-running the same solve under a
    // nested NullLogger scope adds nothing
    let exec = Executor::par_with_threads(2);
    let (a, b, mut x) = poisson(&exec);
    let rec = Arc::new(Record::new());
    {
        let _scope = observe::install_scoped(rec.clone());
        builder().solve(&a, &b, &mut x).unwrap();
        let count = rec.len();
        assert!(count > 0);
        {
            let _null = observe::install_scoped(Arc::new(NullLogger));
            let mut x2 = Dense::zeros(exec.clone(), Dim2::new(x.len(), 1));
            builder().solve(&a, &b, &mut x2).unwrap();
        }
        assert_eq!(rec.len(), count, "NullLogger scope must add no events");
    }
    assert!(!observe::enabled());
}

/// `solve_data` installs the logger before format selection runs, so
/// autotune candidate/decision events are captured alongside the
/// solve's own events.
#[test]
fn builder_solve_data_captures_autotune_events() {
    let _lock = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let exec = Executor::par_with_threads(2);
    let data = stencil::laplace_2d::<f64>(16, 16);
    let n = data.dim.rows;
    let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let rec = Arc::new(Record::new());
    let result = builder()
        .with_logger(rec.clone())
        .solve_data(&exec, &data, &b, &mut x)
        .unwrap();
    assert!(result.converged);

    let events = rec.events();
    assert!(
        events
            .iter()
            .any(|e| matches!(e, Event::AutotuneDecision { .. })),
        "solve_data must emit the format decision"
    );
    let profile = Profile::from_events(&events, Device::Gen12, Precision::Double);
    assert!(profile.autotune_format.is_some());
    assert!(profile.autotune_source.is_some());
}
