//! Fault-injection integration suite: every Krylov driver must survive
//! injected NaN payloads, bit-flips and transient apply failures —
//! either converging after recovery ([`ResilientSolver`]) or returning
//! a structured breakdown/error. Never a panic, and never a silent
//! wrong answer: whenever a solve claims convergence, the final iterate
//! is re-verified against the *clean* operator here.
//!
//! All fault schedules are seeded, so failures reproduce exactly.

use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::kernels::blas;
use sparkle::matgen::stencil;
use sparkle::matrix::{Csr, Dense};
use sparkle::resilience::{
    FaultSpec, FaultyOp, RecoveryPolicy, ResilientSolver, SolverKind,
};
use sparkle::solver::{Solver, SolverConfig};
use sparkle::stop::{Criterion, StopStatus};
use sparkle::testing::prng::Prng;
use sparkle::testing::prop::{gen_sparse, gen_vec};
use sparkle::{Dim2, MatrixData, SparkleError};

/// Every buildable driver, exercised one by one.
const ALL_KINDS: [SolverKind; 6] = [
    SolverKind::Cg,
    SolverKind::Fcg,
    SolverKind::BiCgStab,
    SolverKind::Cgs,
    SolverKind::Gmres { restart: 20 },
    SolverKind::Richardson { omega: 0.9 },
];

fn spd_system(seed: u64, n: usize) -> (MatrixData<f64>, Vec<f64>) {
    let mut rng = Prng::new(seed);
    let mut data = gen_sparse::<f64>(&mut rng, n, n, 3);
    data.symmetrize();
    data.shift_diagonal(2.0);
    let b = gen_vec::<f64>(&mut rng, n);
    (data, b)
}

/// `||b - A x||` against the *clean* operator — the arbiter for every
/// convergence claim in this suite.
fn clean_residual(a: &Csr<f64>, b: &Dense<f64>, x: &Dense<f64>) -> f64 {
    let mut r = b.clone();
    a.apply_advanced(-1.0, x, 1.0, &mut r).unwrap();
    r.norm2_host()
}

/// NaN payloads must surface as a structured breakdown from every
/// driver: `Ok` with `converged == false` and a `Diverged` status — no
/// panic, no spinning to `max_iters` with a poisoned iterate.
#[test]
fn every_driver_reports_nan_injection_as_breakdown() {
    let (data, bv) = spd_system(101, 100);
    let exec = Executor::reference();
    for kind in ALL_KINDS {
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let faulty = FaultyOp::new(
            a,
            FaultSpec {
                seed: 7,
                nan_prob: 1.0,
                armed_after: 1,
                ..FaultSpec::default()
            },
        );
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(100, 1));
        let solver = kind.build::<f64>(SolverConfig::with_criterion(
            Criterion::residual(1e-10, 200),
        ));
        let r = solver.solve(&faulty, &b, &mut x).unwrap();
        assert!(!r.converged, "{}: converged on NaN data: {r:?}", kind.name());
        assert!(
            r.breakdown().is_some(),
            "{}: no structured breakdown, status {:?} after {} iters",
            kind.name(),
            r.status,
            r.iterations
        );
        // detection must fire promptly, not ride out the whole budget
        assert!(r.iterations < 200, "{}: spun to max_iters", kind.name());
    }
}

/// Transient apply failures must come back as structured errors from
/// every driver — propagated, not panicked on.
#[test]
fn every_driver_propagates_transient_errors() {
    let (data, bv) = spd_system(103, 80);
    let exec = Executor::reference();
    for kind in ALL_KINDS {
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let faulty = FaultyOp::new(
            a,
            FaultSpec {
                seed: 9,
                transient_prob: 1.0,
                ..FaultSpec::default()
            },
        );
        let b = Dense::vector(exec.clone(), &bv);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(80, 1));
        let solver = kind.build::<f64>(SolverConfig::with_criterion(
            Criterion::residual(1e-10, 200),
        ));
        let err = solver.solve(&faulty, &b, &mut x).unwrap_err();
        assert!(
            err.to_string().contains("injected transient"),
            "{}: unexpected error {err}",
            kind.name()
        );
    }
}

/// Bit-flips are the nasty case: the iterate stays finite, the
/// recurrence keeps "converging" — only the true-residual check at the
/// checkpoint boundary can catch the corruption. The resilient wrapper
/// must converge anyway, verified against the clean operator.
#[test]
fn resilient_solver_recovers_from_bitflips() {
    let data = stencil::laplace_2d::<f64>(10, 10);
    let exec = Executor::reference();
    let clean = Csr::from_data(exec.clone(), &data).unwrap();
    let a = Csr::from_data(exec.clone(), &data).unwrap();
    let faulty = FaultyOp::new(
        a,
        FaultSpec {
            seed: 11,
            bitflip_prob: 0.10,
            max_faults: 3,
            armed_after: 2,
            ..FaultSpec::default()
        },
    );
    let b = Dense::filled(exec.clone(), Dim2::new(100, 1), 1.0);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(100, 1));
    let solver = ResilientSolver::new(Criterion::residual(1e-8, 5000)).with_policy(
        RecoveryPolicy {
            checkpoint_every: 20,
            ..RecoveryPolicy::default()
        },
    );
    let out = solver.solve_outcome(&faulty, &b, &mut x).unwrap();
    assert!(out.result.converged, "{out:?}");
    assert!(!faulty.faults().is_empty(), "no fault ever fired");
    let res = clean_residual(&clean, &b, &x);
    assert!(
        res <= 1e-8 * b.norm2_host() * 10.0,
        "silent wrong answer: clean residual {res:.3e}"
    );
}

/// NaN payloads mid-solve: detection aborts the segment, rollback +
/// restart carries the solve to convergence once the fault budget is
/// spent.
#[test]
fn resilient_solver_recovers_from_nan_payloads() {
    let (data, bv) = spd_system(107, 150);
    let exec = Executor::reference();
    let clean = Csr::from_data(exec.clone(), &data).unwrap();
    let a = Csr::from_data(exec.clone(), &data).unwrap();
    let faulty = FaultyOp::new(
        a,
        FaultSpec {
            seed: 13,
            nan_prob: 0.05,
            max_faults: 3,
            armed_after: 5,
            ..FaultSpec::default()
        },
    );
    let b = Dense::vector(exec.clone(), &bv);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(150, 1));
    let solver = ResilientSolver::new(Criterion::residual(1e-8, 5000)).with_policy(
        RecoveryPolicy {
            checkpoint_every: 25,
            ..RecoveryPolicy::default()
        },
    );
    let out = solver.solve_outcome(&faulty, &b, &mut x).unwrap();
    assert!(out.result.converged, "{out:?}");
    let res = clean_residual(&clean, &b, &x);
    assert!(
        res <= 1e-8 * b.norm2_host() * 10.0,
        "silent wrong answer: clean residual {res:.3e}"
    );
}

/// Transient faults during a solve roll back to the checkpoint and
/// retry; the solve still converges and the event log records the
/// recovery.
#[test]
fn resilient_solver_recovers_from_transients() {
    let (data, bv) = spd_system(109, 120);
    let exec = Executor::reference();
    let clean = Csr::from_data(exec.clone(), &data).unwrap();
    let a = Csr::from_data(exec.clone(), &data).unwrap();
    let faulty = FaultyOp::new(
        a,
        FaultSpec {
            seed: 17,
            transient_prob: 0.08,
            max_faults: 4,
            armed_after: 2,
            ..FaultSpec::default()
        },
    );
    let b = Dense::vector(exec.clone(), &bv);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(120, 1));
    let solver = ResilientSolver::new(Criterion::residual(1e-8, 5000)).with_policy(
        RecoveryPolicy {
            checkpoint_every: 15,
            ..RecoveryPolicy::default()
        },
    );
    let out = solver.solve_outcome(&faulty, &b, &mut x).unwrap();
    assert!(out.result.converged, "{out:?}");
    assert!(!faulty.faults().is_empty(), "no fault ever fired");
    let res = clean_residual(&clean, &b, &x);
    assert!(res <= 1e-8 * b.norm2_host() * 10.0);
}

/// When every apply is poisoned, recovery is impossible — the `Solver`
/// facade must return the structured breakdown error, never a silent
/// non-answer.
#[test]
fn unrecoverable_corruption_is_a_structured_error() {
    let (data, bv) = spd_system(113, 60);
    let exec = Executor::reference();
    let a = Csr::from_data(exec.clone(), &data).unwrap();
    let faulty = FaultyOp::new(
        a,
        FaultSpec {
            seed: 19,
            nan_prob: 1.0,
            ..FaultSpec::default()
        },
    );
    let b = Dense::vector(exec.clone(), &bv);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(60, 1));
    let solver = ResilientSolver::new(Criterion::residual(1e-8, 300));
    let err = Solver::<f64>::solve(&solver, &faulty, &b, &mut x).unwrap_err();
    assert!(
        matches!(err, SparkleError::Breakdown { solver: "resilient", .. }),
        "expected structured breakdown, got {err}"
    );
}

/// The acceptance sweep: the matgen suite under mixed injected faults.
/// Every outcome must be either a convergence that the clean operator
/// confirms, or a structured breakdown/budget status. Zero panics,
/// zero silent wrong answers.
#[test]
fn matgen_suite_under_mixed_faults_has_no_silent_wrong_answers() {
    let exec = Executor::reference();
    let suite: Vec<(&str, MatrixData<f64>)> = vec![
        ("laplace_2d", stencil::laplace_2d::<f64>(12, 12)),
        ("stencil_3d", stencil::stencil_3d::<f64>(6, 6, 6, 0.0)),
        ("random_spd", spd_system(211, 140).0),
    ];
    for (name, data) in &suite {
        let n = data.dim.rows;
        let clean = Csr::from_data(exec.clone(), data).unwrap();
        for seed in [1u64, 2, 3] {
            let a = Csr::from_data(exec.clone(), data).unwrap();
            let faulty = FaultyOp::new(
                a,
                FaultSpec {
                    seed,
                    nan_prob: 0.02,
                    bitflip_prob: 0.02,
                    transient_prob: 0.02,
                    max_faults: 4,
                    armed_after: 3,
                },
            );
            let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
            let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            let solver = ResilientSolver::new(Criterion::residual(1e-8, 4000))
                .with_policy(RecoveryPolicy {
                    checkpoint_every: 25,
                    ..RecoveryPolicy::default()
                });
            let out = solver.solve_outcome(&faulty, &b, &mut x).unwrap();
            if out.result.converged {
                let res = clean_residual(&clean, &b, &x);
                assert!(
                    res <= 1e-8 * b.norm2_host() * 10.0,
                    "{name} seed {seed}: silent wrong answer, clean residual {res:.3e}"
                );
            } else {
                assert!(
                    matches!(
                        out.result.status,
                        StopStatus::Diverged(_) | StopStatus::BudgetExhausted
                    ),
                    "{name} seed {seed}: unstructured failure {:?}",
                    out.result.status
                );
            }
        }
    }
}

/// Backend degradation: once the xla runtime's circuit breaker opens,
/// BLAS and SpMV dispatch must route to the host `par` kernels and
/// agree with the reference executor — the library keeps serving.
#[test]
fn degraded_xla_runtime_falls_back_to_host_kernels() {
    // empty manifest: every xla dispatch fails while the breaker is
    // closed (exactly the pre-existing failure-path contract) …
    let exec = Executor::xla("/nonexistent_artifacts_dir").unwrap();
    let reference = Executor::reference();
    let (data, bv) = spd_system(301, 50);

    let a = Csr::from_data(exec.clone(), &data).unwrap();
    let b = Dense::vector(exec.clone(), &bv);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(50, 1));
    assert!(a.apply(&b, &mut x).is_err(), "breaker closed: must error");
    let mut y = Dense::vector(exec.clone(), &bv);
    assert!(blas::axpy(&exec, 2.0, &b, &mut y).is_err());

    // … until the breaker opens: same calls now run on the host path
    let runtime = exec.xla_runtime().unwrap();
    runtime.breaker().trip();
    assert!(runtime.degraded());

    a.apply(&b, &mut x).unwrap();
    let ar = Csr::from_data(reference.clone(), &data).unwrap();
    let br = Dense::vector(reference.clone(), &bv);
    let mut xr = Dense::zeros(reference.clone(), Dim2::new(50, 1));
    ar.apply(&br, &mut xr).unwrap();
    for (got, want) in x.as_slice().iter().zip(xr.as_slice()) {
        assert!((got - want).abs() <= 1e-13 * want.abs().max(1.0));
    }

    let mut y = Dense::vector(exec.clone(), &bv);
    let mut x2 = Dense::zeros(exec.clone(), Dim2::new(50, 1));
    blas::axpy(&exec, 2.0, &b, &mut y).unwrap();
    blas::scal(&exec, 0.5, &mut y).unwrap();
    let d = blas::dot(&exec, &y, &b).unwrap();
    assert!(d.is_finite());
    // a whole solve runs end-to-end on the degraded executor
    let solver = SolverKind::Cg.build::<f64>(SolverConfig::with_criterion(
        Criterion::residual(1e-8, 500),
    ));
    let r = solver.solve(&a, &b, &mut x2).unwrap();
    assert!(r.converged, "degraded-mode CG: {r:?}");

    // operator override: reset closes the breaker, xla errors return
    runtime.breaker().reset();
    assert!(!runtime.degraded());
    assert!(a.apply(&b, &mut x).is_err());
}

/// A stagnating iteration (Richardson that makes no progress) must be
/// cut short by the stagnation window, not ride out the whole budget.
#[test]
fn stagnation_window_cuts_hopeless_iteration_short() {
    let (data, bv) = spd_system(401, 80);
    let exec = Executor::reference();
    let a = Csr::from_data(exec.clone(), &data).unwrap();
    let b = Dense::vector(exec.clone(), &bv);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(80, 1));
    let mut cfg = SolverConfig::with_criterion(Criterion::residual(1e-12, 10_000));
    cfg.breakdown.stagnation_window = 20;
    // omega = 0: the iterate never moves, the residual never improves
    let solver = SolverKind::Richardson { omega: 0.0 }.build::<f64>(cfg);
    let r = solver.solve(&a, &b, &mut x).unwrap();
    assert!(!r.converged);
    assert!(
        matches!(
            r.breakdown(),
            Some(sparkle::stop::Breakdown::Stagnation { .. })
        ),
        "expected stagnation, got {:?}",
        r.status
    );
    assert!(r.iterations <= 50, "stagnated solve ran {} iters", r.iterations);
}
