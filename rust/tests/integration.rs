//! Cross-module integration tests: generators → formats → solvers →
//! verification, across executors; MatrixMarket round trips; suite
//! coverage. (XLA-executor specifics live in `xla_backend.rs`.)

use std::sync::Arc;

use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::matgen::{suite, MatrixStats};
use sparkle::matrix::conversion::{self, FromData};
use sparkle::matrix::{Coo, Csr, Dense, Ell, Hybrid, SellP};
use sparkle::precond::Jacobi;
use sparkle::solver::{BiCgStab, Cg, Fcg, Gmres, Solver, SolverConfig};
use sparkle::stop::Criterion;
use sparkle::testing::prng::Prng;
use sparkle::testing::prop::{assert_close, for_all, gen_sparse, gen_vec};
use sparkle::{Dim2, MatrixData};

// ------------------------------------------------------------- solvers

/// Every solver solves every (appropriately conditioned) Table-1 analog
/// on both host executors and the solutions agree across executors.
#[test]
fn all_solvers_on_suite_matrices() {
    let scale = 2048; // small but structurally faithful analogs
    // SPD-ish entries for CG/FCG; all are diagonally dominant, so the
    // unsymmetric solvers handle every entry
    for entry in suite::table1() {
        let data = entry.generate::<f64>(scale);
        let n = data.dim.rows;
        let exec = Executor::par_with_threads(2);
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
        let crit = Criterion::residual(1e-7, 3000);
        let solvers: Vec<(&str, Box<dyn Solver<f64>>)> = vec![
            ("bicgstab", Box::new(BiCgStab::new(SolverConfig::with_criterion(crit.clone())))),
            ("gmres", Box::new(Gmres::new(SolverConfig::with_criterion(crit.clone())))),
        ];
        for (name, solver) in solvers {
            let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            let r = solver.solve(&a, &b, &mut x).unwrap();
            assert!(
                r.converged,
                "{name} failed on {} (n={n}): {r:?}",
                entry.name
            );
            // verify the true residual
            let mut resid = b.clone();
            a.apply_advanced(-1.0, &x, 1.0, &mut resid).unwrap();
            let rel = resid.norm2_host() / b.norm2_host();
            assert!(rel < 1e-5, "{name} on {}: true residual {rel}", entry.name);
        }
    }
}

/// CG/FCG on symmetrized systems: identical solutions across executors.
#[test]
fn symmetric_solvers_cross_executor_agreement() {
    let mut rng = Prng::new(404);
    let n = 300;
    let mut data = gen_sparse::<f64>(&mut rng, n, n, 4);
    data.symmetrize();
    data.shift_diagonal(1.0);
    let bv = gen_vec::<f64>(&mut rng, n);
    let crit = Criterion::residual(1e-11, 600);

    let mut solutions: Vec<Vec<f64>> = Vec::new();
    for exec in [Executor::reference(), Executor::par_with_threads(4)] {
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        for solver in [
            Box::new(Cg::new(SolverConfig::with_criterion(crit.clone()))) as Box<dyn Solver<f64>>,
            Box::new(Fcg::new(SolverConfig::with_criterion(crit.clone()))),
        ] {
            let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            let r = solver.solve(&a, &b, &mut x).unwrap();
            assert!(r.converged, "{} on {}", solver.name(), exec.name());
            solutions.push(x.as_slice().to_vec());
        }
    }
    for s in &solutions[1..] {
        assert_close(s, &solutions[0], 1e-6, "cross-executor solution");
    }
}

/// The solver works through *any* format's LinOp (same operator, four
/// storage layouts, same solution).
#[test]
fn solver_format_independence() {
    let mut rng = Prng::new(405);
    let n = 200;
    let mut data = gen_sparse::<f64>(&mut rng, n, n, 4);
    data.symmetrize();
    data.shift_diagonal(1.0);
    let exec = Executor::reference();
    let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
    let crit = Criterion::residual(1e-10, 500);
    let mut first: Option<Vec<f64>> = None;
    let ops: Vec<Box<dyn LinOp<f64>>> = vec![
        Box::new(Csr::from_data(exec.clone(), &data).unwrap()),
        Box::new(Coo::from_data(exec.clone(), &data).unwrap()),
        Box::new(Ell::from_data(exec.clone(), &data).unwrap()),
        Box::new(SellP::from_data(exec.clone(), &data).unwrap()),
        Box::new(Hybrid::from_data(exec.clone(), &data).unwrap()),
    ];
    for op in &ops {
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let r = Cg::new(SolverConfig::with_criterion(crit.clone()))
            .solve(op.as_ref(), &b, &mut x)
            .unwrap();
        assert!(r.converged, "format {}", op.op_name());
        match &first {
            None => first = Some(x.as_slice().to_vec()),
            Some(f) => assert_close(x.as_slice(), f, 1e-8, op.op_name()),
        }
    }
}

/// Preconditioned CG through the full stack on a generated FEM problem.
#[test]
fn jacobi_pcg_on_fem() {
    let data = sparkle::matgen::fem::fem::<f64>(400, 6, 1, 9);
    let n = data.dim.rows;
    let exec = Executor::par_with_threads(2);
    let a = Csr::from_data(exec.clone(), &data).unwrap();
    let jacobi = Jacobi::from_csr(&a).unwrap();
    let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let r = Cg::new(SolverConfig::with_criterion(Criterion::residual(1e-9, 1000)))
        .with_preconditioner(Arc::new(jacobi))
        .solve(&a, &b, &mut x)
        .unwrap();
    assert!(r.converged, "{r:?}");
}

// ----------------------------------------------------- conversions / io

/// Property: every format round-trips any random matrix through
/// MatrixData without changing its dense image.
#[test]
fn prop_format_round_trips() {
    for_all(0xC0FFEE, 10, |rng, _| {
        let n = 20 + rng.below(60);
        let data = gen_sparse::<f64>(rng, n, n, 4);
        let expect = data.to_dense_vec();
        let exec = Executor::reference();
        macro_rules! check {
            ($ty:ident) => {
                let m = $ty::from_data_on(exec.clone(), &data).unwrap();
                let back = conversion::ToData::<f64>::to_data_generic(&m);
                assert_eq!(back.to_dense_vec(), expect, stringify!($ty));
            };
        }
        check!(Csr);
        check!(Coo);
        check!(Ell);
        check!(SellP);
        check!(Hybrid);
    });
}

/// Property: SpMV agrees across formats and executors on random input.
#[test]
fn prop_spmv_format_executor_agreement() {
    for_all(0xBEEF, 8, |rng, _| {
        let n = 30 + rng.below(120);
        let data = gen_sparse::<f64>(rng, n, n, 5);
        let bv = gen_vec::<f64>(rng, n);
        let reference = Executor::reference();
        let csr = Csr::from_data(reference.clone(), &data).unwrap();
        let b = Dense::vector(reference.clone(), &bv);
        let mut expect = Dense::zeros(reference.clone(), Dim2::new(n, 1));
        csr.apply(&b, &mut expect).unwrap();
        for exec in [Executor::reference(), Executor::par_with_threads(3)] {
            let b = Dense::vector(exec.clone(), &bv);
            let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            let ops: Vec<Box<dyn LinOp<f64>>> = vec![
                Box::new(Csr::from_data(exec.clone(), &data).unwrap()),
                Box::new(Coo::from_data(exec.clone(), &data).unwrap()),
                Box::new(Ell::from_data(exec.clone(), &data).unwrap()),
                Box::new(SellP::from_data(exec.clone(), &data).unwrap()),
                Box::new(sparkle::vendor_mkl::VendorCsr::new(
                    Csr::from_data(exec.clone(), &data).unwrap(),
                )),
            ];
            for op in ops {
                op.apply(&b, &mut x).unwrap();
                assert_close(
                    x.as_slice(),
                    expect.as_slice(),
                    1e-11,
                    &format!("{} on {}", op.op_name(), exec.name()),
                );
            }
        }
    });
}

/// MatrixMarket round trip through a real file + reload into another
/// format (the CLI's `gen --out` path).
#[test]
fn mtx_file_round_trip_through_formats() {
    let data = suite::table1_entry("thermal2")
        .unwrap()
        .generate::<f64>(4096);
    let dir = std::env::temp_dir().join("sparkle_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("thermal2_scaled.mtx");
    sparkle::io::write_matrix_market(&path, &data).unwrap();
    let back: MatrixData<f64> = sparkle::io::read_matrix_market(&path).unwrap();
    assert_eq!(back.dim, data.dim);
    assert_eq!(back.nnz(), data.nnz());
    // SpMV equality through the reloaded matrix
    let exec = Executor::reference();
    let a1 = Csr::from_data(exec.clone(), &data).unwrap();
    let a2 = Csr::from_data(exec.clone(), &back).unwrap();
    let b = Dense::filled(exec.clone(), Dim2::new(data.dim.rows, 1), 1.0);
    let mut x1 = Dense::zeros(exec.clone(), Dim2::new(data.dim.rows, 1));
    let mut x2 = x1.clone();
    a1.apply(&b, &mut x1).unwrap();
    a2.apply(&b, &mut x2).unwrap();
    assert_close(x1.as_slice(), x2.as_slice(), 1e-12, "mtx round trip");
    std::fs::remove_file(path).ok();
}

// ------------------------------------------------------------ matgen

/// Structure statistics drive the perf model: verify stats are stable
/// across scales for each generator class (density and irregularity are
/// scale-invariants of the generator).
#[test]
fn generator_stats_scale_invariant() {
    for entry in suite::table1() {
        let small = MatrixStats::from_data(&entry.generate::<f64>(4096));
        let large = MatrixStats::from_data(&entry.generate::<f64>(512));
        let density_ratio = small.avg_row / large.avg_row;
        assert!(
            (0.4..2.5).contains(&density_ratio),
            "{}: density drifts with scale ({:.2} vs {:.2})",
            entry.name,
            small.avg_row,
            large.avg_row
        );
    }
}

/// Failure injection: malformed inputs surface as errors, not panics.
#[test]
fn failure_paths_are_errors() {
    let exec = Executor::reference();
    // dimension mismatch in apply
    let data = gen_sparse::<f64>(&mut Prng::new(1), 10, 10, 2);
    let a = Csr::from_data(exec.clone(), &data).unwrap();
    let b = Dense::filled(exec.clone(), Dim2::new(7, 1), 1.0);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(10, 1));
    assert!(a.apply(&b, &mut x).is_err());
    // singular Jacobi
    let mut d = MatrixData::<f64>::new(Dim2::square(2));
    d.push(0, 1, 1.0);
    d.push(1, 0, 1.0);
    let sing = Csr::from_data(exec.clone(), &d).unwrap();
    assert!(Jacobi::from_csr(&sing).is_err());
    // unknown mtx
    assert!(sparkle::io::read_matrix_market::<f64>("/definitely/not/here.mtx").is_err());
    // xla executor without artifacts
    assert!(Executor::xla("/nonexistent_artifacts_dir").is_ok()); // dir missing -> empty manifest
    let e = Executor::xla("/nonexistent_artifacts_dir").unwrap();
    let a2 = Csr::from_data(e.clone(), &data).unwrap();
    let b2 = Dense::filled(e.clone(), Dim2::new(10, 1), 1.0);
    let mut x2 = Dense::zeros(e.clone(), Dim2::new(10, 1));
    assert!(a2.apply(&b2, &mut x2).is_err(), "missing artifacts must error");
}
