//! Fused-kernel equivalence and workspace-reuse properties.
//!
//! The fused host kernels (PR "fused Krylov kernels") are designed to be
//! *bit-identical* to the composed BLAS-1/SpMV sequences they replace on
//! each executor: same elementary operations in the same order. These
//! tests state that as a property over random inputs for every format
//! and both precisions, and verify the solver workspace performs zero
//! pool misses (= zero Dense allocations) after warm-up.

use std::sync::{Arc, Mutex};

use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::kernels::{blas, set_fused_enabled};
use sparkle::matrix::{Coo, Csr, Dense, Ell, Hybrid, SellP};
use sparkle::solver::{workspace as ws, BiCgStab, Cg, Gmres, Solver, SolverConfig};
use sparkle::stop::Criterion;
use sparkle::testing::prng::Prng;
use sparkle::testing::prop::{assert_close, for_all, gen_sparse, gen_vec};
use sparkle::{Dim2, MatrixData, Value};

/// Tests that toggle the global fused switch serialize on this lock and
/// restore the default before releasing it.
static FUSED_LOCK: Mutex<()> = Mutex::new(());

fn lock_fused() -> std::sync::MutexGuard<'static, ()> {
    FUSED_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn executors() -> Vec<Arc<Executor>> {
    vec![
        Executor::reference(),
        Executor::par_with_threads(1),
        Executor::par_with_threads(4),
    ]
}

fn vecs<T: Value>(rng: &mut Prng, exec: &Arc<Executor>, n: usize, k: usize) -> Vec<Dense<T>> {
    (0..k)
        .map(|_| Dense::vector(exec.clone(), &gen_vec::<T>(rng, n)))
        .collect()
}

/// Every fused BLAS-1 primitive matches the composed sequence through
/// the same public dispatch, bit for bit, on every host executor.
fn blas1_fused_vs_composed<T: Value>(seed: u64) {
    let _g = lock_fused();
    for_all(seed, 8, |rng, case| {
        let n = 1 + rng.below(9000);
        for exec in executors() {
            let vs = vecs::<T>(rng, &exec, n, 6);
            let (p, q, s, t, v, z) = (&vs[0], &vs[1], &vs[2], &vs[3], &vs[4], &vs[5]);
            let alpha = T::from_f64(rng.uniform(-2.0, 2.0));
            let beta = T::from_f64(rng.uniform(-2.0, 2.0));
            let omega = T::from_f64(rng.uniform(-2.0, 2.0));
            let what = format!("case {case} n={n} exec={}", exec.name());

            // dot_norm2
            set_fused_enabled(true);
            let (xy_f, yy_f) = blas::dot_norm2(&exec, p, q).unwrap();
            set_fused_enabled(false);
            let (xy_c, yy_c) = blas::dot_norm2(&exec, p, q).unwrap();
            assert_eq!((xy_f, yy_f), (xy_c, yy_c), "dot_norm2 {what}");

            // axpy_sub_norm2
            let (mut xf, mut rf) = (s.clone(), t.clone());
            let (mut xc, mut rc) = (s.clone(), t.clone());
            set_fused_enabled(true);
            let rr_f = blas::axpy_sub_norm2(&exec, alpha, p, q, &mut xf, &mut rf).unwrap();
            set_fused_enabled(false);
            let rr_c = blas::axpy_sub_norm2(&exec, alpha, p, q, &mut xc, &mut rc).unwrap();
            assert_eq!(rr_f, rr_c, "axpy_sub_norm2 scalar {what}");
            assert_eq!(xf.as_slice(), xc.as_slice(), "axpy_sub_norm2 x {what}");
            assert_eq!(rf.as_slice(), rc.as_slice(), "axpy_sub_norm2 r {what}");

            // add_scaled
            let mut of = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            let mut oc = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            set_fused_enabled(true);
            blas::add_scaled(&exec, z, alpha, v, &mut of).unwrap();
            set_fused_enabled(false);
            blas::add_scaled(&exec, z, alpha, v, &mut oc).unwrap();
            assert_eq!(of.as_slice(), oc.as_slice(), "add_scaled {what}");

            // update_p (both beta != 0 and the beta == 0 overwrite path)
            for b in [beta, T::zero()] {
                let mut pf = s.clone();
                let mut pc = s.clone();
                set_fused_enabled(true);
                blas::update_p(&exec, p, b, omega, v, &mut pf).unwrap();
                set_fused_enabled(false);
                blas::update_p(&exec, p, b, omega, v, &mut pc).unwrap();
                assert_eq!(pf.as_slice(), pc.as_slice(), "update_p {what}");

                let mut pf = s.clone();
                let mut pc = s.clone();
                set_fused_enabled(true);
                blas::update_p_cgs(&exec, p, b, q, &mut pf).unwrap();
                set_fused_enabled(false);
                blas::update_p_cgs(&exec, p, b, q, &mut pc).unwrap();
                assert_eq!(pf.as_slice(), pc.as_slice(), "update_p_cgs {what}");
            }

            // sub_scaled_norm2
            let mut rf = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            let mut rc = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            set_fused_enabled(true);
            let rr_f = blas::sub_scaled_norm2(&exec, s, omega, t, &mut rf).unwrap();
            set_fused_enabled(false);
            let rr_c = blas::sub_scaled_norm2(&exec, s, omega, t, &mut rc).unwrap();
            assert_eq!(rr_f, rr_c, "sub_scaled_norm2 scalar {what}");
            assert_eq!(rf.as_slice(), rc.as_slice(), "sub_scaled_norm2 r {what}");

            // axpy2
            let mut xf = z.clone();
            let mut xc = z.clone();
            set_fused_enabled(true);
            blas::axpy2(&exec, alpha, p, omega, s, &mut xf).unwrap();
            set_fused_enabled(false);
            blas::axpy2(&exec, alpha, p, omega, s, &mut xc).unwrap();
            assert_eq!(xf.as_slice(), xc.as_slice(), "axpy2 {what}");

            // scal_into (both scales and the beta == 0 zero-fill path)
            for b in [beta, T::zero()] {
                let mut of = t.clone();
                let mut oc = t.clone();
                set_fused_enabled(true);
                blas::scal_into(&exec, b, p, &mut of).unwrap();
                set_fused_enabled(false);
                blas::scal_into(&exec, b, p, &mut oc).unwrap();
                assert_eq!(of.as_slice(), oc.as_slice(), "scal_into {what}");
            }
        }
    });
    set_fused_enabled(true);
}

#[test]
fn blas1_fused_matches_composed_f64() {
    blas1_fused_vs_composed::<f64>(0xB1A5);
}

#[test]
fn blas1_fused_matches_composed_f32() {
    blas1_fused_vs_composed::<f32>(0xB1A6);
}

/// The batched MGS kernels (`dot_axpy`, `mgs_project`, `mgs_update`)
/// match the composed dot/axpy chain through the same public dispatch,
/// bit for bit, on every host executor and both precisions.
fn mgs_fused_vs_composed<T: Value>(seed: u64) {
    let _g = lock_fused();
    for_all(seed, 6, |rng, case| {
        let n = 1 + rng.below(6000);
        let k = 1 + rng.below(6);
        for exec in executors() {
            let basis_v = vecs::<T>(rng, &exec, n, k);
            let vrefs: Vec<&Dense<T>> = basis_v.iter().collect();
            let w0 = Dense::vector(exec.clone(), &gen_vec::<T>(rng, n));
            let x0 = Dense::vector(exec.clone(), &gen_vec::<T>(rng, n));
            let what = format!("case {case} n={n} k={k} exec={}", exec.name());

            // dot_axpy: coefficient and updated w both bitwise equal
            let mut wf = w0.clone();
            let mut wc = w0.clone();
            set_fused_enabled(true);
            let hf = blas::dot_axpy(&exec, vrefs[0], &mut wf).unwrap();
            set_fused_enabled(false);
            let hc = blas::dot_axpy(&exec, vrefs[0], &mut wc).unwrap();
            assert_eq!(hf, hc, "dot_axpy h {what}");
            assert_eq!(wf.as_slice(), wc.as_slice(), "dot_axpy w {what}");

            // mgs_project: coefficients, remainder and ‖w‖² all match
            let mut wf = w0.clone();
            let mut wc = w0.clone();
            let mut hfv = vec![T::zero(); k];
            let mut hcv = vec![T::zero(); k];
            set_fused_enabled(true);
            let wwf = blas::mgs_project(&exec, &vrefs, &mut wf, &mut hfv).unwrap();
            set_fused_enabled(false);
            let wwc = blas::mgs_project(&exec, &vrefs, &mut wc, &mut hcv).unwrap();
            assert_eq!(wwf, wwc, "mgs_project ww {what}");
            assert_eq!(hfv, hcv, "mgs_project h {what}");
            assert_eq!(wf.as_slice(), wc.as_slice(), "mgs_project w {what}");

            // mgs_update: folded solution bitwise equal
            let y: Vec<T> = (0..k).map(|_| T::from_f64(rng.uniform(-2.0, 2.0))).collect();
            let mut xf = x0.clone();
            let mut xc = x0.clone();
            set_fused_enabled(true);
            blas::mgs_update(&exec, &vrefs, &y, &mut xf).unwrap();
            set_fused_enabled(false);
            blas::mgs_update(&exec, &vrefs, &y, &mut xc).unwrap();
            assert_eq!(xf.as_slice(), xc.as_slice(), "mgs_update {what}");
        }
    });
    set_fused_enabled(true);
}

#[test]
fn mgs_fused_matches_composed_f64() {
    mgs_fused_vs_composed::<f64>(0x3650);
}

#[test]
fn mgs_fused_matches_composed_f32() {
    mgs_fused_vs_composed::<f32>(0x3651);
}

/// A full restarted GMRES solve — restarts exercise `mgs_update` at the
/// restart boundary and `mgs_project` at every basis size — is invariant
/// under the fused toggle on every host executor: same iteration count,
/// same residual, bitwise-identical solution.
#[test]
fn gmres_restarted_identical_fused_vs_composed() {
    let _g = lock_fused();
    let n = 150;
    let mut rng = Prng::new(53);
    let data = gen_sparse::<f64>(&mut rng, n, n, 4);
    let bv = gen_vec::<f64>(&mut rng, n);
    for exec in executors() {
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::vector(exec.clone(), &bv);
        let solver = Gmres::new(SolverConfig::with_criterion(Criterion::residual(1e-8, 2000)))
            .with_restart(10);

        set_fused_enabled(true);
        let mut xf = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let rf = solver.solve(&a, &b, &mut xf).unwrap();

        set_fused_enabled(false);
        let mut xc = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let rc = solver.solve(&a, &b, &mut xc).unwrap();

        let what = format!("gmres(10) on {}", exec.name());
        assert_eq!(rf.iterations, rc.iterations, "iterations {what}");
        assert_eq!(rf.resnorm, rc.resnorm, "resnorm {what}");
        assert_eq!(xf.as_slice(), xc.as_slice(), "solution {what}");
        assert!(rf.converged, "did not converge: {what}");
    }
    set_fused_enabled(true);
}

/// `apply_dot` (fused SpMV + dot) matches apply-then-dot for every
/// format on every host executor, bit for bit.
fn apply_dot_all_formats<T: Value>(seed: u64) {
    let _g = lock_fused();
    for_all(seed, 6, |rng, case| {
        let n = 8 + rng.below(300);
        let data = gen_sparse::<T>(rng, n, n, 5);
        let bv = gen_vec::<T>(rng, n);
        let wv = gen_vec::<T>(rng, n);
        for exec in executors() {
            let b = Dense::vector(exec.clone(), &bv);
            let w = Dense::vector(exec.clone(), &wv);
            let ops: Vec<(&str, Box<dyn LinOp<T>>)> = vec![
                ("csr", Box::new(Csr::from_data(exec.clone(), &data).unwrap())),
                ("coo", Box::new(Coo::from_data(exec.clone(), &data).unwrap())),
                ("ell", Box::new(Ell::from_data(exec.clone(), &data).unwrap())),
                ("sellp", Box::new(SellP::from_data(exec.clone(), &data).unwrap())),
                ("hybrid", Box::new(Hybrid::from_data(exec.clone(), &data).unwrap())),
            ];
            for (name, a) in &ops {
                let what = format!("case {case} {name} n={n} exec={}", exec.name());
                // composed oracle: plain apply + two plain dots
                set_fused_enabled(false);
                let mut xc = Dense::zeros(exec.clone(), Dim2::new(n, 1));
                a.apply(&b, &mut xc).unwrap();
                let wx_c = blas::dot(&exec, &w, &xc).unwrap();
                let xx_c = blas::dot(&exec, &xc, &xc).unwrap();
                // fused path through the LinOp hook
                set_fused_enabled(true);
                let mut xf = Dense::zeros(exec.clone(), Dim2::new(n, 1));
                let (wx_f, xx_f) = a.apply_dot(&b, &mut xf, &w).unwrap();
                assert_eq!(xf.as_slice(), xc.as_slice(), "apply_dot x {what}");
                assert_eq!(wx_f, wx_c, "apply_dot w·x {what}");
                assert_eq!(xx_f, xx_c, "apply_dot ‖x‖² {what}");
            }
        }
    });
    set_fused_enabled(true);
}

#[test]
fn apply_dot_matches_composed_f64() {
    apply_dot_all_formats::<f64>(0x5D07);
}

#[test]
fn apply_dot_matches_composed_f32() {
    apply_dot_all_formats::<f32>(0x5D08);
}

fn spd_system(seed: u64, n: usize) -> (MatrixData<f64>, Vec<f64>) {
    let mut rng = Prng::new(seed);
    let mut data = gen_sparse::<f64>(&mut rng, n, n, 3);
    data.symmetrize();
    data.shift_diagonal(1.0);
    let b = gen_vec::<f64>(&mut rng, n);
    (data, b)
}

/// Whole solves give the identical iterate whether the fused kernels
/// are dispatched or the composed fallback runs — the drivers are
/// numerically invariant under the toggle.
#[test]
fn solvers_identical_fused_vs_composed() {
    let _g = lock_fused();
    let n = 200;
    let (spd, bv) = spd_system(0xCafe, n);
    let mut rng = Prng::new(0xFace);
    let gen_data = gen_sparse::<f64>(&mut rng, n, n, 4);
    let crit = Criterion::residual(1e-9, 400);

    for exec in executors() {
        let solvers: Vec<(Box<dyn Solver<f64>>, &MatrixData<f64>)> = vec![
            (
                Box::new(Cg::<f64>::new(SolverConfig::with_criterion(crit.clone()))),
                &spd,
            ),
            (
                Box::new(BiCgStab::new(SolverConfig::with_criterion(crit.clone()))),
                &gen_data,
            ),
            (
                Box::new(Gmres::new(SolverConfig::with_criterion(crit.clone()))),
                &gen_data,
            ),
        ];
        for (solver, data) in solvers {
            let a = Csr::from_data(exec.clone(), data).unwrap();
            let b = Dense::vector(exec.clone(), &bv);

            set_fused_enabled(true);
            let mut xf = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            let rf = solver.solve(&a, &b, &mut xf).unwrap();

            set_fused_enabled(false);
            let mut xc = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            let rc = solver.solve(&a, &b, &mut xc).unwrap();

            let what = format!("{} on {}", solver.name(), exec.name());
            assert_eq!(rf.iterations, rc.iterations, "iterations {what}");
            assert_eq!(rf.resnorm, rc.resnorm, "resnorm {what}");
            assert_eq!(xf.as_slice(), xc.as_slice(), "solution {what}");
            assert!(rf.converged, "did not converge: {what}");
        }
    }
    set_fused_enabled(true);
}

/// Preconditioned CG goes through the z-materialized path; it must
/// still converge and match across the toggle.
#[test]
fn preconditioned_cg_fused_vs_composed() {
    let _g = lock_fused();
    let n = 150;
    let (data, bv) = spd_system(0xBead, n);
    let exec = Executor::reference();
    let a = Csr::from_data(exec.clone(), &data).unwrap();
    let b = Dense::vector(exec.clone(), &bv);
    let jacobi = Arc::new(sparkle::precond::Jacobi::from_csr(&a).unwrap());
    let solver = Cg::new(SolverConfig::with_criterion(Criterion::residual(1e-10, 500)))
        .with_preconditioner(jacobi);

    set_fused_enabled(true);
    let mut xf = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let rf = solver.solve(&a, &b, &mut xf).unwrap();
    set_fused_enabled(false);
    let mut xc = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let rc = solver.solve(&a, &b, &mut xc).unwrap();
    set_fused_enabled(true);

    assert!(rf.converged && rc.converged);
    assert_eq!(rf.iterations, rc.iterations);
    assert_eq!(xf.as_slice(), xc.as_slice());
}

/// After a warm-up solve, repeated solves of the same shape perform
/// zero workspace misses — i.e. zero Dense allocations per solve.
/// The pool is thread-local, so this test is isolated by construction.
#[test]
fn workspace_zero_misses_after_warmup() {
    let n = 120;
    let (data, bv) = spd_system(0xD00d, n);
    let exec = Executor::reference();
    let a = Csr::from_data(exec.clone(), &data).unwrap();
    let b = Dense::vector(exec.clone(), &bv);
    let crit = Criterion::residual(1e-8, 300);

    let solvers: Vec<Box<dyn Solver<f64>>> = vec![
        Box::new(Cg::<f64>::new(SolverConfig::with_criterion(crit.clone()))),
        Box::new(BiCgStab::new(SolverConfig::with_criterion(crit.clone()))),
        Box::new(Gmres::new(SolverConfig::with_criterion(crit.clone()))),
    ];
    for solver in solvers {
        ws::clear();
        // warm-up populates the pool
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        solver.solve(&a, &b, &mut x).unwrap();
        let (_, cold_misses) = ws::stats();
        assert!(cold_misses > 0, "{}: warm-up must populate pool", solver.name());

        ws::reset_stats();
        for _ in 0..3 {
            let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
            solver.solve(&a, &b, &mut x).unwrap();
        }
        let (hits, misses) = ws::stats();
        assert_eq!(
            misses,
            0,
            "{}: warm solves must reuse every buffer ({hits} hits)",
            solver.name()
        );
        assert!(hits > 0, "{}: warm solves must use the pool", solver.name());
    }
    ws::clear();
}

/// Par fused reductions agree with the sequential reference to high
/// accuracy (they are designed to be thread-count independent, and the
/// block structure matches the reference order per block).
#[test]
fn par_fused_close_to_reference() {
    for_all(0xACC0, 6, |rng, _| {
        let n = 1 + rng.below(30_000);
        let xv = gen_vec::<f64>(rng, n);
        let yv = gen_vec::<f64>(rng, n);
        let er = Executor::reference();
        let xr = Dense::vector(er.clone(), &xv);
        let yr = Dense::vector(er.clone(), &yv);
        let (xy_r, yy_r) = blas::dot_norm2(&er, &xr, &yr).unwrap();
        for threads in [2, 8] {
            let ep = Executor::par_with_threads(threads);
            let xp = Dense::vector(ep.clone(), &xv);
            let yp = Dense::vector(ep.clone(), &yv);
            let (xy_p, yy_p) = blas::dot_norm2(&ep, &xp, &yp).unwrap();
            // blocked vs sequential summation order: ~n·eps drift
            assert_close(&[xy_p], &[xy_r], 1e-9, "dot_norm2 xy par vs ref");
            assert_close(&[yy_p], &[yy_r], 1e-9, "dot_norm2 yy par vs ref");
        }
    });
}
