//! Fig. 8 reproduction: SpMV throughput over the matrix suite.
//!
//! Per matrix and per kernel (sparkle CSR, sparkle COO, vendor CSR):
//!   * projected GFLOP/s on GEN9/f64 (left panel) and GEN12/f32 (right),
//!     next to the §6.3 roofline bound for each format;
//!   * measured GFLOP/s of the real kernels on this host's `par`
//!     executor (validates relative format behaviour).
//!
//! `SPARKLE_SCALE` controls matrix sizes (default 1/64 of paper size).

use sparkle::bench_util::{bench_scale, f2, spmv_suite, Table, Timer};
use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::core::types::Value;
use sparkle::matrix::{Coo, Csr, Dense};
use sparkle::perfmodel::project::Implementation;
use sparkle::perfmodel::{project_spmv, Device, SpmvKernelKind};
use sparkle::vendor_mkl::VendorCsr;
use sparkle::Dim2;

fn panel<T: Value>(device: Device) {
    let scale = bench_scale();
    let suite = spmv_suite::<T>(scale);
    let p = T::PRECISION;
    println!(
        "\n-- {} / {} ({} matrices, scale 1/{scale}) --",
        device.spec().name,
        p,
        suite.len()
    );
    let mut t = Table::new(&[
        "matrix",
        "n",
        "nnz",
        "csr GF/s",
        "coo GF/s",
        "mkl GF/s",
        "csr bound",
        "coo bound",
        "host csr",
        "host coo",
        "host mkl",
    ]);
    let exec = Executor::par();
    let timer = Timer::default();
    for m in &suite {
        let proj = |imp, kind| project_spmv(device, imp, kind, &m.stats_full, p).gflops;
        let csr_p = proj(Implementation::Sparkle, SpmvKernelKind::Csr);
        let coo_p = proj(Implementation::Sparkle, SpmvKernelKind::Coo);
        let mkl_p = proj(Implementation::Vendor, SpmvKernelKind::Csr);
        let bound_csr =
            project_spmv(device, Implementation::Sparkle, SpmvKernelKind::Csr, &m.stats_full, p)
                .roofline_bound_gflops;
        let bound_coo =
            project_spmv(device, Implementation::Sparkle, SpmvKernelKind::Coo, &m.stats_full, p)
                .roofline_bound_gflops;

        // measured on host
        let csr = Csr::from_data(exec.clone(), &m.data).unwrap();
        let coo = Coo::from_data(exec.clone(), &m.data).unwrap();
        let vendor = VendorCsr::new(csr.clone());
        let b = Dense::filled(exec.clone(), Dim2::new(m.stats.n, 1), T::from_f64(1.0));
        let mut x = Dense::zeros(exec.clone(), Dim2::new(m.stats.n, 1));
        let flops = 2.0 * m.stats.nnz as f64;
        let host_csr = timer.run(|| csr.apply(&b, &mut x).unwrap()).rate_giga(flops);
        let host_coo = timer.run(|| coo.apply(&b, &mut x).unwrap()).rate_giga(flops);
        let host_mkl = timer.run(|| vendor.apply(&b, &mut x).unwrap()).rate_giga(flops);

        t.row(&[
            m.name.clone(),
            m.stats.n.to_string(),
            m.stats.nnz.to_string(),
            f2(csr_p),
            f2(coo_p),
            f2(mkl_p),
            f2(bound_csr),
            f2(bound_coo),
            f2(host_csr),
            f2(host_coo),
            f2(host_mkl),
        ]);
    }
    t.print();
}

fn main() {
    println!("== Fig. 8: SpMV performance over the matrix suite ==");
    // left panel: GEN9, IEEE double
    panel::<f64>(Device::Gen9);
    // right panel: GEN12, IEEE single
    panel::<f32>(Device::Gen12);
    println!(
        "\nshape check (paper §6.3): on GEN9/f64 CSR ≈ vendor CSR ≈ 5.1 of\n\
         6.0-bound, COO ≈ 3.8 of 4.6-bound; on GEN12/f32 all kernels near\n\
         their 14.5/9.7 bounds with the vendor kernel inconsistent —\n\
         winning on long regular rows, losing on irregular circuits."
    );
}
