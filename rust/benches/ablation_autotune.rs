//! Ablation: autotuned format selection vs the best hand-picked format.
//!
//! For every matrix in the `matgen` suite this measures all viable
//! formats on the host `par` executor, lets [`AutoMatrix`] make its own
//! choice, and reports the *regret* — chosen-format throughput as a
//! fraction of the best hand-picked format's throughput. The
//! acceptance bar is a geometric-mean ratio >= 0.90: the tuner may
//! occasionally pick the runner-up on near-ties, but must never pick a
//! badly losing format.
//!
//! Emits `BENCH_autotune.json` (machine-readable) next to the table.

use std::io::Write as _;

use sparkle::autotune::{prior, AutoConfig, AutoMatrix, Features, FormatChoice};
use sparkle::bench_util::{bench_scale, f2, spmv_suite, Table, Timer};
use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::matrix::Dense;
use sparkle::Dim2;

const JSON_PATH: &str = "BENCH_autotune.json";

struct Row {
    name: String,
    n: usize,
    nnz: usize,
    best_format: FormatChoice,
    best_us: f64,
    best_gflops: f64,
    chosen_format: FormatChoice,
    chosen_us: f64,
    chosen_gflops: f64,
    source: String,
    ratio: f64,
}

fn main() {
    let scale = bench_scale();
    println!("== Ablation: autotune regret vs best hand-picked format ==");
    println!("   (par executor, matgen suite, scale {scale})\n");
    let exec = Executor::par();
    let timer = Timer::default();
    // no persistence: every matrix is a cold-start tuning decision
    let cfg = AutoConfig::default();

    let suite = spmv_suite::<f64>(scale);
    let mut rows: Vec<Row> = Vec::new();
    for m in &suite {
        let feats = Features::from_data(&m.data);
        let flops = 2.0 * feats.nnz as f64;
        let b = Dense::filled(exec.clone(), Dim2::new(feats.cols, 1), 1.0);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(feats.rows, 1));

        // exhaustive hand-picked baseline over every viable format
        let mut best: Option<(FormatChoice, f64, f64)> = None;
        for &format in FormatChoice::ALL.iter() {
            if !prior::supported_on(&exec, format) {
                continue;
            }
            if format == FormatChoice::Ell && !prior::ell_is_viable(&feats) {
                continue; // padding blow-up: a human would not pick ELL
            }
            let op = match sparkle::autotune::measure::build_format(
                exec.clone(),
                &m.data,
                format,
            ) {
                Ok(op) => op,
                Err(_) => continue,
            };
            let stats = timer.run(|| op.apply(&b, &mut x).unwrap());
            let us = stats.median * 1e6;
            let gf = stats.rate_giga(flops);
            if best.map_or(true, |(_, bus, _)| us < bus) {
                best = Some((format, us, gf));
            }
        }
        let (best_format, best_us, best_gflops) =
            best.expect("at least CSR is always viable");

        // the tuner's pick, timed under the identical harness
        let auto = AutoMatrix::with_config(exec.clone(), &m.data, &cfg).unwrap();
        let stats = timer.run(|| auto.apply(&b, &mut x).unwrap());
        let chosen_us = stats.median * 1e6;
        let chosen_gflops = stats.rate_giga(flops);
        // regret in time, which is throughput ratio chosen/best
        let ratio = best_us / chosen_us.max(1e-12);

        rows.push(Row {
            name: m.name.clone(),
            n: feats.rows,
            nnz: feats.nnz,
            best_format,
            best_us,
            best_gflops,
            chosen_format: auto.chosen_format(),
            chosen_us,
            chosen_gflops,
            source: format!("{:?}", auto.report().source).to_lowercase(),
            ratio,
        });
    }

    let mut t = Table::new(&[
        "matrix", "best", "best GF/s", "chosen", "chosen GF/s", "ratio", "source",
    ]);
    for r in &rows {
        t.row(&[
            r.name.clone(),
            r.best_format.to_string(),
            f2(r.best_gflops),
            r.chosen_format.to_string(),
            f2(r.chosen_gflops),
            f2(r.ratio),
            r.source.clone(),
        ]);
    }
    t.print();

    let geomean = (rows.iter().map(|r| r.ratio.max(1e-12).ln()).sum::<f64>()
        / rows.len().max(1) as f64)
        .exp();
    let hits = rows
        .iter()
        .filter(|r| r.chosen_format == r.best_format)
        .count();
    println!(
        "\ngeomean chosen/best throughput ratio: {geomean:.3} \
         (exact picks {hits}/{})",
        rows.len()
    );
    println!(
        "acceptance (>= 0.90): {}",
        if geomean >= 0.90 { "PASS" } else { "FAIL" }
    );

    write_json(&rows, scale, geomean).expect("write BENCH_autotune.json");
    println!("wrote {JSON_PATH}");
}

/// Hand-rolled JSON (no serde in the dependency closure).
fn write_json(rows: &[Row], scale: usize, geomean: f64) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"sparkle/ablation_autotune/v1\",\n");
    s.push_str(&format!("  \"scale\": {scale},\n"));
    s.push_str("  \"executor\": \"par\",\n");
    s.push_str("  \"precision\": \"f64\",\n");
    s.push_str("  \"matrices\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str("    {");
        s.push_str(&format!("\"name\": \"{}\", ", r.name));
        s.push_str(&format!("\"n\": {}, ", r.n));
        s.push_str(&format!("\"nnz\": {}, ", r.nnz));
        s.push_str(&format!("\"best_format\": \"{}\", ", r.best_format));
        s.push_str(&format!("\"best_us\": {:.3}, ", r.best_us));
        s.push_str(&format!("\"best_gflops\": {:.4}, ", r.best_gflops));
        s.push_str(&format!("\"chosen_format\": \"{}\", ", r.chosen_format));
        s.push_str(&format!("\"chosen_us\": {:.3}, ", r.chosen_us));
        s.push_str(&format!("\"chosen_gflops\": {:.4}, ", r.chosen_gflops));
        s.push_str(&format!("\"source\": \"{}\", ", r.source));
        s.push_str(&format!("\"ratio\": {:.4}", r.ratio));
        s.push_str(if i + 1 == rows.len() { "}\n" } else { "},\n" });
    }
    s.push_str("  ],\n");
    s.push_str(&format!("  \"geomean_ratio\": {geomean:.4},\n"));
    s.push_str(&format!(
        "  \"acceptance_0p9\": {}\n",
        geomean >= 0.90
    ));
    s.push_str("}\n");
    let mut f = std::fs::File::create(JSON_PATH)?;
    f.write_all(s.as_bytes())
}
