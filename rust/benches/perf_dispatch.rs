//! Perf regression bench: XLA-executor dispatch costs (the L3 hot path).
//!
//! Measures per-call wallclock of the ported backend's kernels across
//! sizes, separating fixed dispatch cost (PJRT launch + literal
//! marshalling + pad/copy) from size-dependent work. Used by the §Perf
//! iteration log in EXPERIMENTS.md.

use sparkle::bench_util::{Table, Timer};
use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::kernels::blas;
use sparkle::matgen::suite;
use sparkle::matrix::{Csr, Dense, Ell};
use sparkle::Dim2;

fn main() {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        println!("artifacts/ not built — run `make artifacts` first");
        return;
    }
    let exec = Executor::xla("artifacts").unwrap();
    let timer = Timer::new(3, 20);

    println!("== perf: XLA dispatch costs ==\n");
    let mut t = Table::new(&["op", "n", "us/call"]);
    for n in [256usize, 1024, 16384, 262144] {
        let x = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0f64);
        let mut y = Dense::filled(exec.clone(), Dim2::new(n, 1), 2.0f64);
        let st = timer.run(|| blas::axpy(&exec, 0.5, &x, &mut y).unwrap());
        t.row(&["axpy".into(), n.to_string(), format!("{:.1}", st.mean * 1e6)]);
        let st = timer.run(|| {
            blas::dot(&exec, &x, &y).unwrap();
        });
        t.row(&["dot".into(), n.to_string(), format!("{:.1}", st.mean * 1e6)]);
    }
    t.print();

    println!("\n-- SpMV per-apply cost (thermal2 analog, scale 1/64) --");
    let data = suite::table1_entry("thermal2").unwrap().generate::<f64>(64);
    let n = data.dim.rows;
    let csr = Csr::from_data(exec.clone(), &data).unwrap();
    let ell = Ell::from_data(exec.clone(), &data).unwrap();
    let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let mut t2 = Table::new(&["format", "ms/apply"]);
    let st = timer.run(|| csr.apply(&b, &mut x).unwrap());
    t2.row(&["csr (row-expand + coo_adv)".into(), format!("{:.3}", st.mean * 1e3)]);
    let st = timer.run(|| ell.apply(&b, &mut x).unwrap());
    t2.row(&["ell (pallas artifact)".into(), format!("{:.3}", st.mean * 1e3)]);
    t2.print();
}
