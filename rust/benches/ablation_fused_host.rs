//! Ablation: fused host kernels vs the composed BLAS-1/SpMV baseline.
//!
//! Runs fixed-iteration CG, BiCGSTAB and restarted GMRES solves over
//! the matgen suite on the `par` executor twice through the *same*
//! driver code: once with the fused kernels disabled (composed
//! baseline) and once enabled. The fused kernels are bit-identical to
//! the composed sequences, so any difference is purely memory traffic
//! (GMRES exercises the batched MGS kernels — one sweep of w per basis
//! vector instead of two). Reports the per-matrix speedup
//! `composed/fused` and the geometric mean; the smoke gate fails if
//! fused is more than 5 % slower than composed anywhere — including
//! the GMRES rows. Also verifies the solver workspace performs zero
//! pool misses (= zero Dense allocations) on repeated CG and GMRES
//! solves after warm-up.
//!
//! Emits `BENCH_fused_host.json` (machine-readable) next to the table.

use std::io::Write as _;

use sparkle::bench_util::{bench_scale, f2, spmv_suite, Table, Timer};
use sparkle::core::executor::Executor;
use sparkle::kernels::set_fused_enabled;
use sparkle::matrix::{Csr, Dense};
use sparkle::resilience::BreakdownPolicy;
use sparkle::solver::{workspace as ws, BiCgStab, Cg, Gmres, Solver, SolverConfig};
use sparkle::stop::Criterion;
use sparkle::Dim2;

const JSON_PATH: &str = "BENCH_fused_host.json";
const ITERS: usize = 25;

struct Row {
    matrix: String,
    solver: &'static str,
    n: usize,
    nnz: usize,
    composed_us: f64,
    fused_us: f64,
    ratio: f64,
}

fn solver_config() -> SolverConfig {
    // fixed iteration budget: both variants do the identical work; a
    // lenient breakdown policy keeps the stagnation window out of the
    // timing loop
    let mut cfg = SolverConfig::with_criterion(Criterion::iterations(ITERS));
    cfg.breakdown = BreakdownPolicy::lenient();
    cfg
}

fn time_solver(
    timer: &Timer,
    solver: &dyn Solver<f64>,
    a: &Csr<f64>,
    b: &Dense<f64>,
    x: &mut Dense<f64>,
) -> (f64, f64) {
    // warm the workspace pool outside the timed region so neither
    // variant pays the cold-start allocations
    x.fill(0.0);
    solver.solve(a, b, x).unwrap();

    set_fused_enabled(false);
    let composed = timer.run(|| {
        x.fill(0.0);
        solver.solve(a, b, x).unwrap();
    });
    set_fused_enabled(true);
    let fused = timer.run(|| {
        x.fill(0.0);
        solver.solve(a, b, x).unwrap();
    });
    (composed.median * 1e6, fused.median * 1e6)
}

fn main() {
    let scale = bench_scale();
    println!("== Ablation: fused host kernels vs composed baseline ==");
    println!("   (par executor, matgen suite, scale {scale}, {ITERS} fixed iters)\n");
    let exec = Executor::par();
    let timer = Timer::default();

    let suite = spmv_suite::<f64>(scale);
    let mut rows: Vec<Row> = Vec::new();
    for m in &suite {
        let n = m.data.dim.rows;

        // CG needs SPD: symmetrized + shifted copy
        let mut spd = m.data.clone();
        spd.symmetrize();
        spd.shift_diagonal(1.0);
        // BiCGSTAB handles general systems; shift keeps it dominant
        let mut gen = m.data.clone();
        gen.shift_diagonal(1.0);

        let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));

        let cases: Vec<(&'static str, Box<dyn Solver<f64>>, Csr<f64>)> = vec![
            (
                "cg",
                Box::new(Cg::<f64>::new(solver_config())),
                Csr::from_data(exec.clone(), &spd).unwrap(),
            ),
            (
                "bicgstab",
                Box::new(BiCgStab::new(solver_config())),
                Csr::from_data(exec.clone(), &gen).unwrap(),
            ),
            (
                // short restart keeps the basis resident while still
                // exercising multi-vector mgs_project/mgs_update sweeps
                "gmres",
                Box::new(Gmres::new(solver_config()).with_restart(10)),
                Csr::from_data(exec.clone(), &gen).unwrap(),
            ),
        ];
        for (name, solver, a) in &cases {
            let (composed_us, fused_us) = time_solver(&timer, solver.as_ref(), a, &b, &mut x);
            rows.push(Row {
                matrix: m.name.clone(),
                solver: *name,
                n,
                nnz: a.nnz(),
                composed_us,
                fused_us,
                ratio: composed_us / fused_us.max(1e-12),
            });
        }
    }

    let mut t = Table::new(&["matrix", "solver", "n", "composed µs", "fused µs", "speedup"]);
    for r in &rows {
        t.row(&[
            r.matrix.clone(),
            r.solver.to_string(),
            r.n.to_string(),
            f2(r.composed_us),
            f2(r.fused_us),
            f2(r.ratio),
        ]);
    }
    t.print();

    let geomean = (rows.iter().map(|r| r.ratio.max(1e-12).ln()).sum::<f64>()
        / rows.len().max(1) as f64)
        .exp();
    let worst = rows
        .iter()
        .map(|r| r.ratio)
        .fold(f64::INFINITY, f64::min);
    println!("\ngeomean composed/fused speedup: {geomean:.3} (worst {worst:.3})");
    println!(
        "target (geomean >= 1.15): {}",
        if geomean >= 1.15 { "PASS" } else { "MISS" }
    );

    // repeated-solve workspace check: zero pool misses after warm-up
    let misses = workspace_misses_after_warmup(&exec, scale);
    println!(
        "workspace misses after warm-up: {misses} ({})",
        if misses == 0 { "PASS" } else { "FAIL" }
    );

    write_json(&rows, scale, geomean, worst, misses).expect("write BENCH_fused_host.json");
    println!("wrote {JSON_PATH}");

    // smoke gate: fused must never be > 5 % slower than composed, and
    // warm solves must be allocation-free
    if worst < 0.95 {
        eprintln!("FAIL: fused slower than composed by > 5 % (worst ratio {worst:.3})");
        std::process::exit(1);
    }
    if misses > 0 {
        eprintln!("FAIL: {misses} workspace misses on warm solves");
        std::process::exit(1);
    }
}

/// Warm one solve shape per solver, then count pool misses over
/// repeated CG and GMRES solves. GMRES is the stress case: the Krylov
/// basis is `restart + 1` pooled vectors per solve, so a leak anywhere
/// in the basis recycling shows up here as a miss.
fn workspace_misses_after_warmup(
    exec: &std::sync::Arc<Executor>,
    scale: usize,
) -> u64 {
    let suite = spmv_suite::<f64>(scale);
    let m = &suite[0];
    let n = m.data.dim.rows;
    let mut spd = m.data.clone();
    spd.symmetrize();
    spd.shift_diagonal(1.0);
    let mut gen = m.data.clone();
    gen.shift_diagonal(1.0);
    let a_spd = Csr::from_data(exec.clone(), &spd).unwrap();
    let a_gen = Csr::from_data(exec.clone(), &gen).unwrap();
    let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
    let cg = Cg::new(solver_config());
    let gmres = Gmres::new(solver_config()).with_restart(10);

    ws::clear();
    let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    // warm-up populates the pool for both solver shapes
    cg.solve(&a_spd, &b, &mut x).unwrap();
    x.fill(0.0);
    gmres.solve(&a_gen, &b, &mut x).unwrap();
    ws::reset_stats();
    for _ in 0..5 {
        x.fill(0.0);
        cg.solve(&a_spd, &b, &mut x).unwrap();
        x.fill(0.0);
        gmres.solve(&a_gen, &b, &mut x).unwrap();
    }
    let (_, misses) = ws::stats();
    misses
}

/// Hand-rolled JSON (no serde in the dependency closure).
fn write_json(
    rows: &[Row],
    scale: usize,
    geomean: f64,
    worst: f64,
    ws_misses: u64,
) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"sparkle/ablation_fused_host/v1\",\n");
    s.push_str(&format!("  \"scale\": {scale},\n"));
    s.push_str("  \"executor\": \"par\",\n");
    s.push_str("  \"precision\": \"f64\",\n");
    s.push_str(&format!("  \"fixed_iters\": {ITERS},\n"));
    s.push_str("  \"cases\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str("    {");
        s.push_str(&format!("\"matrix\": \"{}\", ", r.matrix));
        s.push_str(&format!("\"solver\": \"{}\", ", r.solver));
        s.push_str(&format!("\"n\": {}, ", r.n));
        s.push_str(&format!("\"nnz\": {}, ", r.nnz));
        s.push_str(&format!("\"composed_us\": {:.3}, ", r.composed_us));
        s.push_str(&format!("\"fused_us\": {:.3}, ", r.fused_us));
        s.push_str(&format!("\"ratio\": {:.4}", r.ratio));
        s.push_str(if i + 1 == rows.len() { "}\n" } else { "},\n" });
    }
    s.push_str("  ],\n");
    s.push_str(&format!("  \"geomean_ratio\": {geomean:.4},\n"));
    s.push_str(&format!("  \"worst_ratio\": {worst:.4},\n"));
    s.push_str(&format!("  \"workspace_misses_after_warmup\": {ws_misses},\n"));
    s.push_str(&format!("  \"acceptance_1p15\": {},\n", geomean >= 1.15));
    s.push_str(&format!("  \"smoke_0p95\": {}\n", worst >= 0.95));
    s.push_str("}\n");
    let mut f = std::fs::File::create(JSON_PATH)?;
    f.write_all(s.as_bytes())
}
