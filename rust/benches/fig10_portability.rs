//! Fig. 10 reproduction: SpMV bandwidth relative to each platform's
//! theoretical peak — the performance-portability figure.
//!
//! Four panels (V100/cuda, RadeonVII/hip, GEN9/dpcpp, GEN12/dpcpp), each
//! showing sparkle CSR, sparkle COO and the vendor-library CSR over the
//! matrix suite; per-panel min/median/max summarize the cloud.

use sparkle::bench_util::{bench_scale, f2, spmv_suite, Table};
use sparkle::core::types::Precision;
use sparkle::perfmodel::project::Implementation;
use sparkle::perfmodel::{project_spmv, Device, SpmvKernelKind};

fn median(mut v: Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

fn main() {
    let scale = bench_scale();
    println!("== Fig. 10: SpMV bandwidth relative to theoretical peak ==");
    let suite = spmv_suite::<f64>(scale);
    println!("({} matrices, scale 1/{scale})", suite.len());

    let mut summary = Table::new(&[
        "platform", "kernel", "min", "median", "max", "paper band",
    ]);
    for device in Device::ALL {
        // GEN12 lacks native fp64 (§6.1): evaluated in single precision
        let p = if device == Device::Gen12 {
            Precision::Single
        } else {
            Precision::Double
        };
        println!("\n-- {} ({p}) --", device.spec().name);
        let mut t = Table::new(&["matrix", "csr rel", "coo rel", "vendor rel"]);
        let mut series: [Vec<f64>; 3] = [vec![], vec![], vec![]];
        for m in &suite {
            let rel = |imp, kind| project_spmv(device, imp, kind, &m.stats_full, p).relative_bw;
            let csr = rel(Implementation::Sparkle, SpmvKernelKind::Csr);
            let coo = rel(Implementation::Sparkle, SpmvKernelKind::Coo);
            let ven = rel(Implementation::Vendor, SpmvKernelKind::Csr);
            series[0].push(csr);
            series[1].push(coo);
            series[2].push(ven);
            t.row(&[m.name.clone(), f2(csr), f2(coo), f2(ven)]);
        }
        t.print();
        let (lo, hi) = device.spec().relative_bw_band;
        for (i, kernel) in ["sparkle csr", "sparkle coo", "vendor csr"].iter().enumerate() {
            summary.row(&[
                device.spec().name.to_string(),
                kernel.to_string(),
                f2(series[i].iter().copied().fold(f64::MAX, f64::min)),
                f2(median(series[i].clone())),
                f2(series[i].iter().copied().fold(0.0, f64::max)),
                format!("{lo:.2}-{hi:.2}"),
            ]);
        }
    }
    println!("\n== summary ==");
    summary.print();
    println!(
        "\nshape check (paper §6.5): GEN12 and the CUDA-class platform sit\n\
         high (~90% of peak for the best matrices), GEN9 and RadeonVII in\n\
         the 60-70% band; the vendor kernel is inconsistent on GEN12 —\n\
         above sparkle for some matrices, below for others; sparkle\n\
         kernels are competitive with vendor kernels on every platform."
    );
}
