//! Ablation: fused solver-step artifacts vs composed BLAS-1 dispatch on
//! the XLA ("ported") executor.
//!
//! The L2 design choice DESIGN.md calls out: one `cg_step` artifact per
//! iteration (1 PJRT dispatch) vs the composed CG driver (~7 dispatches:
//! SpMV + 2 dot + 3 axpy-like + norm). Reports wallclock per iteration
//! and PJRT launch counts for both paths on the CPU PJRT client, plus
//! the projected dispatch-overhead saving on the modeled GPUs.

use sparkle::bench_util::{f2, Table, Timer};
use sparkle::core::executor::Executor;
use sparkle::matgen::stencil;
use sparkle::matrix::{Csr, Dense, Ell};
use sparkle::solver::fused::FusedCg;
use sparkle::solver::{Cg, Solver, SolverConfig};
use sparkle::stop::Criterion;
use sparkle::Dim2;

fn main() {
    println!("== Ablation: fused cg_step artifact vs composed BLAS-1 CG ==\n");
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        println!("artifacts/ not built — run `make artifacts` first");
        return;
    }
    let iters = 40;
    let mut t = Table::new(&[
        "n", "path", "launches/iter", "ms/iter", "speedup",
    ]);
    for side in [24usize, 40, 64] {
        let data = stencil::laplace_2d::<f64>(side, side);
        let n = side * side;
        let crit = Criterion::iterations(iters);

        // composed path
        let exec = Executor::xla("artifacts").unwrap();
        let rt = exec.xla_runtime().unwrap().clone();
        let a = Csr::from_data(exec.clone(), &data).unwrap();
        let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
        let timer = Timer::new(1, 3);
        let before = rt.launch_count();
        let composed_stats = timer.run(|| {
            x.fill(0.0);
            Cg::new(SolverConfig::with_criterion(crit.clone()))
                .solve(&a, &b, &mut x)
                .unwrap();
        });
        let composed_launches =
            (rt.launch_count() - before) as f64 / 4.0 / iters as f64; // 4 runs
        let composed_ms = composed_stats.mean * 1e3 / iters as f64;

        // fused path
        let exec2 = Executor::xla("artifacts").unwrap();
        let rt2 = exec2.xla_runtime().unwrap().clone();
        let ell = Ell::from_data(exec2.clone(), &data).unwrap();
        let b2 = Dense::filled(exec2.clone(), Dim2::new(n, 1), 1.0);
        let mut x2 = Dense::zeros(exec2.clone(), Dim2::new(n, 1));
        let before2 = rt2.launch_count();
        let fused_stats = timer.run(|| {
            x2.fill(0.0);
            FusedCg::new(SolverConfig::with_criterion(crit.clone()))
                .solve(&ell, &b2, &mut x2)
                .unwrap();
        });
        let fused_launches = (rt2.launch_count() - before2) as f64 / 4.0 / iters as f64;
        let fused_ms = fused_stats.mean * 1e3 / iters as f64;

        t.row(&[
            n.to_string(),
            "composed".into(),
            f2(composed_launches),
            format!("{composed_ms:.3}"),
            "1.00".into(),
        ]);
        t.row(&[
            n.to_string(),
            "fused".into(),
            f2(fused_launches),
            format!("{fused_ms:.3}"),
            f2(composed_ms / fused_ms),
        ]);
    }
    t.print();
    println!(
        "\nmodel view: on GEN9 (8us/launch) the composed path pays\n\
         ~{}us/iter of launch overhead, the fused path ~8us — the gap\n\
         closes as the matrix grows and bandwidth dominates.",
        7 * 8
    );
}
