//! Ablation: merge-path (vendor-style) vs row-parallel CSR scheduling.
//!
//! The mechanism behind the §6.5 "oneMKL inconsistency": nonzero-
//! balanced merge-path scheduling wins when rows are wildly imbalanced,
//! row-parallel wins on regular matrices (no fixup pass, better row
//! locality). Sweeps thread counts on both a regular stencil and a
//! power-law circuit.

use sparkle::bench_util::{f2, Table, Timer};
use sparkle::core::executor::{Executor, ParConfig};
use sparkle::core::linop::LinOp;
use sparkle::kernels::par;
use sparkle::matgen::{circuit, stencil, MatrixStats};
use sparkle::matrix::{Csr, Dense};
use sparkle::vendor_mkl::VendorCsr;
use sparkle::Dim2;

fn main() {
    println!("== Ablation: merge-path vs row-parallel CSR scheduling ==\n");
    let exec = Executor::par();
    let timer = Timer::default();

    let cases = vec![
        ("stencil7_40^3 (regular)", stencil::stencil_3d::<f64>(40, 40, 40, 0.0)),
        (
            "circuit_powerlaw (skewed)",
            circuit::circuit::<f64>(60_000, 360_000, 55),
        ),
    ];
    let mut t = Table::new(&["matrix", "threads", "row-par GF/s", "merge GF/s", "merge/row"]);
    for (name, data) in &cases {
        let stats = MatrixStats::from_data(data);
        let flops = 2.0 * stats.nnz as f64;
        let a = Csr::from_data(exec.clone(), data).unwrap();
        let b = Dense::filled(exec.clone(), Dim2::new(stats.n, 1), 1.0);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(stats.n, 1));
        for threads in [1usize, 2, 4, 8] {
            let cfg = ParConfig {
                threads,
                seq_threshold: 0,
            };
            let row_gf = timer
                .run(|| par::csr_spmv_advanced(&cfg, 1.0, &a, 0.0, &b, &mut x))
                .rate_giga(flops);
            let vendor = VendorCsr::new(a.clone()).with_config(cfg.clone());
            let merge_gf = timer
                .run(|| vendor.apply(&b, &mut x).unwrap())
                .rate_giga(flops);
            t.row(&[
                name.to_string(),
                threads.to_string(),
                f2(row_gf),
                f2(merge_gf),
                f2(merge_gf / row_gf),
            ]);
        }
    }
    t.print();
    println!(
        "\nshape check: merge/row ratio should rise with thread count on\n\
         the skewed matrix (row-parallel threads idle behind the hub\n\
         rows) and stay ≤1 on the regular stencil."
    );
}
