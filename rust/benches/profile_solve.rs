//! Profiling smoke bench: run one instrumented CG solve on the host
//! `par` executor, stream every event to `BENCH_observe.jsonl`, and
//! write the aggregated roofline [`Profile`] to `BENCH_observe.json`.
//!
//! Acceptance: the solve converges and the profile reports SpMV
//! roofline efficiency in (0, 1] against the GEN12 device model —
//! exits non-zero otherwise so CI can gate on it.

use std::sync::Arc;

use sparkle::bench_util::bench_scale;
use sparkle::core::executor::Executor;
use sparkle::core::types::Precision;
use sparkle::matgen::stencil;
use sparkle::observe::{JsonlLogger, Logger as _, Profile, Record};
use sparkle::perfmodel::Device;
use sparkle::solver::SolverBuilder;
use sparkle::stop::Criterion;
use sparkle::{Dense, Dim2};

const JSON_PATH: &str = "BENCH_observe.json";
const JSONL_PATH: &str = "BENCH_observe.jsonl";

fn main() {
    let side = bench_scale().max(16);
    let data = stencil::laplace_2d::<f64>(side, side);
    let n = data.dim.rows;
    println!("== Profiled CG solve (laplace_2d {side}x{side}, n={n}, par executor) ==\n");

    let exec = Executor::par();
    let b = Dense::filled(exec.clone(), Dim2::new(n, 1), 1.0);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(n, 1));
    let rec = Arc::new(Record::new());
    let result = SolverBuilder::cg()
        .with_criterion(Criterion::residual(1e-8, 2000))
        .with_logger(rec.clone())
        .solve_data(&exec, &data, &b, &mut x)
        .expect("instrumented solve failed");
    println!(
        "converged: {} in {} iterations (resnorm {:.3e})\n",
        result.converged, result.iterations, result.resnorm
    );

    // stream the raw event log (the JSON-lines artifact)
    let events = rec.events();
    let jsonl = JsonlLogger::to_file(JSONL_PATH).expect("create BENCH_observe.jsonl");
    for e in &events {
        jsonl.log(e);
    }
    jsonl.flush().expect("flush BENCH_observe.jsonl");

    // aggregate against the paper's GEN12 roofline
    let profile = Profile::from_events(&events, Device::Gen12, Precision::Double);
    profile.summary_table().print();

    let eff = profile.best_spmv_efficiency();
    match eff {
        Some(e) => println!(
            "\nbest SpMV roofline efficiency vs {}: {e:.3}",
            profile.device.spec().name
        ),
        None => println!("\nno SpMV kernels observed"),
    }
    let pass = result.converged && matches!(eff, Some(e) if e > 0.0 && e <= 1.0);
    println!(
        "acceptance (converged && SpMV efficiency in (0,1]): {}",
        if pass { "PASS" } else { "FAIL" }
    );

    profile.write_json(JSON_PATH).expect("write BENCH_observe.json");
    println!("wrote {JSON_PATH} and {JSONL_PATH} ({} events)", events.len());
    if !pass {
        std::process::exit(1);
    }
}
