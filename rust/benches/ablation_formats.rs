//! Ablation: storage-format behaviour across matrix structure
//! (the design space behind the paper's CSR-vs-COO study).
//!
//! Measures all five formats (CSR, COO, ELL, SELL-P, Hybrid) on the host
//! `par` executor over a regular stencil, a moderately irregular FEM
//! matrix and a power-law circuit, plus a SELL-P slice-size sweep.
//! Storage overhead (padding ratio) is reported next to throughput —
//! the ELL-blowup on circuits is the reason Ginkgo ships Hybrid.

use sparkle::bench_util::{f2, Table, Timer};
use sparkle::core::executor::Executor;
use sparkle::core::linop::LinOp;
use sparkle::matgen::{circuit, fem, stencil, MatrixStats};
use sparkle::matrix::{Coo, Csr, Dense, Ell, Hybrid, SellP};
use sparkle::Dim2;

fn main() {
    println!("== Ablation: sparse format × matrix structure (host measured) ==\n");
    let exec = Executor::par();
    let timer = Timer::default();

    let cases = vec![
        ("stencil7_32^3", stencil::stencil_3d::<f64>(32, 32, 32, 0.0)),
        ("fem_block3", fem::fem::<f64>(12_000, 6, 3, 77)),
        ("circuit_powerlaw", circuit::circuit::<f64>(40_000, 240_000, 78)),
    ];
    let mut t = Table::new(&[
        "matrix", "format", "GF/s", "stored/nnz", "note",
    ]);
    for (name, data) in &cases {
        let stats = MatrixStats::from_data(data);
        let flops = 2.0 * stats.nnz as f64;
        let b = Dense::filled(exec.clone(), Dim2::new(stats.n, 1), 1.0);
        let mut x = Dense::zeros(exec.clone(), Dim2::new(stats.n, 1));

        let csr = Csr::from_data(exec.clone(), data).unwrap();
        let gf = timer.run(|| csr.apply(&b, &mut x).unwrap()).rate_giga(flops);
        t.row(&[name.to_string(), "csr".into(), f2(gf), "1.00".into(), "".into()]);

        let coo = Coo::from_data(exec.clone(), data).unwrap();
        let gf = timer.run(|| coo.apply(&b, &mut x).unwrap()).rate_giga(flops);
        t.row(&[name.to_string(), "coo".into(), f2(gf), "1.00".into(), "".into()]);

        // ELL explodes on power-law rows: guard the memory blow-up
        let ell_stored = stats.n * stats.max_row;
        if ell_stored < 64_000_000 {
            let ell = Ell::from_data(exec.clone(), data).unwrap();
            let ratio = ell.stored_total() as f64 / stats.nnz as f64;
            let gf = timer.run(|| ell.apply(&b, &mut x).unwrap()).rate_giga(flops);
            t.row(&[name.to_string(), "ell".into(), f2(gf), f2(ratio), "".into()]);
        } else {
            t.row(&[
                name.to_string(),
                "ell".into(),
                "-".into(),
                f2(ell_stored as f64 / stats.nnz as f64),
                "padding blow-up: skipped".into(),
            ]);
        }

        let sellp = SellP::from_data(exec.clone(), data).unwrap();
        let gf = timer.run(|| sellp.apply(&b, &mut x).unwrap()).rate_giga(flops);
        t.row(&[
            name.to_string(),
            "sellp".into(),
            f2(gf),
            f2(sellp.padding_ratio()),
            "".into(),
        ]);

        let hybrid = Hybrid::from_data(exec.clone(), data).unwrap();
        let gf = timer.run(|| hybrid.apply(&b, &mut x).unwrap()).rate_giga(flops);
        t.row(&[
            name.to_string(),
            "hybrid".into(),
            f2(gf),
            "~1".into(),
            format!("ell width {}", hybrid.ell_part().stored_per_row()),
        ]);
    }
    t.print();

    println!("\n-- SELL-P slice-size sweep (circuit matrix) --");
    let (_, data) = &cases[2];
    let stats = MatrixStats::from_data(data);
    let flops = 2.0 * stats.nnz as f64;
    let b = Dense::filled(exec.clone(), Dim2::new(stats.n, 1), 1.0);
    let mut x = Dense::zeros(exec.clone(), Dim2::new(stats.n, 1));
    let mut t2 = Table::new(&["slice_size", "GF/s", "stored/nnz"]);
    for slice in [8usize, 16, 32, 64, 128] {
        let sellp = SellP::from_data_with_slice(exec.clone(), data, slice).unwrap();
        let gf = timer.run(|| sellp.apply(&b, &mut x).unwrap()).rate_giga(flops);
        t2.row(&[slice.to_string(), f2(gf), f2(sellp.padding_ratio())]);
    }
    t2.print();
    println!(
        "\nshape check: padding ratio grows with slice size on power-law\n\
         matrices (bigger slices absorb more of the dense row); ELL is\n\
         unusable on circuits while SELL-P/Hybrid stay near 1x storage."
    );
}
