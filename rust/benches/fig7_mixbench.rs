//! Fig. 7 reproduction: experimental roofline via mixbench-style
//! arithmetic-intensity sweep, GEN9 (left) and GEN12 (right).
//!
//! For each flops-per-byte point the model reports the attainable
//! GFLOP/s at double/single/half precision; the host column measures the
//! same fma-chain kernel on this CPU (shape validation). The GEN12
//! double column exposes the paper's headline observation: fp64
//! emulation collapses to 8 GFLOP/s.

use std::time::Instant;

use sparkle::bench_util::{f2, Table};
use sparkle::core::types::Precision;
use sparkle::perfmodel::{Device, Roofline};

/// Host fma-chain: y = y*s + t repeated `iters` times over a buffer.
fn host_mixbench(flops_per_elem: usize, n: usize) -> f64 {
    let iters = (flops_per_elem / 2).max(1);
    let mut buf = vec![1.0f64; n];
    // warmup
    for v in buf.iter_mut() {
        *v = *v * 0.999 + 0.001;
    }
    let t0 = Instant::now();
    let reps = 5;
    for _ in 0..reps {
        for v in buf.iter_mut() {
            let mut y = *v;
            for _ in 0..iters {
                y = y * 0.999 + 0.001;
            }
            *v = y;
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let flops = (2 * iters * n * reps) as f64;
    flops / secs / 1e9
}

fn panel(device: Device) {
    let spec = device.spec();
    let roof = Roofline::new(spec.clone());
    println!("\n-- {} --", spec.name);
    let mut t = Table::new(&[
        "flop/byte",
        "f64 GF/s",
        "f32 GF/s",
        "f16 GF/s",
        "host f64 GF/s",
    ]);
    for ai_num in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
        let ai = ai_num as f64 / 8.0; // flops per byte (8-byte elements)
        t.row(&[
            format!("{ai:.3}"),
            f2(roof.attainable_gflops(ai, Precision::Double)),
            f2(roof.attainable_gflops(ai, Precision::Single)),
            f2(roof.attainable_gflops(ai, Precision::Half)),
            f2(host_mixbench(ai_num, 1 << 18)),
        ]);
    }
    t.print();
    println!(
        "ridge points (flop/byte): f64 {:.2}  f32 {:.2}  f16 {:.2}  | peaks {:?} GFLOP/s",
        roof.ridge_point(Precision::Double),
        roof.ridge_point(Precision::Single),
        roof.ridge_point(Precision::Half),
        spec.peak_gflops
    );
}

fn main() {
    println!("== Fig. 7: experimental roofline (mixbench sweep) ==");
    panel(Device::Gen9);
    panel(Device::Gen12);
    println!(
        "\nshape check: GEN9 tops out at 105/430/810 GFLOP/s (d/s/h);\n\
         GEN12 reaches 2.2/4.0 TFLOP/s (s/h) but only 8 GFLOP/s at f64 —\n\
         the emulated-double cliff that motivates the paper's single-\n\
         precision evaluation on GEN12."
    );
}
