//! Fig. 9 reproduction: Krylov solver throughput on the Table-1 suite.
//!
//! Paper protocol (§6.4): 1000 iterations after warmup, COO SpMV inside
//! all solvers; upper panel GEN9/f64, lower GEN12/f32.
//!
//! Reported per (solver, matrix):
//!   * projected GFLOP/s on the target GPU from the solver's per-
//!     iteration flops/bytes/dispatch counts,
//!   * measured GFLOP/s of the real solver on this host's `par`
//!     executor (fewer iterations; throughput is iteration-count-
//!     invariant for fixed-work solvers).

use sparkle::bench_util::{bench_scale, f2, Table, Timer};
use sparkle::core::executor::Executor;
use sparkle::core::types::Value;
use sparkle::matgen::{suite, MatrixStats};
use sparkle::matrix::{Coo, Dense};
use sparkle::perfmodel::{project_solver, Device};
use sparkle::solver::{BiCgStab, Cg, Cgs, Gmres, Solver, SolverConfig};
use sparkle::stop::Criterion;
use sparkle::Dim2;

const MEASURED_ITERS: usize = 60;
const PAPER_ITERS: usize = 1000;

fn solvers<T: Value>() -> Vec<Box<dyn Solver<T>>> {
    let cfg = || SolverConfig::with_criterion(Criterion::iterations(MEASURED_ITERS));
    vec![
        Box::new(Cg::new(cfg())),
        Box::new(BiCgStab::new(cfg())),
        Box::new(Cgs::new(cfg())),
        Box::new(Gmres::new(cfg())),
    ]
}

/// Dispatches per iteration on an accelerator backend (for the launch-
/// overhead term of the projection): BLAS-1 + SpMV calls per iteration.
fn dispatches(name: &str) -> u64 {
    match name {
        "cg" => 7,
        "bicgstab" => 13,
        "cgs" => 13,
        "gmres" => 35, // avg over a restart cycle: grows with basis
        _ => 10,
    }
}

/// Host-side work per iteration in microseconds (Hessenberg handling and
/// the §6.4 "workaround" penalty for GMRES on the ported backend).
fn host_work_us(name: &str) -> f64 {
    if name == "gmres" {
        60.0
    } else {
        0.0
    }
}

fn panel<T: Value>(device: Device) {
    let scale = bench_scale();
    let p = T::PRECISION;
    println!("\n-- {} / {} (scale 1/{scale}, {PAPER_ITERS} paper-iterations) --",
             device.spec().name, p);
    let mut t = Table::new(&[
        "matrix", "solver", "proj GF/s", "host GF/s", "host iters/s",
    ]);
    let exec = Executor::par();
    for entry in suite::table1() {
        let data = entry.generate::<T>(scale);
        let stats = MatrixStats::from_data(&data);
        // device projections run at the *published* dimensions; the host
        // measurement below runs the scaled analog
        let full = stats.scaled_to(entry.n_full, entry.nnz_full);
        let a = Coo::from_data(exec.clone(), &data).unwrap();
        let b = Dense::filled(exec.clone(), Dim2::new(stats.n, 1), T::from_f64(1.0));
        for solver in solvers::<T>() {
            let flops = solver.flops_per_iter(full.nnz, full.n);
            let bytes = solver.bytes_per_iter(full.nnz, full.n, p.bytes());
            let (proj_gf, _ms) = project_solver(
                device,
                flops,
                bytes,
                dispatches(solver.name()),
                host_work_us(solver.name()),
                p,
                PAPER_ITERS,
            );
            // measured host run (one timed pass; solvers are expensive)
            let timer = Timer::new(0, 1);
            let mut x = Dense::zeros(exec.clone(), Dim2::new(stats.n, 1));
            let mut iters_done = 0usize;
            let st = timer.run(|| {
                let r = solver.solve(&a, &b, &mut x).unwrap();
                iters_done = r.iterations.max(1);
            });
            let host_flops = solver.flops_per_iter(stats.nnz, stats.n);
            let host_gf = (host_flops as f64 * iters_done as f64) / st.mean / 1e9;
            t.row(&[
                entry.name.to_string(),
                solver.name().to_string(),
                f2(proj_gf),
                f2(host_gf),
                f2(iters_done as f64 / st.mean),
            ]);
        }
    }
    t.print();
}

fn main() {
    println!("== Fig. 9: Krylov solver performance (COO SpMV) ==");
    // upper panel: GEN9, double
    panel::<f64>(Device::Gen9);
    // lower panel: GEN12, single
    panel::<f32>(Device::Gen12);
    println!(
        "\nshape check (paper §6.4): GEN9 solvers land between ~1.5 and\n\
         ~2.5 GFLOP/s, GEN12 between ~5 and ~9 GFLOP/s; the three short-\n\
         recurrence solvers cluster per matrix while GMRES trails\n\
         (Hessenberg handling + workaround paths); per-matrix spread\n\
         exceeds per-solver spread."
    );
}
