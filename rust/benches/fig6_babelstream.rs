//! Fig. 6 reproduction: BabelStream bandwidth vs array size.
//!
//! Left panel: GEN9, IEEE double. Right panel: GEN12, IEEE single.
//! Two series per kernel are reported:
//!   * `model` — the calibrated roofline projection for the Intel GPU
//!     (the paper's testbed substitute; reproduces the saturating shape
//!     and the DOT dip),
//!   * `host`  — the same kernels *measured* on this machine's `par`
//!     executor (validates the kernel implementations move the bytes
//!     they claim; absolute numbers are this CPU's, not the GPU's).

use sparkle::bench_util::{f2, Table, Timer};
use sparkle::core::executor::Executor;
use sparkle::core::types::Value;
use sparkle::kernels::stream::{self, StreamArrays, StreamKernel};
use sparkle::perfmodel::{Device, Roofline};

fn panel<T: Value>(device: Device, sizes: &[usize]) {
    let spec = device.spec();
    let roof = Roofline::new(spec.clone());
    println!(
        "\n-- {} / {} --",
        spec.name,
        T::PRECISION
    );
    let mut t = Table::new(&[
        "kernel",
        "elements",
        "MiB",
        "model GB/s",
        "host GB/s",
    ]);
    let exec = Executor::par();
    let timer = Timer::default();
    for &n in sizes {
        let mut arrays = StreamArrays::<T>::new(n);
        for kernel in StreamKernel::ALL {
            let bytes = (kernel.bytes_per_element(T::PRECISION.bytes()) * n) as f64;
            let model = if kernel == StreamKernel::Dot {
                roof.sync_bandwidth_at(bytes)
            } else {
                roof.bandwidth_at(bytes)
            };
            let stats = timer.run(|| {
                stream::run(&exec, kernel, &mut arrays).unwrap();
            });
            t.row(&[
                kernel.name().to_string(),
                n.to_string(),
                format!("{:.1}", bytes / 1024.0 / 1024.0),
                f2(model),
                f2(stats.rate_giga(bytes)),
            ]);
        }
    }
    t.print();
    let peak = roof.bandwidth_at(1e12);
    println!(
        "model peak {:.1} GB/s (paper: {} GB/s measured, {} theoretical)",
        peak, spec.bw_measured, spec.bw_theoretical
    );
}

fn main() {
    println!("== Fig. 6: BabelStream bandwidth vs array size ==");
    let sizes: Vec<usize> = (12..=26)
        .step_by(2)
        .map(|p| 1usize << p)
        .collect();
    // GEN9 panel uses double precision (paper left plot)
    panel::<f64>(Device::Gen9, &sizes);
    // GEN12 panel uses single precision (paper right plot)
    panel::<f32>(Device::Gen12, &sizes);
    println!(
        "\nshape check: bandwidth saturates with array size on both GPUs;\n\
         DOT trails the streaming kernels (global synchronization); GEN12\n\
         peak ≈ 1.6x GEN9 peak (58 vs 37 GB/s)."
    );
}
