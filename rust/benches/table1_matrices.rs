//! Table 1 reproduction: the ten test matrices with key characteristics.
//!
//! Prints published (paper) vs generated (scaled analog) dimension and
//! nonzeros plus the structural stats the perf model consumes.
//! Run full-size with `SPARKLE_SCALE=1 cargo bench --bench table1_matrices`.

use sparkle::bench_util::{bench_scale, Table};
use sparkle::matgen::{suite, MatrixStats};

fn main() {
    let scale = bench_scale();
    println!("== Table 1: test matrices (scale 1/{scale}) ==\n");
    let mut t = Table::new(&[
        "Matrix",
        "Origin",
        "n (paper)",
        "nnz (paper)",
        "n (gen)",
        "nnz (gen)",
        "nnz/row gen|paper",
        "max_row",
        "row_cv",
    ]);
    for entry in suite::table1() {
        let data = entry.generate::<f64>(scale);
        let s = MatrixStats::from_data(&data);
        t.row(&[
            entry.name.to_string(),
            entry.origin.to_string(),
            entry.n_full.to_string(),
            entry.nnz_full.to_string(),
            s.n.to_string(),
            s.nnz.to_string(),
            format!(
                "{:.1}|{:.1}",
                s.avg_row,
                entry.nnz_full as f64 / entry.n_full as f64
            ),
            s.max_row.to_string(),
            format!("{:.2}", s.row_cv),
        ]);
    }
    t.print();
    println!(
        "\nshape check: generated densities track the published nnz/row per\n\
         origin class; circuit entries carry the heavy row tails (max_row,\n\
         row_cv) that drive the Fig. 8 outliers."
    );
}
