//! Offline stand-in for the `num-traits` crate: the `Zero`/`One`/`Num`/
//! `NumAssign`/`Float` tower for `f32` and `f64`, which is the exact
//! surface sparkle's `Value` trait bounds require.

use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Rem, RemAssign, Sub, SubAssign};

/// Additive identity.
pub trait Zero: Sized + Add<Self, Output = Self> {
    fn zero() -> Self;
    fn is_zero(&self) -> bool;
}

/// Multiplicative identity.
pub trait One: Sized + Mul<Self, Output = Self> {
    fn one() -> Self;
}

/// The basic arithmetic operators.
pub trait NumOps<Rhs = Self, Output = Self>:
    Add<Rhs, Output = Output>
    + Sub<Rhs, Output = Output>
    + Mul<Rhs, Output = Output>
    + Div<Rhs, Output = Output>
    + Rem<Rhs, Output = Output>
{
}

impl<T, Rhs, Output> NumOps<Rhs, Output> for T where
    T: Add<Rhs, Output = Output>
        + Sub<Rhs, Output = Output>
        + Mul<Rhs, Output = Output>
        + Div<Rhs, Output = Output>
        + Rem<Rhs, Output = Output>
{
}

/// Numeric type with identities and arithmetic.
pub trait Num: PartialEq + Zero + One + NumOps {}
impl<T> Num for T where T: PartialEq + Zero + One + NumOps {}

/// The compound-assignment operators.
pub trait NumAssignOps<Rhs = Self>:
    AddAssign<Rhs> + SubAssign<Rhs> + MulAssign<Rhs> + DivAssign<Rhs> + RemAssign<Rhs>
{
}

impl<T, Rhs> NumAssignOps<Rhs> for T where
    T: AddAssign<Rhs> + SubAssign<Rhs> + MulAssign<Rhs> + DivAssign<Rhs> + RemAssign<Rhs>
{
}

/// Numeric type supporting the assignment operators.
pub trait NumAssign: Num + NumAssignOps {}
impl<T> NumAssign for T where T: Num + NumAssignOps {}

/// IEEE floating-point numbers.
pub trait Float: Num + Copy + Neg<Output = Self> + PartialOrd {
    fn nan() -> Self;
    fn infinity() -> Self;
    fn neg_infinity() -> Self;
    fn min_value() -> Self;
    fn max_value() -> Self;
    fn epsilon() -> Self;
    fn is_nan(self) -> bool;
    fn is_finite(self) -> bool;
    fn abs(self) -> Self;
    fn signum(self) -> Self;
    fn recip(self) -> Self;
    fn sqrt(self) -> Self;
    fn powi(self, n: i32) -> Self;
    fn powf(self, n: Self) -> Self;
    fn exp(self) -> Self;
    fn ln(self) -> Self;
    fn log2(self) -> Self;
    fn log10(self) -> Self;
    fn floor(self) -> Self;
    fn ceil(self) -> Self;
    fn round(self) -> Self;
    fn min(self, other: Self) -> Self;
    fn max(self, other: Self) -> Self;
    fn mul_add(self, a: Self, b: Self) -> Self;
    fn hypot(self, other: Self) -> Self;
}

macro_rules! impl_float {
    ($t:ty) => {
        impl Zero for $t {
            fn zero() -> Self {
                0.0
            }
            fn is_zero(&self) -> bool {
                *self == 0.0
            }
        }

        impl One for $t {
            fn one() -> Self {
                1.0
            }
        }

        impl Float for $t {
            fn nan() -> Self {
                <$t>::NAN
            }
            fn infinity() -> Self {
                <$t>::INFINITY
            }
            fn neg_infinity() -> Self {
                <$t>::NEG_INFINITY
            }
            fn min_value() -> Self {
                <$t>::MIN
            }
            fn max_value() -> Self {
                <$t>::MAX
            }
            fn epsilon() -> Self {
                <$t>::EPSILON
            }
            fn is_nan(self) -> bool {
                <$t>::is_nan(self)
            }
            fn is_finite(self) -> bool {
                <$t>::is_finite(self)
            }
            fn abs(self) -> Self {
                <$t>::abs(self)
            }
            fn signum(self) -> Self {
                <$t>::signum(self)
            }
            fn recip(self) -> Self {
                <$t>::recip(self)
            }
            fn sqrt(self) -> Self {
                <$t>::sqrt(self)
            }
            fn powi(self, n: i32) -> Self {
                <$t>::powi(self, n)
            }
            fn powf(self, n: Self) -> Self {
                <$t>::powf(self, n)
            }
            fn exp(self) -> Self {
                <$t>::exp(self)
            }
            fn ln(self) -> Self {
                <$t>::ln(self)
            }
            fn log2(self) -> Self {
                <$t>::log2(self)
            }
            fn log10(self) -> Self {
                <$t>::log10(self)
            }
            fn floor(self) -> Self {
                <$t>::floor(self)
            }
            fn ceil(self) -> Self {
                <$t>::ceil(self)
            }
            fn round(self) -> Self {
                <$t>::round(self)
            }
            fn min(self, other: Self) -> Self {
                <$t>::min(self, other)
            }
            fn max(self, other: Self) -> Self {
                <$t>::max(self, other)
            }
            fn mul_add(self, a: Self, b: Self) -> Self {
                <$t>::mul_add(self, a, b)
            }
            fn hypot(self, other: Self) -> Self {
                <$t>::hypot(self, other)
            }
        }
    };
}

impl_float!(f32);
impl_float!(f64);

#[cfg(test)]
mod tests {
    use super::*;

    fn norm<T: Float>(v: &[T]) -> T {
        let mut acc = T::zero();
        for &x in v {
            acc = acc + x * x;
        }
        acc.sqrt()
    }

    #[test]
    fn generic_float_usable() {
        assert!((norm(&[3.0f64, 4.0]) - 5.0).abs() < 1e-15);
        assert!((norm(&[3.0f32, 4.0]) - 5.0).abs() < 1e-6);
        assert!(f64::zero().is_zero());
        assert_eq!(f32::one(), 1.0);
    }

    fn assign<T: NumAssign + Copy>(mut a: T, b: T) -> T {
        a += b;
        a *= b;
        a
    }

    #[test]
    fn assign_ops() {
        assert_eq!(assign(1.0f64, 2.0), 6.0);
    }
}
