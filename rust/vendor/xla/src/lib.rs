//! Offline stand-in for the `xla-rs` PJRT bridge.
//!
//! Mirrors the subset of the real crate's API that sparkle's `runtime`
//! layer calls. Host-side literal construction, reshaping and readback
//! are fully functional (sparkle's marshalling tests exercise them);
//! `compile`/`execute` report [`Error`] because no PJRT plugin is linked
//! into this build — exactly the failure mode of the real crate on a
//! machine without an XLA installation. Callers that gate on artifact
//! availability never reach those paths.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type matching the real crate's role (opaque message carrier).
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Crate-local result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types a PJRT buffer/literal can hold.
pub trait ArrayElement: Copy + Send + Sync + 'static {
    /// Primitive-type tag (mirrors XLA's `PrimitiveType` names).
    const TY: ElementType;
    /// Serialize one element (little-endian, fixed width).
    fn write_le(self, out: &mut Vec<u8>);
    /// Deserialize one element from `Self::TY.byte_width()` bytes.
    fn read_le(bytes: &[u8]) -> Self;
}

/// Primitive element type tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S32,
}

impl ElementType {
    /// Bytes per element.
    pub fn byte_width(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::F64 => 8,
        }
    }
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl ArrayElement for f64 {
    const TY: ElementType = ElementType::F64;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        f64::from_le_bytes([
            bytes[0], bytes[1], bytes[2], bytes[3], bytes[4], bytes[5], bytes[6], bytes[7],
        ])
    }
}

impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

/// Host-side literal: typed bytes plus a shape.
#[derive(Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: ArrayElement>(v: &[T]) -> Literal {
        let mut data = Vec::with_capacity(v.len() * T::TY.byte_width());
        for &x in v {
            x.write_le(&mut data);
        }
        Literal {
            ty: T::TY,
            dims: vec![v.len() as i64],
            data,
        }
    }

    /// Element count.
    pub fn element_count(&self) -> usize {
        self.data.len() / self.ty.byte_width()
    }

    /// Shape dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret with new dims; element count must be preserved.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape: {} elements into dims {:?}",
                self.element_count(),
                dims
            )));
        }
        Ok(Literal {
            ty: self.ty,
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    /// Read back as a host vector of `T` (type must match).
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error::new(format!(
                "to_vec: literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        let w = self.ty.byte_width();
        Ok(self.data.chunks_exact(w).map(T::read_le).collect())
    }

    /// Split a tuple literal into its parts. The stub never produces
    /// tuple literals (execution is unavailable), so this errs on
    /// non-tuples rather than silently wrapping.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::new(
            "decompose_tuple: no tuple literals without a PJRT execution",
        ))
    }
}

/// A PJRT device handle (opaque).
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice;

/// Device-resident buffer. The stub keeps the literal host-side.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Copy back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Compiled-and-loaded executable handle.
pub struct PjRtLoadedExecutable {
    name: String,
}

impl PjRtLoadedExecutable {
    /// Execute on host literals.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(format!(
            "execute {}: no PJRT plugin in this build",
            self.name
        )))
    }

    /// Execute on device-resident buffers.
    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(format!(
            "execute_b {}: no PJRT plugin in this build",
            self.name
        )))
    }
}

/// Parsed HLO module (text payload is retained but never lowered).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text file from disk.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::new(format!("read {:?}: {e}", path.as_ref())))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    text_len: usize,
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            text_len: proto.text.len(),
        }
    }
}

/// PJRT client. The CPU client constructs successfully (matching the
/// real crate, whose CPU plugin is always linked); compilation fails.
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    /// CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "cpu" })
    }

    /// Platform name, e.g. "cpu".
    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    /// Move host data into a buffer on `device` (default device if None).
    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        let count: usize = dims.iter().product();
        if count != data.len() {
            return Err(Error::new(format!(
                "buffer_from_host_buffer: {} elements into dims {:?}",
                data.len(),
                dims
            )));
        }
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let lit = Literal::vec1(data).reshape(&dims_i64)?;
        Ok(PjRtBuffer { literal: lit })
    }

    /// Compile a computation for this client.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(format!(
            "compile: no PJRT plugin in this build ({} bytes of HLO text)",
            comp.text_len
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_f64() {
        let v = vec![1.0f64, -2.5, 3.25];
        let lit = Literal::vec1(&v);
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<f64>().unwrap(), v);
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let lit = Literal::vec1(&[1i32, 2, 3, 4]);
        let m = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert_eq!(m.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(lit.reshape(&[3]).is_err());
        // rank-0 scalar
        let s = Literal::vec1(&[7.0f32]).reshape(&[]).unwrap();
        assert_eq!(s.element_count(), 1);
    }

    #[test]
    fn client_buffers_work_execution_fails() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        let buf = c
            .buffer_from_host_buffer(&[1.0f64, 2.0], &[2], None)
            .unwrap();
        assert_eq!(buf.to_literal_sync().unwrap().to_vec::<f64>().unwrap(), vec![1.0, 2.0]);
        let comp = XlaComputation::from_proto(&HloModuleProto {
            text: "HloModule m".into(),
        });
        assert!(c.compile(&comp).is_err());
    }
}
