//! Offline stand-in for the `once_cell` crate: single-threaded
//! `unsync::OnceCell`, the only type sparkle uses (lazy per-matrix
//! caches on `Csr`/`Coo`/`Ell`).

/// Single-threaded cells.
pub mod unsync {
    use std::cell::UnsafeCell;
    use std::fmt;

    /// A cell which can be written to only once. `!Sync` by construction
    /// (interior `UnsafeCell`), matching the real crate.
    pub struct OnceCell<T> {
        inner: UnsafeCell<Option<T>>,
    }

    impl<T> OnceCell<T> {
        /// An empty cell.
        pub const fn new() -> Self {
            Self {
                inner: UnsafeCell::new(None),
            }
        }

        /// The stored value, if set.
        pub fn get(&self) -> Option<&T> {
            // Safe: &self access on a !Sync type; a stored value is
            // never removed or replaced, so the reference stays valid.
            unsafe { (*self.inner.get()).as_ref() }
        }

        /// Set the value; errs with the value if already set.
        pub fn set(&self, value: T) -> Result<(), T> {
            let slot = unsafe { &mut *self.inner.get() };
            if slot.is_some() {
                return Err(value);
            }
            *slot = Some(value);
            Ok(())
        }

        /// The stored value, initializing with `f` if empty.
        pub fn get_or_init(&self, f: impl FnOnce() -> T) -> &T {
            if self.get().is_none() {
                // `f` may itself use the cell; only write if still empty
                // (mirrors the real crate's reentrancy behaviour closely
                // enough for sparkle's non-reentrant initializers).
                let value = f();
                let _ = self.set(value);
            }
            self.get().expect("OnceCell initialized")
        }

        /// Take the value out, leaving the cell empty.
        pub fn take(&mut self) -> Option<T> {
            self.inner.get_mut().take()
        }
    }

    impl<T> Default for OnceCell<T> {
        fn default() -> Self {
            Self::new()
        }
    }

    impl<T: Clone> Clone for OnceCell<T> {
        fn clone(&self) -> Self {
            let cell = Self::new();
            if let Some(v) = self.get() {
                let _ = cell.set(v.clone());
            }
            cell
        }
    }

    impl<T: fmt::Debug> fmt::Debug for OnceCell<T> {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            match self.get() {
                Some(v) => write!(f, "OnceCell({v:?})"),
                None => write!(f, "OnceCell(<uninit>)"),
            }
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn set_once() {
            let c = OnceCell::new();
            assert!(c.get().is_none());
            assert!(c.set(5).is_ok());
            assert_eq!(c.set(6), Err(6));
            assert_eq!(c.get(), Some(&5));
        }

        #[test]
        fn get_or_init_runs_once() {
            let c = OnceCell::new();
            let mut calls = 0;
            assert_eq!(*c.get_or_init(|| {
                calls += 1;
                7
            }), 7);
            assert_eq!(*c.get_or_init(|| unreachable!()), 7);
            assert_eq!(calls, 1);
        }

        #[test]
        fn clone_copies_value() {
            let c = OnceCell::new();
            let _ = c.set(vec![1, 2]);
            let d = c.clone();
            assert_eq!(d.get(), Some(&vec![1, 2]));
        }
    }
}
